//! Static discrete-event executor.
//!
//! Takes the schedules a wave produced, plus the background workload, and
//! advances simulated time on the unified event core ([`super::event`]):
//! iteration completions re-price the next iteration from the *current*
//! contention (background churn, other DL jobs co-resident on the same
//! nodes), utilization is sampled at a fixed period (the paper samples
//! every 10 minutes), and per-job completions release resources and report
//! the training time used both for metrics and as the RL reward `O`.
//!
//! This executor runs with *frozen membership* — the dynamic driver in
//! `coordinator::dynamic` handles arrival streams and node churn on the
//! same [`EventQueue`].

use crate::cluster::Deployment;
use crate::dnn::ModelGraph;
use crate::obs;
use crate::sched::JobSchedule;
use crate::workload::Workload;

use super::event::{EventKind, EventQueue};
use super::state::{ResourceState, TaskHandle};
use super::timing;

/// Utilization / task-count sampling period in simulated seconds
/// ("we measured the resource utilization of the devices every 10
/// minutes").
pub const SAMPLE_PERIOD_SECS: f64 = 600.0;

/// Per-job execution result.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job_id: usize,
    /// Training time: execution start (post-scheduling) → completion.
    pub train_secs: f64,
    pub iterations: usize,
}

/// Everything the execution produced.
#[derive(Debug, Clone, Default)]
pub struct ExecutionReport {
    pub jobs: Vec<JobResult>,
    /// Per-(node, sample) task counts (DL partitions + background tasks).
    pub tasks_per_device: Vec<f64>,
    /// Per-(node, sample) actual utilization, one vec per resource kind
    /// (cpu, mem, bw).
    pub util_cpu: Vec<f64>,
    pub util_mem: Vec<f64>,
    pub util_bw: Vec<f64>,
    /// Nodes entering actual overload during execution (the paper's
    /// residual unsafe actions from unpredictable demands).
    pub runtime_overloads: usize,
    /// Simulated time when the last job finished.
    pub makespan: f64,
}

/// The executor: owns the event loop for one experiment run.
pub struct Executor<'a> {
    pub dep: &'a Deployment,
    pub workload: &'a Workload,
    pub graph: &'a ModelGraph,
    pub alpha: f64,
    pub sample_period: f64,
    /// Utilization / task-count sampling continues at least this long,
    /// so methods that finish sooner record their freed-up resources —
    /// the paper samples over the whole experiment duration, which is
    /// why shielded methods report *lower* median utilization.
    pub sample_horizon: f64,
}

struct JobRun {
    start: f64,
    iters_done: usize,
    iters_total: usize,
    handles: Vec<TaskHandle>,
    done: bool,
}

/// Place every background segment active at t = 0 into `state` so the
/// schedulers observe the PageRank load that is already running (§V-A:
/// the jobs run "throughout the whole training period").  Returns the
/// handles to hand to [`Executor::run_with_background`].
pub fn place_initial_background(
    state: &mut ResourceState,
    workload: &Workload,
) -> Vec<(usize, TaskHandle)> {
    workload
        .background
        .iter()
        .enumerate()
        .filter(|(_, bg)| bg.start <= 0.0 && bg.end > 0.0)
        .map(|(i, bg)| (i, state.place(bg.node, bg.demand, bg.demand, false)))
        .collect()
}

impl<'a> Executor<'a> {
    pub fn new(dep: &'a Deployment, workload: &'a Workload, graph: &'a ModelGraph, alpha: f64) -> Self {
        Executor {
            dep,
            workload,
            graph,
            alpha,
            sample_period: SAMPLE_PERIOD_SECS,
            sample_horizon: 0.0,
        }
    }

    /// Run all scheduled jobs to completion.  `state` must already hold
    /// the wave's placements (the schedules' handles) and any background
    /// segments pre-placed before scheduling (`pre_placed`, as returned by
    /// [`place_initial_background`]).
    pub fn run(&self, state: &mut ResourceState, schedules: &mut Vec<JobSchedule>) -> ExecutionReport {
        self.run_with_background(state, schedules, Vec::new())
    }

    pub fn run_with_background(
        &self,
        state: &mut ResourceState,
        schedules: &mut Vec<JobSchedule>,
        pre_placed: Vec<(usize, TaskHandle)>,
    ) -> ExecutionReport {
        let n_clusters = self.dep.clusters.len();
        let mut report = ExecutionReport::default();
        let mut queue = EventQueue::new();

        // Background workload events.  Pre-placed segments only need
        // their end events.
        let mut bg_handles: Vec<Option<TaskHandle>> = vec![None; self.workload.background.len()];
        for (i, h) in pre_placed {
            bg_handles[i] = Some(h);
            queue.push(self.workload.background[i].end, EventKind::BgEnd { bg: i });
        }
        for (i, bg) in self.workload.background.iter().enumerate() {
            if bg_handles[i].is_none() {
                queue.push(bg.start, EventKind::BgStart { bg: i });
            }
        }

        // Job starts: execution begins after the decision completes.
        let mut runs: Vec<JobRun> = Vec::with_capacity(schedules.len());
        for (ji, s) in schedules.iter_mut().enumerate() {
            let start = s.job.arrival + s.decision_secs;
            runs.push(JobRun {
                start,
                iters_done: 0,
                iters_total: s.job.iterations,
                handles: std::mem::take(&mut s.handles),
                done: false,
            });
            // First iteration completion is priced lazily at start time:
            // use a zero-length bootstrap event.
            queue.push(start, EventKind::IterEnd { job: ji });
        }

        queue.push(self.sample_period, EventKind::Sample);

        let mut was_overloaded: Vec<bool> =
            (0..self.dep.n()).map(|n| state.actual_overloaded(n, self.alpha)).collect();
        let check_overloads = |state: &ResourceState, report: &mut ExecutionReport,
                                   was: &mut Vec<bool>| {
            for n in 0..self.dep.n() {
                let now = state.actual_overloaded(n, self.alpha);
                if now && !was[n] {
                    report.runtime_overloads += 1;
                }
                was[n] = now;
            }
        };

        let mut remaining = runs.len();
        while let Some(ev) = queue.pop() {
            obs::sim_time(ev.t);
            let _ev_span = obs::span(obs::Phase::EventDispatch);
            match ev.kind {
                EventKind::BgStart { bg } => {
                    let b = &self.workload.background[bg];
                    let h = state.place(b.node, b.demand, b.demand, false);
                    bg_handles[bg] = Some(h);
                    queue.push(b.end.max(ev.t), EventKind::BgEnd { bg });
                    check_overloads(state, &mut report, &mut was_overloaded);
                }
                EventKind::BgEnd { bg } => {
                    if let Some(h) = bg_handles[bg].take() {
                        state.release(h);
                    }
                    check_overloads(state, &mut report, &mut was_overloaded);
                }
                EventKind::Sample => {
                    if remaining > 0 || ev.t < self.sample_horizon {
                        for n in 0..self.dep.n() {
                            report.tasks_per_device.push(state.task_count(n) as f64);
                            report.util_cpu.push(state.actual_util(n, crate::cluster::ResourceKind::Cpu).clamp(0.0, 2.0));
                            report.util_mem.push(state.actual_util(n, crate::cluster::ResourceKind::Mem).clamp(0.0, 2.0));
                            report.util_bw.push(state.actual_util(n, crate::cluster::ResourceKind::Bw).clamp(0.0, 2.0));
                        }
                        // Windowed samplers: read-only over the samples
                        // just pushed (no RNG, pinned).  The static path
                        // has no collision/forward activity mid-run, so
                        // only the depth + utilization series fire here.
                        if obs::active() {
                            let n = self.dep.n();
                            let tail =
                                |v: &[f64]| crate::util::stats::mean_of(&v[v.len() - n..]);
                            obs::sample(obs::Series::QueueDepth, ev.t, queue.len() as f64);
                            obs::sample(obs::Series::UtilCpu, ev.t, tail(&report.util_cpu));
                            obs::sample(obs::Series::UtilMem, ev.t, tail(&report.util_mem));
                            obs::sample(obs::Series::UtilBw, ev.t, tail(&report.util_bw));
                        }
                        queue.push(ev.t + self.sample_period, EventKind::Sample);
                    }
                }
                EventKind::IterEnd { job } => {
                    let sched = &schedules[job];
                    let run = &mut runs[job];
                    if run.done {
                        continue;
                    }
                    if ev.t > run.start {
                        run.iters_done += 1;
                    }
                    if run.iters_done >= run.iters_total {
                        run.done = true;
                        remaining -= 1;
                        for h in run.handles.drain(..) {
                            state.release(h);
                        }
                        report.jobs.push(JobResult {
                            job_id: sched.job.id,
                            train_secs: ev.t - run.start,
                            iterations: run.iters_done,
                        });
                        report.makespan = report.makespan.max(ev.t);
                        check_overloads(state, &mut report, &mut was_overloaded);
                        if remaining == 0 && ev.t >= self.sample_horizon {
                            break;
                        }
                    } else {
                        // Price the next iteration under current contention;
                        // the first one also pays the pipeline fill.
                        let head = self.dep.clusters[sched.job.cluster].head;
                        let mut dt = timing::iteration_secs(
                            self.dep,
                            state,
                            self.graph,
                            &sched.placement,
                            sched.job.owner,
                            head,
                            n_clusters,
                        );
                        if run.iters_done == 0 {
                            dt += timing::pipeline_fill_secs(
                                self.dep,
                                state,
                                self.graph,
                                &sched.placement,
                            );
                        }
                        queue.push(ev.t + dt.max(1e-6), EventKind::IterEnd { job });
                    }
                }
                EventKind::JobArrival { .. }
                | EventKind::ViewRefresh
                | EventKind::NodeFail { .. }
                | EventKind::NodeJoin { .. }
                | EventKind::MobilityTick
                | EventKind::RequestArrival { .. }
                | EventKind::RequestDone { .. } => {
                    unreachable!(
                        "the static executor does not schedule churn/mobility/serving events"
                    )
                }
            }
        }
        report.jobs.sort_by_key(|j| j.job_id);
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, CONTAINER_PROFILE};
    use crate::dnn::ModelKind;
    use crate::rl::{RewardParams, TabularQ};
    use crate::sched::marl_wave;
    use crate::util::Rng;
    use crate::workload::{Workload, WorkloadSpec};

    fn run_once(iterations: usize, workload_frac: f64) -> (ExecutionReport, usize) {
        run_model(ModelKind::Rnn, iterations, workload_frac)
    }

    fn run_model(
        model: ModelKind,
        iterations: usize,
        workload_frac: f64,
    ) -> (ExecutionReport, usize) {
        run_model_seeded(model, iterations, workload_frac, 7)
    }

    fn run_model_seeded(
        model: ModelKind,
        iterations: usize,
        workload_frac: f64,
        seed: u64,
    ) -> (ExecutionReport, usize) {
        let mut rng = Rng::new(seed);
        let dep = Deployment::generate(&mut rng, 5, 5, &CONTAINER_PROFILE);
        let mut state = ResourceState::new(&dep);
        let graph = model.build();
        let spec = WorkloadSpec {
            model,
            iterations,
            workload: workload_frac,
            ..Default::default()
        };
        let wl = Workload::generate(&mut rng, &dep, &spec, 100_000.0);
        let jobs: Vec<_> = wl.dl_jobs.iter().filter(|j| j.cluster == 0).cloned().collect();
        let mut policy = TabularQ::new(0.2, 0.1);
        let params = RewardParams::default();
        let out = marl_wave(
            &dep, &mut state, &graph, &jobs, &mut policy, None, &params, 3, &mut rng,
        );
        let mut schedules = out.schedules;
        let exec = Executor::new(&dep, &wl, &graph, params.alpha);
        let report = exec.run(&mut state, &mut schedules);
        // After completion all DL tasks are released.
        let left: usize = (0..dep.n()).map(|n| state.dl_task_count(n)).sum();
        (report, left)
    }

    #[test]
    fn all_jobs_complete_and_release() {
        let (report, left) = run_once(5, 1.0);
        assert_eq!(report.jobs.len(), 3);
        assert_eq!(left, 0);
        for j in &report.jobs {
            assert_eq!(j.iterations, 5);
            assert!(j.train_secs > 0.0);
        }
        assert!(report.makespan > 0.0);
    }

    #[test]
    fn more_iterations_take_longer() {
        let (r5, _) = run_once(5, 1.0);
        let (r15, _) = run_once(15, 1.0);
        let t5: f64 = r5.jobs.iter().map(|j| j.train_secs).sum();
        let t15: f64 = r15.jobs.iter().map(|j| j.train_secs).sum();
        assert!(t15 > 2.0 * t5, "t5={t5} t15={t15}");
    }

    #[test]
    fn higher_workload_slows_training() {
        // VGG's CPU-heavy layers make background contention visible; a
        // single seed is noisy (placements differ run to run), so compare
        // totals pooled over seeds.
        let mut t_low = 0.0;
        let mut t_high = 0.0;
        for seed_shift in 0..3u64 {
            let (r_low, _) = run_model_seeded(ModelKind::Vgg16, 5, 0.4, 7 + seed_shift);
            let (r_high, _) = run_model_seeded(ModelKind::Vgg16, 5, 1.0, 7 + seed_shift);
            t_low += r_low.jobs.iter().map(|j| j.train_secs).sum::<f64>();
            t_high += r_high.jobs.iter().map(|j| j.train_secs).sum::<f64>();
        }
        assert!(t_high > t_low, "low={t_low} high={t_high}");
    }

    #[test]
    fn samples_collected_when_run_is_long() {
        let (report, _) = run_once(50, 1.0);
        // Sampling every 600 s; RNN jobs take a while with contention.
        if report.makespan > SAMPLE_PERIOD_SECS {
            assert!(!report.tasks_per_device.is_empty());
            assert_eq!(report.util_cpu.len(), report.util_mem.len());
            assert_eq!(report.util_cpu.len(), report.util_bw.len());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = run_once(5, 1.0);
        let (b, _) = run_once(5, 1.0);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.train_secs, y.train_secs);
        }
        assert_eq!(a.runtime_overloads, b.runtime_overloads);
    }
}
