//! The unified event core of the simulator.
//!
//! Every dynamic occurrence in an experiment — job arrivals, iteration
//! completions, background-workload churn, periodic sampling and state-view
//! refreshes, and node join/leave/failure — is one [`EventKind`] drawn from
//! a single time-ordered [`EventQueue`].  The static executor
//! (`sim::engine`) and the dynamic churn driver (`coordinator::dynamic`)
//! both run on this queue; they differ only in which kinds they schedule
//! and how they handle them.
//!
//! Ordering is deterministic: events pop by ascending time, ties broken by
//! insertion sequence.  Because every scenario owns its queue and pushes
//! events in a seed-determined order, replays are bit-identical regardless
//! of host thread count.
//!
//! Adding a new event kind is a three-step change: add the variant here,
//! schedule it (`EventQueue::push`) from whichever layer owns its timing,
//! and handle it in the driver's `match` — the compiler's exhaustiveness
//! check points at every driver that must decide what the kind means.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::cluster::NodeId;

/// One kind of simulated occurrence.  The payload indexes into the
/// scheduling driver's own tables (workload job lists, background-segment
/// lists), keeping the queue itself free of references.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A batch of DL jobs arrives and requests scheduling.  `wave`
    /// indexes the driver's precomputed arrival-batch table.
    JobArrival { wave: usize },
    /// One pipeline iteration of running job `job` completes (also used
    /// as the zero-length bootstrap event at execution start).
    IterEnd { job: usize },
    /// Background segment `bg` starts occupying its node.
    BgStart { bg: usize },
    /// Background segment `bg` releases its node.
    BgEnd { bg: usize },
    /// Periodic utilization / task-count sampling tick.
    Sample,
    /// Periodic refresh of the schedulers' (stale) state views.
    ViewRefresh,
    /// Edge node `node` fails: membership shrinks, resident tasks are
    /// lost, stranded DL layers must be rescheduled.
    NodeFail { node: NodeId },
    /// Edge node `node` (re)joins its cluster.
    NodeJoin { node: NodeId },
    /// Periodic mobility tick: node positions advance and every
    /// position-derived structure refreshes (adjacency, link matrices,
    /// shield regions, candidate sets).
    MobilityTick,
    /// Inference request `req` (an index into the driver's request
    /// table) arrives at its origin node and asks for placement —
    /// admission control, one shielded policy decision, then service.
    RequestArrival { req: usize },
    /// Inference request `req` finishes service and releases its host.
    RequestDone { req: usize },
}

/// A scheduled event: fire time plus insertion sequence (the tie-break).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub t: f64,
    pub seq: usize,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: reverse the comparison; break time ties by insertion
        // sequence for determinism.
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic time-ordered event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    seq: usize,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `kind` at simulated time `t`.
    pub fn push(&mut self, t: f64, kind: EventKind) {
        self.heap.push(Event { t, seq: self.seq, kind });
        self.seq += 1;
    }

    /// Next event in (time, insertion) order.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// The event `pop` would return, without removing it — the shard
    /// driver peeks the global queue to pick each epoch's barrier time.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::Sample);
        q.push(1.0, EventKind::IterEnd { job: 0 });
        q.push(3.0, EventKind::BgStart { bg: 2 });
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.t).collect();
        assert_eq!(order, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn ties_break_by_insertion_sequence() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::NodeFail { node: 7 });
        q.push(2.0, EventKind::NodeJoin { node: 7 });
        q.push(2.0, EventKind::MobilityTick);
        q.push(2.0, EventKind::ViewRefresh);
        let kinds: Vec<EventKind> = std::iter::from_fn(|| q.pop()).map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::NodeFail { node: 7 },
                EventKind::NodeJoin { node: 7 },
                EventKind::MobilityTick,
                EventKind::ViewRefresh,
            ]
        );
    }

    #[test]
    fn interleaved_push_pop_stays_ordered() {
        let mut q = EventQueue::new();
        q.push(10.0, EventKind::Sample);
        q.push(1.0, EventKind::JobArrival { wave: 0 });
        let first = q.pop().unwrap();
        assert_eq!(first.kind, EventKind::JobArrival { wave: 0 });
        // An event scheduled mid-run before the pending one still wins.
        q.push(4.0, EventKind::IterEnd { job: 1 });
        assert_eq!(q.pop().unwrap().t, 4.0);
        assert_eq!(q.pop().unwrap().t, 10.0);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn peek_matches_pop_without_consuming() {
        let mut q = EventQueue::new();
        assert!(q.peek().is_none());
        q.push(5.0, EventKind::Sample);
        q.push(1.0, EventKind::ViewRefresh);
        let head = *q.peek().unwrap();
        assert_eq!(head.t, 1.0);
        assert_eq!(q.len(), 2, "peek must not consume");
        let popped = q.pop().unwrap();
        assert_eq!((popped.t, popped.seq), (head.t, head.seq));
    }

    #[test]
    fn len_tracks_pending_events() {
        let mut q = EventQueue::new();
        assert_eq!(q.len(), 0);
        q.push(1.0, EventKind::Sample);
        q.push(2.0, EventKind::Sample);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn identical_push_sequences_replay_identically() {
        let build = || {
            let mut q = EventQueue::new();
            for i in 0..50 {
                q.push((i % 7) as f64, EventKind::IterEnd { job: i });
            }
            std::iter::from_fn(move || q.pop()).map(|e| (e.t, e.kind)).collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }
}
