//! Discrete-event simulation of the edge deployment.
//!
//! [`state`] tracks resident demands and utilization; [`timing`] prices a
//! training iteration for a given placement (compute, inter-level
//! transfers, parameter synchronization, contention); [`engine`] advances
//! simulated time across scheduled DL jobs, churning background
//! workload, sampling utilization, and recording completions.

pub mod engine;
pub mod state;
pub mod timing;

pub use engine::{ExecutionReport, Executor};
pub use state::{ResourceState, TaskHandle};
