//! Discrete-event simulation of the edge deployment.
//!
//! [`event`] is the unified event core: one time-ordered queue whose
//! kinds cover job arrivals, iteration completions, background churn,
//! sampling/state-view refreshes and node join/leave/failure.  [`state`]
//! tracks resident demands and utilization; [`timing`] prices a training
//! iteration for a given placement (compute, inter-level transfers,
//! parameter synchronization, contention); [`engine`] advances simulated
//! time across scheduled DL jobs on the event core, churning background
//! workload, sampling utilization, and recording completions.

pub mod engine;
pub mod event;
pub mod state;
pub mod timing;

pub use engine::{ExecutionReport, Executor};
pub use event::{Event, EventKind, EventQueue};
pub use state::{ResourceState, TaskHandle};
