//! Iteration-time model (§6 of DESIGN.md).
//!
//! The paper's clusters train with *concurrent data/model parallelism*
//! (its layer/level formulation follows PipeDream): every placed layer is
//! a pipeline stage and all stages process (micro)batches concurrently.
//! Steady-state throughput is therefore set by the **bottleneck stage**:
//!
//! ```text
//! t_iter  = max( max_l t_compute(l, P(l)),  max_xfer,  t_sync )
//! t_fill  = Σ_levels max_{l ∈ level} t_compute + Σ transfers   (once)
//! JCT     ≈ t_fill + iterations × t_iter
//! ```
//!
//! * `t_compute` — layer FLOPs over the CPU share the node grants
//!   (work-conserving proportional sharing over resident demands — in a
//!   pipeline every resident stage is active), inflated by the
//!   memory-pressure factor when resident memory exceeds capacity.
//!   This is how action collisions become longer training times: a
//!   piled-up node dilutes every stage it hosts, and the slowest stage
//!   *is* the iteration time.
//! * `transfer` — activation bytes over the pairwise link for
//!   consecutive layers on different nodes, throttled by NIC contention.
//!   Link bandwidth/latency come from [`crate::net::Topology::transfer_secs`],
//!   which since the sparse link model prices each pair on demand
//!   (distance-attenuated per-node base rates, `net::link`) instead of
//!   reading O(n²) matrices — this file is the model's hottest consumer.
//! * `t_sync` — parameter-server synchronization: replica parameters flow
//!   owner → cluster head, and heads share the global PS ingress with
//!   every other cluster — the cause of the paper's "JCT grows with the
//!   number of edges" trend (Fig 4).

use crate::cluster::{Deployment, NodeId};
use crate::dnn::{profile, ModelGraph};

use super::state::ResourceState;

/// Aggregate ingress bandwidth of the global parameter server (Mbps),
/// shared by all cluster heads synchronizing concurrently.
pub const GLOBAL_PS_BW_MBPS: f64 = 150.0;
/// Fraction of model parameters exchanged per iteration (gradient push +
/// parameter pull, fp32, no compression).
pub const SYNC_FRACTION: f64 = 2.0;

/// Compute seconds for one layer on its host node under current load.
pub fn layer_secs(state: &ResourceState, node: NodeId, cpu_demand: f64, flops_g: f64) -> f64 {
    let share = state.cpu_share(node, cpu_demand);
    profile::compute_secs(flops_g, share) * state.mem_pressure(node)
}

/// Slowest transfer between consecutive levels under current contention.
fn max_transfer_secs(
    dep: &Deployment,
    state: &ResourceState,
    graph: &ModelGraph,
    placement: &[NodeId],
) -> f64 {
    let mut worst = 0.0f64;
    for &(a, b) in &graph.edges {
        let (na, nb) = (placement[a], placement[b]);
        if na != nb {
            let nic = state.bw_share(na).min(state.bw_share(nb));
            worst = worst.max(dep.topo.transfer_secs(na, nb, graph.layers[a].out_mb, 1) / nic);
        }
    }
    worst
}

/// Steady-state per-iteration time: the pipeline bottleneck.
pub fn iteration_secs(
    dep: &Deployment,
    state: &ResourceState,
    graph: &ModelGraph,
    placement: &[NodeId],
    owner: NodeId,
    cluster_head: NodeId,
    n_clusters: usize,
) -> f64 {
    let mut bottleneck = 0.0f64;
    for layer in &graph.layers {
        let node = placement[layer.id];
        bottleneck = bottleneck.max(layer_secs(state, node, layer.demand().cpu, layer.flops_g));
    }
    bottleneck = bottleneck.max(max_transfer_secs(dep, state, graph, placement));
    bottleneck.max(sync_secs(dep, graph, owner, cluster_head, n_clusters))
}

/// One-time pipeline fill: the full sequential walk through the levels.
pub fn pipeline_fill_secs(
    dep: &Deployment,
    state: &ResourceState,
    graph: &ModelGraph,
    placement: &[NodeId],
) -> f64 {
    let mut total = 0.0f64;
    for (li, level) in graph.levels.iter().enumerate() {
        let mut t_level = 0.0f64;
        for &lid in level {
            let layer = &graph.layers[lid];
            t_level =
                t_level.max(layer_secs(state, placement[lid], layer.demand().cpu, layer.flops_g));
        }
        total += t_level;
        if li + 1 < graph.levels.len() {
            let mut t_xfer = 0.0f64;
            for &(a, b) in &graph.edges {
                if graph.layers[a].level == li && graph.layers[b].level == li + 1 {
                    let (na, nb) = (placement[a], placement[b]);
                    if na != nb {
                        let nic = state.bw_share(na).min(state.bw_share(nb));
                        t_xfer = t_xfer
                            .max(dep.topo.transfer_secs(na, nb, graph.layers[a].out_mb, 1) / nic);
                    }
                }
            }
            total += t_xfer;
        }
    }
    total
}

/// Parameter-synchronization seconds per iteration.
pub fn sync_secs(
    dep: &Deployment,
    graph: &ModelGraph,
    owner: NodeId,
    cluster_head: NodeId,
    n_clusters: usize,
) -> f64 {
    let mb = graph.param_mb() * SYNC_FRACTION;
    // Intra-cluster: owner replica <-> cluster head.
    let intra = dep.topo.transfer_secs(owner, cluster_head, mb, 1);
    // Inter-cluster: heads share the global PS ingress.
    let ps_bw = GLOBAL_PS_BW_MBPS / n_clusters.max(1) as f64;
    let inter = if n_clusters > 1 { mb * 8.0 / ps_bw } else { 0.0 };
    intra + inter
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, Resources, CONTAINER_PROFILE};
    use crate::dnn::ModelKind;
    use crate::util::Rng;

    fn dep(n: usize) -> Deployment {
        let mut rng = Rng::new(13);
        let mut d = Deployment::generate(&mut rng, n, 5, &CONTAINER_PROFILE);
        // These tests assert *relations of the timing model* (fill vs
        // bottleneck, contention, memory pressure), not link-lottery
        // outcomes: pin every node to a uniform fast base rate so a
        // low-bandwidth draw can never make parameter sync the
        // bottleneck and flip a compute-side inequality.
        d.topo.params = crate::net::LinkParams::uniform(n, 1000.0, 0.002);
        d.topo.rebuild_adjacency();
        d
    }

    fn all_on(node: NodeId, graph: &ModelGraph) -> Vec<NodeId> {
        vec![node; graph.n_layers()]
    }

    #[test]
    fn iteration_positive_and_scales_with_model() {
        let d = dep(5);
        let state = ResourceState::new(&d);
        let rnn = ModelKind::Rnn.build();
        let vgg = ModelKind::Vgg16.build();
        let head = d.clusters[0].head;
        let t_rnn = iteration_secs(&d, &state, &rnn, &all_on(0, &rnn), 0, head, 1);
        let t_vgg = iteration_secs(&d, &state, &vgg, &all_on(0, &vgg), 0, head, 1);
        assert!(t_rnn > 0.0);
        assert!(t_vgg > 3.0 * t_rnn, "vgg={t_vgg} rnn={t_rnn}");
    }

    #[test]
    fn fill_exceeds_bottleneck() {
        let d = dep(5);
        let state = ResourceState::new(&d);
        let g = ModelKind::Vgg16.build();
        let head = d.clusters[0].head;
        let fill = pipeline_fill_secs(&d, &state, &g, &all_on(0, &g));
        let iter = iteration_secs(&d, &state, &g, &all_on(0, &g), 0, head, 1);
        assert!(fill > iter, "fill={fill} iter={iter}");
    }

    #[test]
    fn contention_slows_iterations() {
        let d = dep(5);
        let mut state = ResourceState::new(&d);
        let g = ModelKind::Vgg16.build();
        let head = d.clusters[0].head;
        let t_idle = iteration_secs(&d, &state, &g, &all_on(1, &g), 1, head, 1);
        // Saturate node 1's CPU with background demand.
        let cap = state.caps(1).cpu;
        state.place(1, Resources::new(cap * 2.0, 10.0, 0.0), Resources::new(cap * 2.0, 10.0, 0.0), false);
        let t_loaded = iteration_secs(&d, &state, &g, &all_on(1, &g), 1, head, 1);
        assert!(t_loaded > 1.5 * t_idle, "idle={t_idle} loaded={t_loaded}");
    }

    #[test]
    fn memory_oversubscription_penalizes() {
        let d = dep(5);
        let mut state = ResourceState::new(&d);
        let g = ModelKind::Vgg16.build();
        let head = d.clusters[0].head;
        let t0 = iteration_secs(&d, &state, &g, &all_on(2, &g), 2, head, 1);
        let mem = state.caps(2).mem;
        state.place(2, Resources::new(0.0, mem * 1.5, 0.0), Resources::new(0.0, mem * 1.5, 0.0), false);
        let t1 = iteration_secs(&d, &state, &g, &all_on(2, &g), 2, head, 1);
        assert!(t1 > t0);
    }

    #[test]
    fn balanced_beats_piled_bottleneck() {
        // The core economic fact behind the paper: spreading stages over
        // the cluster beats piling them onto one node.
        let d = dep(5);
        let g = ModelKind::Vgg16.build();
        let head = d.clusters[0].head;
        let mut piled_state = ResourceState::new(&d);
        let piled: Vec<NodeId> = all_on(4, &g);
        for l in &g.layers {
            let dem = l.demand();
            piled_state.place(4, dem, dem, true);
        }
        let t_piled = iteration_secs(&d, &piled_state, &g, &piled, 0, head, 1);

        let mut spread_state = ResourceState::new(&d);
        let spread: Vec<NodeId> = (0..g.n_layers()).map(|i| i % 5).collect();
        for l in &g.layers {
            let dem = l.demand();
            spread_state.place(spread[l.id], dem, dem, true);
        }
        let t_spread = iteration_secs(&d, &spread_state, &g, &spread, 0, head, 1);
        assert!(t_piled > 1.3 * t_spread, "piled={t_piled} spread={t_spread}");
    }

    #[test]
    fn cross_node_placement_pays_transfers_in_fill() {
        let d = dep(5);
        let state = ResourceState::new(&d);
        let g = ModelKind::Rnn.build();
        let same = pipeline_fill_secs(&d, &state, &g, &all_on(0, &g));
        let alt: Vec<NodeId> = (0..g.n_layers()).map(|i| if i % 2 == 0 { 0 } else { 1 }).collect();
        let cross = pipeline_fill_secs(&d, &state, &g, &alt);
        assert!(cross > same, "cross={cross} same={same}");
    }

    #[test]
    fn sync_grows_with_cluster_count() {
        let d = dep(25);
        let g = ModelKind::GoogleNet.build();
        let head = d.clusters[0].head;
        let s1 = sync_secs(&d, &g, 0, head, 1);
        let s5 = sync_secs(&d, &g, 0, head, 5);
        assert!(s5 > s1, "s5={s5} s1={s1}");
    }

    #[test]
    fn sync_bounds_iteration_from_below() {
        let d = dep(25);
        let g = ModelKind::Vgg16.build();
        let state = ResourceState::new(&d);
        let head = d.clusters[0].head;
        let iter = iteration_secs(&d, &state, &g, &all_on(0, &g), 0, head, 5);
        let sync = sync_secs(&d, &g, 0, head, 5);
        assert!(iter >= sync);
    }
}
