//! Shared resource-state tracking: which demands are resident on which
//! node, and the utilization math every component (agents, shields,
//! execution engine) consults.
//!
//! Two demand ledgers per node are kept:
//!
//! * **estimated** — the profiled demands everyone *reasons* about
//!   (agents observe them, shields check them: "the shield observes
//!   whether the joint action actually changes the resource utilization
//!   ... to a value higher than the threshold");
//! * **actual** — the realized demands including the run-time noise the
//!   paper blames for residual collisions ("the resource demands of
//!   tasks are time-varying and dynamic and sometimes cannot be
//!   accurately predicted").

use crate::cluster::{Deployment, NodeId, ResourceKind, Resources};

/// Opaque handle for a resident task's demands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TaskHandle(pub usize);

#[derive(Debug, Clone)]
struct Resident {
    node: NodeId,
    est: Resources,
    actual: Resources,
    /// true for DL partitions, false for background jobs.
    is_dl: bool,
}

/// Live resource state over the nodes of a deployment — either all of
/// them (`new`, `base == 0`) or one cluster's contiguous id slice
/// (`for_cluster`), which is what lets the sharded tick engine give each
/// region lane its own O(cluster)-memory state instead of an O(n) clone.
/// All public APIs keep taking *global* `NodeId`s; the offset is an
/// internal storage detail.  Touching a node outside the tracked slice
/// panics (index out of bounds) — lanes own disjoint node ranges by
/// construction.
#[derive(Debug, Clone)]
pub struct ResourceState {
    /// First tracked node id (0 for whole-deployment states).
    base: usize,
    caps: Vec<Resources>,
    est: Vec<Resources>,
    actual: Vec<Resources>,
    dl_tasks: Vec<usize>,
    bg_tasks: Vec<usize>,
    residents: Vec<Option<Resident>>,
}

impl ResourceState {
    pub fn new(dep: &Deployment) -> ResourceState {
        let n = dep.n();
        ResourceState {
            base: 0,
            caps: dep.nodes.iter().map(|d| d.caps).collect(),
            est: vec![Resources::default(); n],
            actual: vec![Resources::default(); n],
            dl_tasks: vec![0; n],
            bg_tasks: vec![0; n],
            residents: Vec::new(),
        }
    }

    /// State over one cluster's member span only (`min..=max` of
    /// `members`): O(cluster) memory, global-`NodeId` API.
    pub fn for_cluster(dep: &Deployment, members: &[NodeId]) -> ResourceState {
        let base = members.iter().copied().min().unwrap_or(0);
        let end = members.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        let n = end - base;
        ResourceState {
            base,
            caps: dep.nodes[base..end].iter().map(|d| d.caps).collect(),
            est: vec![Resources::default(); n],
            actual: vec![Resources::default(); n],
            dl_tasks: vec![0; n],
            bg_tasks: vec![0; n],
            residents: Vec::new(),
        }
    }

    /// Number of tracked nodes (the whole deployment for `new`).
    pub fn n(&self) -> usize {
        self.caps.len()
    }

    /// First tracked node id (0 unless built with `for_cluster`).
    pub fn base(&self) -> usize {
        self.base
    }

    /// The tracked global node ids, ascending.
    pub fn node_ids(&self) -> std::ops::Range<usize> {
        self.base..self.base + self.caps.len()
    }

    #[inline]
    fn ix(&self, node: NodeId) -> usize {
        node - self.base
    }

    #[inline]
    pub fn caps(&self, node: NodeId) -> &Resources {
        &self.caps[self.ix(node)]
    }

    /// Place a task; returns a handle for later release.
    pub fn place(&mut self, node: NodeId, est: Resources, actual: Resources, is_dl: bool) -> TaskHandle {
        let i = self.ix(node);
        self.est[i] = self.est[i].add(&est);
        self.actual[i] = self.actual[i].add(&actual);
        if is_dl {
            self.dl_tasks[i] += 1;
        } else {
            self.bg_tasks[i] += 1;
        }
        self.residents.push(Some(Resident { node, est, actual, is_dl }));
        TaskHandle(self.residents.len() - 1)
    }

    /// Release a previously placed task.
    pub fn release(&mut self, h: TaskHandle) {
        let r = self.residents[h.0].take().expect("double release");
        let i = self.ix(r.node);
        self.est[i] = self.est[i].sub(&r.est);
        self.actual[i] = self.actual[i].sub(&r.actual);
        if r.is_dl {
            self.dl_tasks[i] -= 1;
        } else {
            self.bg_tasks[i] -= 1;
        }
    }

    /// Estimated utilization of one resource (Eq. 1) including an
    /// hypothetical extra demand.
    #[inline]
    pub fn util_with(&self, node: NodeId, extra: &Resources, k: ResourceKind) -> f64 {
        let i = self.ix(node);
        self.caps[i].utilization(&self.est[i].add(extra), k)
    }

    /// Estimated utilization of one resource (Eq. 1).
    #[inline]
    pub fn util(&self, node: NodeId, k: ResourceKind) -> f64 {
        let i = self.ix(node);
        self.caps[i].utilization(&self.est[i], k)
    }

    /// Actual (noisy) utilization of one resource.
    pub fn actual_util(&self, node: NodeId, k: ResourceKind) -> f64 {
        let i = self.ix(node);
        self.caps[i].utilization(&self.actual[i], k)
    }

    /// Combined estimated utilization (Eq. 2).
    pub fn combined_util(&self, node: NodeId) -> f64 {
        let i = self.ix(node);
        self.caps[i].combined_utilization(&self.est[i])
    }

    /// Whether any resource exceeds `alpha` on `node` (estimates).
    pub fn overloaded(&self, node: NodeId, alpha: f64) -> bool {
        ResourceKind::ALL.iter().any(|&k| self.util(node, k) > alpha)
    }

    /// Whether any resource exceeds `alpha` on `node` (actuals).
    pub fn actual_overloaded(&self, node: NodeId, alpha: f64) -> bool {
        ResourceKind::ALL.iter().any(|&k| self.actual_util(node, k) > alpha)
    }

    /// Estimated resident demand.
    #[inline]
    pub fn demand(&self, node: NodeId) -> &Resources {
        &self.est[self.ix(node)]
    }

    /// Actual resident demand.
    pub fn actual_demand(&self, node: NodeId) -> &Resources {
        &self.actual[self.ix(node)]
    }

    /// Number of resident DL partitions on `node`.
    pub fn dl_task_count(&self, node: NodeId) -> usize {
        self.dl_tasks[self.ix(node)]
    }

    /// Number of resident tasks (DL + background) on `node`.
    pub fn task_count(&self, node: NodeId) -> usize {
        let i = self.ix(node);
        self.dl_tasks[i] + self.bg_tasks[i]
    }

    /// CPU share actually granted to a task demanding `cpu_demand` on
    /// `node`: work-conserving proportional processor sharing — the whole
    /// capacity is divided among resident tasks in proportion to their
    /// demands, so a task alone on an idle node runs at full node speed
    /// and tasks on a piled-up node slow down proportionally.  This is
    /// what makes balanced schedules (the shield's goal) faster.
    #[inline]
    pub fn cpu_share(&self, node: NodeId, cpu_demand: f64) -> f64 {
        let i = self.ix(node);
        let total = self.actual[i].cpu;
        let cap = self.caps[i].cpu;
        cap * cpu_demand / total.max(cpu_demand).max(1e-9)
    }

    /// Memory pressure factor: 1.0 when resident memory fits, growing
    /// steeply with oversubscription (swap-thrashing model: every page of
    /// working set beyond RAM costs orders of magnitude more).
    #[inline]
    pub fn mem_pressure(&self, node: NodeId) -> f64 {
        let u = self.actual_util(node, ResourceKind::Mem);
        if u <= 1.0 {
            1.0
        } else {
            1.0 + 2.0 * (u - 1.0)
        }
    }

    /// Bandwidth contention factor in (0, 1]: fraction of a link's rate a
    /// flow through `node` actually achieves when the node's aggregate
    /// bandwidth demand exceeds its NIC capacity.
    #[inline]
    pub fn bw_share(&self, node: NodeId) -> f64 {
        let i = self.ix(node);
        let total = self.actual[i].bw;
        let cap = self.caps[i].bw;
        if total <= cap {
            1.0
        } else {
            cap / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, CONTAINER_PROFILE};
    use crate::util::Rng;

    fn state() -> ResourceState {
        let mut rng = Rng::new(1);
        ResourceState::new(&Deployment::generate(&mut rng, 10, 5, &CONTAINER_PROFILE))
    }

    fn r(cpu: f64, mem: f64, bw: f64) -> Resources {
        Resources { cpu, mem, bw }
    }

    #[test]
    fn place_and_release_roundtrip() {
        let mut s = state();
        let before = *s.demand(3);
        let h = s.place(3, r(0.2, 100.0, 5.0), r(0.25, 110.0, 5.0), true);
        assert_eq!(s.dl_task_count(3), 1);
        assert!(s.demand(3).cpu > before.cpu);
        s.release(h);
        assert_eq!(s.dl_task_count(3), 0);
        assert_eq!(s.demand(3).cpu, before.cpu);
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut s = state();
        let h = s.place(0, r(0.1, 10.0, 1.0), r(0.1, 10.0, 1.0), true);
        s.release(h);
        s.release(h);
    }

    #[test]
    fn overload_detection_uses_alpha() {
        let mut s = state();
        let cap = s.caps(0).cpu;
        s.place(0, r(cap * 0.85, 10.0, 1.0), r(cap * 0.85, 10.0, 1.0), true);
        assert!(!s.overloaded(0, 0.9));
        s.place(0, r(cap * 0.10, 10.0, 1.0), r(cap * 0.10, 10.0, 1.0), true);
        assert!(s.overloaded(0, 0.9));
    }

    #[test]
    fn estimates_and_actuals_tracked_separately() {
        let mut s = state();
        s.place(1, r(0.3, 50.0, 2.0), r(0.45, 80.0, 2.0), true);
        assert!(s.actual_util(1, ResourceKind::Cpu) > s.util(1, ResourceKind::Cpu));
    }

    #[test]
    fn processor_sharing_when_oversubscribed() {
        let mut s = state();
        let cap = s.caps(2).cpu;
        // Two tasks each demanding the full capacity: each gets half.
        s.place(2, r(cap, 1.0, 0.0), r(cap, 1.0, 0.0), true);
        s.place(2, r(cap, 1.0, 0.0), r(cap, 1.0, 0.0), true);
        let share = s.cpu_share(2, cap);
        assert!((share - cap / 2.0).abs() < 1e-12);
    }

    #[test]
    fn lone_task_gets_full_node() {
        // Work-conserving: a task alone on the node runs at node speed.
        let mut s = state();
        s.place(2, r(0.1, 1.0, 0.0), r(0.1, 1.0, 0.0), true);
        let cap = s.caps(2).cpu;
        assert!((s.cpu_share(2, 0.1) - cap).abs() < 1e-12);
    }

    #[test]
    fn share_proportional_to_demand() {
        let mut s = state();
        let cap = s.caps(3).cpu;
        s.place(3, r(0.3, 1.0, 0.0), r(0.3, 1.0, 0.0), true);
        s.place(3, r(0.1, 1.0, 0.0), r(0.1, 1.0, 0.0), true);
        let big = s.cpu_share(3, 0.3);
        let small = s.cpu_share(3, 0.1);
        assert!((big / small - 3.0).abs() < 1e-9);
        assert!((big + small - cap).abs() < 1e-9);
    }

    #[test]
    fn mem_pressure_kicks_in_past_capacity() {
        let mut s = state();
        let mem = s.caps(4).mem;
        s.place(4, r(0.1, mem * 0.5, 0.0), r(0.1, mem * 0.5, 0.0), true);
        assert_eq!(s.mem_pressure(4), 1.0);
        s.place(4, r(0.1, mem * 0.75, 0.0), r(0.1, mem * 0.75, 0.0), true);
        assert!(s.mem_pressure(4) > 1.0);
    }

    #[test]
    fn cluster_slice_state_matches_full_state() {
        // A `for_cluster` state over cluster 1 (nodes 5..10 of a 10-node,
        // 2-cluster deployment) must answer every query exactly like the
        // whole-deployment state under the same placement sequence.
        let mut rng = Rng::new(1);
        let dep = Deployment::generate(&mut rng, 10, 5, &CONTAINER_PROFILE);
        let members = dep.clusters[1].members.clone();
        let mut full = ResourceState::new(&dep);
        let mut slice = ResourceState::for_cluster(&dep, &members);
        assert_eq!(slice.base(), 5);
        assert_eq!(slice.n(), 5);
        assert_eq!(slice.node_ids().collect::<Vec<_>>(), members);
        let mut handles = Vec::new();
        for (i, &node) in members.iter().enumerate() {
            let est = r(0.1 * (i + 1) as f64, 20.0 * (i + 1) as f64, 2.0);
            let actual = r(0.12 * (i + 1) as f64, 22.0 * (i + 1) as f64, 2.0);
            let hf = full.place(node, est, actual, i % 2 == 0);
            let hs = slice.place(node, est, actual, i % 2 == 0);
            handles.push((hf, hs));
        }
        for &node in &members {
            assert_eq!(slice.caps(node), full.caps(node));
            assert_eq!(slice.demand(node), full.demand(node));
            assert_eq!(slice.actual_demand(node), full.actual_demand(node));
            assert_eq!(slice.task_count(node), full.task_count(node));
            assert_eq!(slice.dl_task_count(node), full.dl_task_count(node));
            for k in ResourceKind::ALL {
                assert_eq!(slice.util(node, k), full.util(node, k));
                assert_eq!(slice.actual_util(node, k), full.actual_util(node, k));
            }
            assert_eq!(slice.combined_util(node), full.combined_util(node));
            assert_eq!(slice.overloaded(node, 0.5), full.overloaded(node, 0.5));
            assert_eq!(slice.cpu_share(node, 0.2), full.cpu_share(node, 0.2));
            assert_eq!(slice.mem_pressure(node), full.mem_pressure(node));
            assert_eq!(slice.bw_share(node), full.bw_share(node));
        }
        let (hf, hs) = handles[2];
        full.release(hf);
        slice.release(hs);
        assert_eq!(slice.demand(members[2]), full.demand(members[2]));
    }

    #[test]
    fn util_with_is_hypothetical() {
        let s = state();
        let extra = r(0.5, 0.0, 0.0);
        let u = s.util_with(0, &extra, ResourceKind::Cpu);
        assert!(u > 0.0);
        // State unchanged.
        assert_eq!(s.util(0, ResourceKind::Cpu), 0.0);
    }
}
