//! `figures` — regenerates every figure of the paper's evaluation
//! (Figures 4–13) as console tables.
//!
//! Usage: `figures <fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|fig13|all>`
//!        `[--reps N] [--seed S] [--iterations N] [--models vgg16,googlenet,rnn]`
//!
//! Absolute numbers live on this simulated testbed, not the authors' EC2
//! cluster; the *shape* (who wins, by what factor, trends along the
//! sweeps) is the reproduction target — see EXPERIMENTS.md.

use srole::config::ExperimentConfig;
use srole::coordinator::{Experiment, Method};
use srole::dnn::ModelKind;
use srole::util::cli::{Cli, CliError};
use srole::util::table::{f, Table};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("figures", "regenerate the paper's figures")
        .opt("reps", Some("3"), "repetitions per configuration")
        .opt("seed", Some("1"), "base seed")
        .opt("iterations", Some("50"), "training iterations per job")
        .opt("models", Some("vgg16,googlenet,rnn"), "comma-separated models");
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(CliError::Help) => {
            print!("{}", cli.usage());
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let which = args.positional.first().cloned().unwrap_or_else(|| "all".to_string());
    let ctx = Ctx {
        reps: args.usize("reps").unwrap_or(3),
        seed: args.u64("seed").unwrap_or(1),
        iterations: args.usize("iterations").unwrap_or(50),
        models: args
            .get("models")
            .unwrap()
            .split(',')
            .map(|m| ModelKind::parse(m).unwrap_or_else(|| panic!("unknown model {m}")))
            .collect(),
    };

    let all = which == "all";
    let mut matched = false;
    if all || which == "fig4" {
        matched = true;
        fig4_jct_vs_edges(&ctx);
    }
    if all || which == "fig5" {
        matched = true;
        fig5_tasks_vs_workload(&ctx);
    }
    if all || which == "fig6" {
        matched = true;
        utilization_figure(&ctx, false, "Fig 6");
    }
    if all || which == "fig7" {
        matched = true;
        overhead_figure(&ctx, false, "Fig 7");
    }
    if all || which == "fig8" {
        matched = true;
        collisions_figure(&ctx, false, "Fig 8");
    }
    if all || which == "fig9" {
        matched = true;
        fig9_jct_real(&ctx);
    }
    if all || which == "fig10" {
        matched = true;
        fig10_tasks_real(&ctx);
    }
    if all || which == "fig11" {
        matched = true;
        utilization_figure(&ctx, true, "Fig 11");
    }
    if all || which == "fig12" {
        matched = true;
        overhead_figure(&ctx, true, "Fig 12");
    }
    if all || which == "fig13" {
        matched = true;
        collisions_figure(&ctx, true, "Fig 13");
    }
    if !matched {
        eprintln!("unknown figure {which}; use fig4..fig13 or all");
        std::process::exit(2);
    }
}

struct Ctx {
    reps: usize,
    seed: u64,
    iterations: usize,
    models: Vec<ModelKind>,
}

impl Ctx {
    fn base(&self, model: ModelKind) -> ExperimentConfig {
        ExperimentConfig {
            model,
            seed: self.seed,
            repetitions: self.reps,
            iterations: self.iterations,
            ..Default::default()
        }
    }

    fn real(&self, model: ModelKind) -> ExperimentConfig {
        ExperimentConfig {
            model,
            seed: self.seed,
            repetitions: self.reps,
            iterations: self.iterations,
            ..ExperimentConfig::real_device()
        }
    }
}

/// Fig 4a–c: job completion time vs number of edges (emulation).
fn fig4_jct_vs_edges(ctx: &Ctx) {
    for model in &ctx.models {
        let mut t = Table::new(
            &format!("Fig 4 ({}): JCT median [s] vs #edges", model.name()),
            &["edges", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        for edges in [5usize, 10, 15, 20, 25] {
            let mut cfg = ctx.base(*model);
            cfg.n_edges = edges;
            let exp = Experiment::new(cfg);
            let mut row = vec![edges.to_string()];
            for m in Method::ALL {
                row.push(f(exp.run(m).metrics.jct_summary().median));
            }
            t.row(row);
        }
        t.print();
    }
}

/// Fig 5a–c: tasks per device vs workload (emulation, 25 edges).
fn fig5_tasks_vs_workload(ctx: &Ctx) {
    for model in &ctx.models {
        let mut t = Table::new(
            &format!("Fig 5 ({}): tasks/device median (min..max) vs workload", model.name()),
            &["workload", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        for w in [0.6, 0.7, 0.8, 0.9, 1.0] {
            let mut cfg = ctx.base(*model);
            cfg.workload = w;
            let exp = Experiment::new(cfg);
            let mut row = vec![format!("{:.0}%", w * 100.0)];
            for m in Method::ALL {
                let r = exp.run(m);
                match r.metrics.tasks_summary() {
                    Some(s) => row.push(format!("{:.1} ({:.0}..{:.0})", s.median, s.min, s.max)),
                    None => row.push("-".into()),
                }
            }
            t.row(row);
        }
        t.print();
    }
}

/// Fig 6/11: per-resource utilization.
fn utilization_figure(ctx: &Ctx, real: bool, fig: &str) {
    for model in &ctx.models {
        let cfg = if real { ctx.real(*model) } else { ctx.base(*model) };
        let exp = Experiment::new(cfg);
        let mut t = Table::new(
            &format!("{fig} ({}): utilization median (min..max) per resource", model.name()),
            &["resource", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        let results: Vec<_> = Method::ALL.iter().map(|&m| exp.run(m)).collect();
        for res in ["cpu", "mem", "bw"] {
            let mut row = vec![res.to_string()];
            for r in &results {
                match r.metrics.util_summary(res) {
                    Some(s) => row.push(format!("{:.2} ({:.2}..{:.2})", s.median, s.min, s.max)),
                    None => row.push("-".into()),
                }
            }
            t.row(row);
        }
        t.print();
    }
}

/// Fig 7/12: computation overhead split into scheduling + shielding.
fn overhead_figure(ctx: &Ctx, real: bool, fig: &str) {
    for model in &ctx.models {
        let cfg = if real { ctx.real(*model) } else { ctx.base(*model) };
        let exp = Experiment::new(cfg);
        let mut t = Table::new(
            &format!("{fig} ({}): per-job overhead [s]", model.name()),
            &["component", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        let results: Vec<_> = Method::ALL.iter().map(|&m| exp.run(m)).collect();
        let mut sched = vec!["scheduling".to_string()];
        let mut shield = vec!["shielding".to_string()];
        let mut total = vec!["total".to_string()];
        for r in &results {
            // Scheduling bar = decision latency minus shielding (for
            // centralized RL this includes queueing at the head).
            sched.push(format!(
                "{:.3}",
                r.metrics.mean_decision_secs() - r.metrics.mean_shield_secs()
            ));
            shield.push(format!("{:.3}", r.metrics.mean_shield_secs()));
            total.push(format!("{:.3}", r.metrics.mean_overhead_secs()));
        }
        t.row(sched);
        t.row(shield);
        t.row(total);
        t.print();
    }
}

/// Fig 8/13: action collisions vs the κ penalty.
fn collisions_figure(ctx: &Ctx, real: bool, fig: &str) {
    for model in &ctx.models {
        let mut t = Table::new(
            &format!("{fig} ({}): action collisions vs κ", model.name()),
            &["kappa", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        for kappa in [25.0, 50.0, 100.0, 200.0] {
            let mut cfg = if real { ctx.real(*model) } else { ctx.base(*model) };
            cfg.reward.kappa = kappa;
            let exp = Experiment::new(cfg);
            let mut row = vec![format!("{kappa:.0}")];
            for m in Method::ALL {
                row.push(exp.run(m).metrics.collisions.to_string());
            }
            t.row(row);
        }
        t.print();
    }
}

/// Fig 9: JCT on the real-device testbed (10 Pis, one cluster).
fn fig9_jct_real(ctx: &Ctx) {
    let mut t = Table::new(
        "Fig 9: JCT median [s], real-device testbed",
        &["model", "RL", "MARL", "SROLE-C", "SROLE-D"],
    );
    for model in &ctx.models {
        let exp = Experiment::new(ctx.real(*model));
        let mut row = vec![model.name().to_string()];
        for m in Method::ALL {
            row.push(f(exp.run(m).metrics.jct_summary().median));
        }
        t.row(row);
    }
    t.print();
}

/// Fig 10: tasks per device, real-device testbed.
fn fig10_tasks_real(ctx: &Ctx) {
    let mut t = Table::new(
        "Fig 10: tasks/device median (min..max), real-device testbed",
        &["model", "RL", "MARL", "SROLE-C", "SROLE-D"],
    );
    for model in &ctx.models {
        let exp = Experiment::new(ctx.real(*model));
        let mut row = vec![model.name().to_string()];
        for m in Method::ALL {
            let r = exp.run(m);
            match r.metrics.tasks_summary() {
                Some(s) => row.push(format!("{:.1} ({:.0}..{:.0})", s.median, s.min, s.max)),
                None => row.push("-".into()),
            }
        }
        t.row(row);
    }
    t.print();
}
