//! `figures` — regenerates every figure of the paper's evaluation
//! (Figures 4–13) as console tables, running the evaluation grid through
//! the parallel scenario harness (`srole::harness`): every
//! `(method × configuration)` cell is an independent, deterministic
//! scenario, executed across OS threads.
//!
//! Usage: `figures <fig4|fig5|...|fig13|scale|churn|mobility|profile|serve|all>`
//!        `[--reps N] [--seed S] [--iterations N] [--threads T]`
//!        `[--models vgg16,googlenet,rnn] [--edges 5,10,15,20,25]`
//!        `[--pretrain N] [--trace PATH]`
//!
//! `figures scale` sweeps 10→300,000-node deployments concurrently (the
//! shield-tree tick-engine scale ceiling; `--edges` overrides the
//! sweep points, so CI smokes just the 300,000-node cell; node density
//! is held constant past 256 nodes and cells of ≥30,000 nodes shard
//! their lanes across every core); `figures churn` sweeps node-failure
//! rates on a 100-node cluster through the dynamic event-driven driver;
//! `figures
//! mobility` sweeps a random-waypoint speed × pause grid (plus a
//! stationary-trace baseline and a square trace patrol) on a 50-node
//! cluster, reporting shield-region handoffs and layer migrations;
//! `figures profile` runs one traced sharded SROLE-D cell (10 000 nodes
//! by default) and prints the per-phase per-lane wall-clock attribution
//! table plus sampled-series percentiles — `--trace PATH` additionally
//! writes the JSONL event trace and its Chrome `trace_event` twin;
//! `figures serve` sweeps the inference-serving workload over a
//! rate-shape × SLO grid (latency p50/p99/p999, SLO violations,
//! admission rejections; `--edges` picks the deployment size, cells of
//! ≥1000 nodes shard their lanes) and writes `BENCH_serving.json`;
//! `--edges` reshapes the
//! Fig 4 sweep the same way.  Absolute numbers live on this simulated
//! testbed, not the authors' EC2 cluster; the *shape* (who wins, by what
//! factor, trends along the sweeps) is the reproduction target.

use srole::config::ExperimentConfig;
use srole::coordinator::Method;
use srole::dnn::ModelKind;
use srole::harness::{run_parallel, write_bench_json, Scenario, ScenarioReport, Sweep};
use srole::util::cli::{Cli, CliError};
use srole::util::table::{f, Table};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::new("figures", "regenerate the paper's figures")
        .opt("reps", Some("3"), "repetitions per configuration")
        .opt("seed", Some("1"), "base seed")
        .opt("iterations", Some("50"), "training iterations per job")
        .opt("threads", Some("0"), "worker threads (0 = all cores)")
        .opt("models", Some("vgg16,googlenet,rnn"), "comma-separated models")
        .opt("edges", Some("5,10,15,20,25"), "comma-separated cluster sizes (fig4; overrides the scale sweep)")
        .opt("pretrain", Some("300"), "offline pre-training episodes per scenario")
        .opt("trace", None, "profile: write the JSONL event trace here (arms full mode)");
    let args = match cli.parse(&argv) {
        Ok(a) => a,
        Err(CliError::Help) => {
            print!("{}", cli.usage());
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let which = args.positional.first().cloned().unwrap_or_else(|| "all".to_string());
    let edges_explicit = argv.iter().any(|a| a == "--edges" || a.starts_with("--edges="));
    let ctx = Ctx {
        edges_explicit,
        reps: args.usize("reps").unwrap_or(3),
        seed: args.u64("seed").unwrap_or(1),
        iterations: args.usize("iterations").unwrap_or(50),
        threads: args.usize("threads").unwrap_or(0),
        pretrain: args.usize("pretrain").unwrap_or(300),
        models: args
            .get("models")
            .unwrap()
            .split(',')
            .map(|m| ModelKind::parse(m).unwrap_or_else(|| panic!("unknown model {m}")))
            .collect(),
        edges: args
            .get("edges")
            .unwrap()
            .split(',')
            .map(|e| e.trim().parse().unwrap_or_else(|_| panic!("bad edge count {e}")))
            .collect(),
        trace: args.get("trace").map(std::path::PathBuf::from),
    };

    let all = which == "all";
    let mut matched = false;
    if all || which == "fig4" {
        matched = true;
        fig4_jct_vs_edges(&ctx);
    }
    if all || which == "fig5" {
        matched = true;
        fig5_tasks_vs_workload(&ctx);
    }
    if all || which == "fig6" {
        matched = true;
        utilization_figure(&ctx, false, "Fig 6");
    }
    if all || which == "fig7" {
        matched = true;
        overhead_figure(&ctx, false, "Fig 7");
    }
    if all || which == "fig8" {
        matched = true;
        collisions_figure(&ctx, false, "Fig 8");
    }
    if all || which == "fig9" {
        matched = true;
        fig9_jct_real(&ctx);
    }
    if all || which == "fig10" {
        matched = true;
        fig10_tasks_real(&ctx);
    }
    if all || which == "fig11" {
        matched = true;
        utilization_figure(&ctx, true, "Fig 11");
    }
    if all || which == "fig12" {
        matched = true;
        overhead_figure(&ctx, true, "Fig 12");
    }
    if all || which == "fig13" {
        matched = true;
        collisions_figure(&ctx, true, "Fig 13");
    }
    if which == "scale" {
        matched = true;
        scale_sweep(&ctx);
    }
    if which == "churn" {
        matched = true;
        churn_figure(&ctx);
    }
    if which == "mobility" {
        matched = true;
        mobility_figure(&ctx);
    }
    if which == "profile" {
        matched = true;
        profile_figure(&ctx);
    }
    if which == "serve" {
        matched = true;
        serve_figure(&ctx);
    }
    if !matched {
        eprintln!(
            "unknown figure {which}; use fig4..fig13, scale, churn, mobility, profile, \
             serve, or all"
        );
        std::process::exit(2);
    }
}

struct Ctx {
    reps: usize,
    seed: u64,
    iterations: usize,
    threads: usize,
    pretrain: usize,
    models: Vec<ModelKind>,
    edges: Vec<usize>,
    /// Whether `--edges` was passed on the command line (the scale sweep
    /// keeps its own 10→1000 default otherwise).
    edges_explicit: bool,
    /// `figures profile`: write the JSONL event trace here (arms the
    /// full trace mode instead of profile-only).
    trace: Option<std::path::PathBuf>,
}

impl Ctx {
    fn base(&self, model: ModelKind) -> ExperimentConfig {
        ExperimentConfig {
            model,
            seed: self.seed,
            repetitions: self.reps,
            iterations: self.iterations,
            pretrain_episodes: self.pretrain,
            ..Default::default()
        }
    }

    fn real(&self, model: ModelKind) -> ExperimentConfig {
        ExperimentConfig {
            model,
            seed: self.seed,
            repetitions: self.reps,
            iterations: self.iterations,
            pretrain_episodes: self.pretrain,
            ..ExperimentConfig::real_device()
        }
    }

    /// Run one sweep through the parallel harness.
    fn run(&self, sweep: &Sweep) -> Vec<ScenarioReport> {
        run_parallel(&sweep.scenarios(), self.threads)
    }

    /// Base config for a multi-model sweep (the sweep's `models`
    /// dimension overrides the model per scenario).
    fn multi_base(&self) -> ExperimentConfig {
        self.base(*self.models.first().expect("at least one model"))
    }

    /// Split one multi-model sweep's reports into per-model slices
    /// (models are the outer dimension in `Sweep::scenarios`).
    fn per_model<'a>(
        &self,
        reports: &'a [ScenarioReport],
    ) -> impl Iterator<Item = (ModelKind, &'a [ScenarioReport])> {
        let chunk = reports.len() / self.models.len().max(1);
        self.models
            .clone()
            .into_iter()
            .zip(reports.chunks(chunk.max(1)))
    }
}

/// Fig 4a–c: job completion time vs number of edges (emulation).
/// One sweep covers every (model × edges × method) cell concurrently.
fn fig4_jct_vs_edges(ctx: &Ctx) {
    let sweep = Sweep::new(ctx.multi_base())
        .models(&ctx.models)
        .methods(&Method::ALL)
        .edges(&ctx.edges);
    let reports = ctx.run(&sweep);
    for (model, model_reports) in ctx.per_model(&reports) {
        let mut t = Table::new(
            &format!("Fig 4 ({}): JCT median [s] vs #edges", model.name()),
            &["edges", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        for (ei, row) in model_reports.chunks(Method::ALL.len()).enumerate() {
            let mut cells = vec![ctx.edges[ei].to_string()];
            for r in row {
                cells.push(f(r.metrics.jct_summary().median));
            }
            t.row(cells);
        }
        t.print();
    }
}

/// Fig 5a–c: tasks per device vs workload (emulation, 25 edges).
fn fig5_tasks_vs_workload(ctx: &Ctx) {
    let workloads = [0.6, 0.7, 0.8, 0.9, 1.0];
    let sweep = Sweep::new(ctx.multi_base())
        .models(&ctx.models)
        .methods(&Method::ALL)
        .workloads(&workloads);
    let reports = ctx.run(&sweep);
    for (model, model_reports) in ctx.per_model(&reports) {
        let mut t = Table::new(
            &format!("Fig 5 ({}): tasks/device median (min..max) vs workload", model.name()),
            &["workload", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        for (wi, row) in model_reports.chunks(Method::ALL.len()).enumerate() {
            let mut cells = vec![format!("{:.0}%", workloads[wi] * 100.0)];
            for r in row {
                match r.metrics.tasks_summary() {
                    Some(s) => cells.push(format!("{:.1} ({:.0}..{:.0})", s.median, s.min, s.max)),
                    None => cells.push("-".into()),
                }
            }
            t.row(cells);
        }
        t.print();
    }
}

/// Fig 6/11: per-resource utilization.
fn utilization_figure(ctx: &Ctx, real: bool, fig: &str) {
    let base = if real { ctx.real(ctx.models[0]) } else { ctx.multi_base() };
    let reports = ctx.run(&Sweep::new(base).models(&ctx.models).methods(&Method::ALL));
    for (model, model_reports) in ctx.per_model(&reports) {
        let mut t = Table::new(
            &format!("{fig} ({}): utilization median (min..max) per resource", model.name()),
            &["resource", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        for res in ["cpu", "mem", "bw"] {
            let mut cells = vec![res.to_string()];
            for r in model_reports {
                match r.metrics.util_summary(res) {
                    Some(s) => cells.push(format!("{:.2} ({:.2}..{:.2})", s.median, s.min, s.max)),
                    None => cells.push("-".into()),
                }
            }
            t.row(cells);
        }
        t.print();
    }
}

/// Fig 7/12: computation overhead split into scheduling + shielding.
fn overhead_figure(ctx: &Ctx, real: bool, fig: &str) {
    let base = if real { ctx.real(ctx.models[0]) } else { ctx.multi_base() };
    let all = ctx.run(&Sweep::new(base).models(&ctx.models).methods(&Method::ALL));
    for (model, reports) in ctx.per_model(&all) {
        let mut t = Table::new(
            &format!("{fig} ({}): per-job overhead [s]", model.name()),
            &["component", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        let mut sched = vec!["scheduling".to_string()];
        let mut shield = vec!["shielding".to_string()];
        let mut total = vec!["total".to_string()];
        for r in reports {
            // Scheduling bar = decision latency minus shielding (for
            // centralized RL this includes queueing at the head).
            sched.push(format!(
                "{:.3}",
                r.metrics.mean_decision_secs() - r.metrics.mean_shield_secs()
            ));
            shield.push(format!("{:.3}", r.metrics.mean_shield_secs()));
            total.push(format!("{:.3}", r.metrics.mean_overhead_secs()));
        }
        t.row(sched);
        t.row(shield);
        t.row(total);
        t.print();
    }
}

/// Fig 8/13: action collisions vs the κ penalty.
fn collisions_figure(ctx: &Ctx, real: bool, fig: &str) {
    let kappas = [25.0, 50.0, 100.0, 200.0];
    let base = if real { ctx.real(ctx.models[0]) } else { ctx.multi_base() };
    let reports = ctx.run(
        &Sweep::new(base).models(&ctx.models).methods(&Method::ALL).kappas(&kappas),
    );
    for (model, model_reports) in ctx.per_model(&reports) {
        let mut t = Table::new(
            &format!("{fig} ({}): action collisions vs κ", model.name()),
            &["kappa", "RL", "MARL", "SROLE-C", "SROLE-D"],
        );
        for (ki, row) in model_reports.chunks(Method::ALL.len()).enumerate() {
            let mut cells = vec![format!("{:.0}", kappas[ki])];
            for r in row {
                cells.push(r.metrics.collisions.to_string());
            }
            t.row(cells);
        }
        t.print();
    }
}

/// Fig 9: JCT on the real-device testbed (10 Pis, one cluster).
fn fig9_jct_real(ctx: &Ctx) {
    let reports = ctx
        .run(&Sweep::new(ctx.real(ctx.models[0])).models(&ctx.models).methods(&Method::ALL));
    let mut t = Table::new(
        "Fig 9: JCT median [s], real-device testbed",
        &["model", "RL", "MARL", "SROLE-C", "SROLE-D"],
    );
    for (model, model_reports) in ctx.per_model(&reports) {
        let mut cells = vec![model.name().to_string()];
        for r in model_reports {
            cells.push(f(r.metrics.jct_summary().median));
        }
        t.row(cells);
    }
    t.print();
}

/// Fig 10: tasks per device, real-device testbed.
fn fig10_tasks_real(ctx: &Ctx) {
    let reports = ctx
        .run(&Sweep::new(ctx.real(ctx.models[0])).models(&ctx.models).methods(&Method::ALL));
    let mut t = Table::new(
        "Fig 10: tasks/device median (min..max), real-device testbed",
        &["model", "RL", "MARL", "SROLE-C", "SROLE-D"],
    );
    for (model, model_reports) in ctx.per_model(&reports) {
        let mut cells = vec![model.name().to_string()];
        for r in model_reports {
            match r.metrics.tasks_summary() {
                Some(s) => cells.push(format!("{:.1} ({:.0}..{:.0})", s.median, s.min, s.max)),
                None => cells.push("-".into()),
            }
        }
        t.row(cells);
    }
    t.print();
}

/// Target mean node degree of the scale sweep's constant-density
/// geometry: each cluster's disc grows with √n so the grid adjacency —
/// and every O(n·k) structure keyed on it, including the sparse link
/// cache — stays genuinely sparse up to 100k nodes.
const SCALE_TARGET_DEGREE: f64 = 256.0;

/// Past this deployment size the scale sweep caps cluster size at
/// [`SCALE_CLUSTER_CAP`] (so one scenario holds many shield regions)
/// and shards its lanes across every core.
const SCALE_SHARD_THRESHOLD: usize = 30_000;
const SCALE_CLUSTER_CAP: usize = 1000;

/// Super-shield fanout for the sharded scale cells: groups of 8
/// clusters resolve their cross-region work group-locally, so the
/// 30k–300k epoch barriers parallelize (`coordinator::shard`,
/// byte-identical to the flat driver by the tree's pinning tests).
const SCALE_TREE_FANOUT: usize = 8;

/// `figures scale`: the ROADMAP scale sweep — 10→300 000-node
/// deployments, all methods, one concurrent harness run.  `--edges`
/// overrides the sweep points (CI smokes only the 300 000-node ceiling
/// cell).
fn scale_sweep(ctx: &Ctx) {
    let edges: Vec<usize> = if ctx.edges_explicit {
        ctx.edges.clone()
    } else {
        vec![10, 25, 50, 100, 300, 1000, 3000, 10_000, 30_000, 100_000, 300_000]
    };
    let model = ctx.models.first().copied().unwrap_or(ModelKind::Vgg16);
    let sweep = Sweep::new(ctx.base(model)).methods(&Method::ALL).edges(&edges);
    let mut scenarios = sweep.scenarios();
    // The point of this sweep is SHIELD-REGION scale, not tiling 5-node
    // clusters: grow one cluster (and its shield membership structures)
    // to the full node count, capped at SCALE_CLUSTER_CAP so the
    // 30k/100k cells become many-region deployments the sharded tick
    // engine can spread across cores (lane = cluster).  Density stays
    // constant: past ~SCALE_TARGET_DEGREE nodes the cluster disc grows
    // with √n, so adjacency degree — and the sparse link cache behind
    // it — stays ~flat instead of going complete-graph quadratic.
    for sc in &mut scenarios {
        sc.cfg.cluster_size = sc.cfg.n_edges.min(SCALE_CLUSTER_CAP);
        sc.cfg.subclusters = (sc.cfg.cluster_size / 10).max(2);
        if sc.cfg.n_edges >= SCALE_SHARD_THRESHOLD {
            sc.cfg.shards = srole::harness::default_threads();
            sc.cfg.tree_fanout = SCALE_TREE_FANOUT;
        }
        let profile = sc.cfg.profile.resource_profile();
        let spread =
            profile.range_m * (sc.cfg.cluster_size as f64 / SCALE_TARGET_DEGREE).sqrt();
        if spread > profile.cluster_spread_m {
            sc.cfg.cluster_spread_m = spread;
        }
    }
    let t0 = std::time::Instant::now();
    let reports = run_parallel(&scenarios, ctx.threads);
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(
        &format!("scale sweep ({}): JCT median [s] / collisions vs #edges", model.name()),
        &["edges", "RL", "MARL", "SROLE-C", "SROLE-D"],
    );
    for (ei, row) in reports.chunks(Method::ALL.len()).enumerate() {
        let mut cells = vec![edges[ei].to_string()];
        for r in row {
            cells.push(format!(
                "{} / {}",
                f(r.metrics.jct_summary().median),
                r.metrics.collisions
            ));
        }
        t.row(cells);
    }
    t.print();
    let busy: f64 = reports.iter().map(|r| r.wall_secs).sum();
    println!(
        "{} scenarios in {:.1}s wall ({:.1}s of scenario work, {:.1}x parallel speedup)",
        reports.len(),
        wall,
        busy,
        busy / wall.max(1e-9)
    );
    write_bench("scale", &reports);
}

/// `figures churn`: JCT / collisions vs node-failure rate on a 100-node
/// cluster, MARL vs SROLE-C vs SROLE-D, through the dynamic event-driven
/// driver (failed nodes rejoin after two minutes).
fn churn_figure(ctx: &Ctx) {
    const CHURN_METHODS: [Method; 3] = [Method::Marl, Method::SroleC, Method::SroleD];
    let rates = [0.0, 1.0, 2.0, 4.0];
    let model = ctx.models.first().copied().unwrap_or(ModelKind::Vgg16);
    let mut base = ctx.base(model);
    base.n_edges = 100;
    base.cluster_size = 100;
    base.subclusters = 10;
    base.rejoin_secs = 120.0;
    // The 0-failure baseline must run the same driver as the churn cells,
    // so the figure's trend isolates the failure rate.
    base.event_driven = true;
    let sweep = Sweep::new(base).methods(&CHURN_METHODS).failure_rates(&rates);
    let t0 = std::time::Instant::now();
    let reports = run_parallel(&sweep.scenarios(), ctx.threads);
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(
        &format!(
            "churn sweep ({}): JCT median [s] / collisions / failures vs failure rate",
            model.name()
        ),
        &["fail_per_1000s", "MARL", "SROLE-C", "SROLE-D"],
    );
    for (ri, row) in reports.chunks(CHURN_METHODS.len()).enumerate() {
        let mut cells = vec![format!("{:.1}", rates[ri])];
        for r in row {
            cells.push(format!(
                "{} / {} / {}",
                f(r.metrics.jct_summary().median),
                r.metrics.collisions,
                r.metrics.node_failures
            ));
        }
        t.row(cells);
    }
    t.print();
    println!("{} scenarios in {wall:.1}s wall", reports.len());
    write_bench("churn", &reports);
}

/// `figures mobility`: the node-mobility sweep — a random-waypoint
/// speed × pause grid (plus a stationary-trace baseline and a square
/// trace patrol) on a 50-node cluster, MARL vs SROLE-C vs SROLE-D,
/// through the dynamic event-driven driver.  Reports JCT alongside the
/// mobility-specific counters: shield-region handoffs (nodes crossing
/// sub-cluster boundaries while alive) and layer migrations (hosts
/// drifting out of their owner's transmission range).
fn mobility_figure(ctx: &Ctx) {
    use srole::net::MobilityModel;
    const MOB_METHODS: [Method; 3] = [Method::Marl, Method::SroleC, Method::SroleD];
    // Motion-free baseline: a *stationary* trace (one zero offset), not
    // `Static` — it runs the full mobility wrapper (same RNG fork, same
    // event cadence; link prices are always distance-attenuated now)
    // while never moving anyone, so the rows differ only in actual
    // motion.
    let mut grid: Vec<MobilityModel> =
        vec![MobilityModel::Trace { offsets: vec![(0.0, 0.0)], speed_mps: 1.0 }];
    for &speed in &[0.5, 1.0, 2.0] {
        for &pause in &[0.0, 30.0] {
            grid.push(MobilityModel::RandomWaypoint { speed_mps: speed, pause_secs: pause });
        }
    }
    grid.push(MobilityModel::default_trace());

    let model = ctx.models.first().copied().unwrap_or(ModelKind::Vgg16);
    let mut base = ctx.base(model);
    base.n_edges = 50;
    base.cluster_size = 25;
    base.subclusters = 4;
    let sweep = Sweep::new(base).methods(&MOB_METHODS).mobility(&grid);
    let t0 = std::time::Instant::now();
    let reports = run_parallel(&sweep.scenarios(), ctx.threads);
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(
        &format!(
            "mobility sweep ({}): JCT median [s] / region handoffs / migrated layers",
            model.name()
        ),
        &["mobility", "MARL", "SROLE-C", "SROLE-D"],
    );
    for (mi, row) in reports.chunks(MOB_METHODS.len()).enumerate() {
        let mut cells = vec![grid[mi].label()];
        for r in row {
            cells.push(format!(
                "{} / {} / {}",
                f(r.metrics.jct_summary().median),
                r.metrics.region_handoffs,
                r.metrics.migrated_layers
            ));
        }
        t.row(cells);
    }
    t.print();
    println!("{} scenarios in {wall:.1}s wall", reports.len());
    write_bench("mobility", &reports);
}

/// `figures profile`: one traced, sharded SROLE-D cell — 10 000 nodes
/// unless `--edges` overrides — printing the per-phase per-lane
/// wall-clock attribution table (driver row last) and percentiles of
/// every sampled series.  `--trace PATH` arms full trace mode and
/// writes the JSONL event trace plus its Chrome `trace_event` twin.
fn profile_figure(ctx: &Ctx) {
    use srole::obs::{ObsReport, Phase, Series, TraceMode};
    use srole::util::stats::Pcts;

    let model = ctx.models.first().copied().unwrap_or(ModelKind::Vgg16);
    let mut cfg = ctx.base(model);
    cfg.n_edges =
        if ctx.edges_explicit { *ctx.edges.first().expect("one edge count") } else { 10_000 };
    // Same shape rules as the scale sweep: big many-region clusters,
    // lanes sharded across every core.
    cfg.cluster_size = cfg.n_edges.min(SCALE_CLUSTER_CAP);
    cfg.subclusters = (cfg.cluster_size / 10).max(2);
    cfg.shards = srole::harness::default_threads();
    cfg.trace = if ctx.trace.is_some() { TraceMode::Full } else { TraceMode::Profile };
    let scenarios = vec![Scenario::new(Method::SroleD, cfg)];
    let reports = run_parallel(&scenarios, 1);
    let report = &reports[0];
    let obs = report.obs.as_ref().expect("traced run must carry an obs report");

    let mut header: Vec<String> = vec!["lane".into()];
    header.extend(Phase::ALL.iter().map(|p| p.name().to_string()));
    header.push("total_s".into());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("profile: per-phase wall-clock [s] — {}", report.scenario.label),
        &header_refs,
    );
    for (lane, prof) in &obs.lanes {
        let mut cells = vec![ObsReport::lane_label(*lane)];
        for p in Phase::ALL {
            cells.push(format!("{:.3}", prof.secs[p as usize]));
        }
        cells.push(format!("{:.3}", prof.total_secs()));
        t.row(cells);
    }
    t.print();

    let mut ps = Table::new(
        "profile: sampled series percentiles",
        &["series", "n", "p50", "p90", "p99", "p99.9"],
    );
    for s in Series::ALL {
        let vals: Vec<f64> = obs.series[s as usize].iter().map(|&(_, _, v)| v).collect();
        match Pcts::of(&vals) {
            Some(p) => ps.row(vec![
                s.name().to_string(),
                p.n.to_string(),
                f(p.p50),
                f(p.p90),
                f(p.p99),
                f(p.p999),
            ]),
            None => {
                let dash = || "-".to_string();
                ps.row(vec![s.name().to_string(), "0".into(), dash(), dash(), dash(), dash()])
            }
        };
    }
    ps.print();

    let total = obs.total_profile();
    println!(
        "{:.1}s wall, {:.1}s attributed across {} lanes, {} trace records ({} dropped)",
        report.wall_secs,
        total.total_secs(),
        obs.lanes.len(),
        obs.records.len(),
        obs.dropped
    );
    if let Some(path) = &ctx.trace {
        match obs.write_trace(path) {
            Ok(chrome) => {
                println!("trace: {} + {}", path.display(), chrome.display());
            }
            Err(e) => {
                eprintln!("could not write trace to {}: {e}", path.display());
                std::process::exit(1);
            }
        }
    }
}

/// `figures serve`: the inference-serving sweep — a rate-shape × SLO
/// grid (`workload = "serving"`), MARL vs SROLE-C vs SROLE-D, reporting
/// end-to-end request latency p50/p99/p999 alongside the SLO-violation
/// and admission-rejection counters.  `--edges` picks the deployment
/// size (default 50); cells of ≥1000 nodes take the scale sweep's
/// shape rules (capped cluster size, lanes sharded across every core),
/// so a sharded 10 000-node cell is one `--edges 10000` away.  The
/// sweep's wall-clock profile lands in `BENCH_serving.json`.
fn serve_figure(ctx: &Ctx) {
    use srole::workload::serving::RateShape;
    const SERVE_METHODS: [Method; 3] = [Method::Marl, Method::SroleC, Method::SroleD];
    const SHAPES: [RateShape; 3] =
        [RateShape::Constant, RateShape::Diurnal, RateShape::Bursty];
    const SLOS: [f64; 3] = [0.5, 2.0, 5.0];

    let model = ctx.models.first().copied().unwrap_or(ModelKind::Vgg16);
    let mut base = ctx.base(model);
    base.n_edges =
        if ctx.edges_explicit { *ctx.edges.first().expect("one edge count") } else { 50 };
    base.cluster_size = base.n_edges.min(SCALE_CLUSTER_CAP);
    base.subclusters = (base.cluster_size / 10).max(2);
    if base.n_edges >= 1000 {
        base.shards = srole::harness::default_threads();
    }
    base.serving = true;
    base.request_rate = 0.2;

    // The serving axes live outside `Sweep`'s dimensions: expand the
    // rate-shape × SLO grid directly, methods varying fastest so each
    // table row's cells are adjacent (the `Sweep` convention).
    let mut scenarios = Vec::new();
    for &shape in &SHAPES {
        for &slo in &SLOS {
            for &method in &SERVE_METHODS {
                let mut cfg = base.clone();
                cfg.rate_shape = shape;
                cfg.slo_secs = slo;
                scenarios.push(Scenario::new(method, cfg));
            }
        }
    }
    let t0 = std::time::Instant::now();
    let reports = run_parallel(&scenarios, ctx.threads);
    let wall = t0.elapsed().as_secs_f64();
    for (si, shape_rows) in reports.chunks(SLOS.len() * SERVE_METHODS.len()).enumerate() {
        let mut t = Table::new(
            &format!(
                "serving sweep ({}, {}): latency p50/p99/p999 [s] / SLO viol / rejected",
                model.name(),
                SHAPES[si].label()
            ),
            &["slo_s", "MARL", "SROLE-C", "SROLE-D"],
        );
        for (li, row) in shape_rows.chunks(SERVE_METHODS.len()).enumerate() {
            let mut cells = vec![format!("{:.1}", SLOS[li])];
            for r in row {
                match r.metrics.request_summary() {
                    Some(p) => cells.push(format!(
                        "{}/{}/{} / {} / {}",
                        f(p.p50),
                        f(p.p99),
                        f(p.p999),
                        r.metrics.slo_violations,
                        r.metrics.requests_rejected
                    )),
                    None => cells.push("-".into()),
                }
            }
            t.row(cells);
        }
        t.print();
    }
    let served: usize = reports.iter().map(|r| r.metrics.requests_served).sum();
    let rejected: usize = reports.iter().map(|r| r.metrics.requests_rejected).sum();
    println!(
        "{} scenarios in {wall:.1}s wall, {served} requests served, {rejected} rejected",
        reports.len()
    );
    write_bench("serving", &reports);
}

/// Persist a sweep's wall-clock profile as `BENCH_<name>.json` (perf
/// trajectory across PRs).
fn write_bench(name: &str, reports: &[ScenarioReport]) {
    match write_bench_json(name, reports, std::path::Path::new(".")) {
        Ok(path) => println!("bench report: {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_{name}.json: {e}"),
    }
}
