//! `srole` — CLI for the SROLE reproduction.
//!
//! Subcommands:
//! * `run` — run one experiment configuration for one/all methods and
//!   print the metric summaries (optionally `--json`).
//! * `emu` — live data-parallel training on the thread-based cluster
//!   emulation (real PJRT compute; prints the loss curve).
//! * `figures` — points at the `figures` binary regenerating Fig 4–13.

use srole::config::ExperimentConfig;
use srole::coordinator::Method;
use srole::harness::{run_parallel, Scenario};
use srole::util::cli::{Cli, CliError};
use srole::util::table::{f, Table};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&argv[1..]),
        Some("pretrain") => cmd_pretrain(&argv[1..]),
        Some("emu") => cmd_emu(&argv[1..]),
        Some("figures") => {
            eprintln!("use the dedicated binary: cargo run --release --bin figures -- <fig4|fig5|...|all>");
            2
        }
        _ => {
            eprintln!("usage: srole <run|pretrain|emu|figures> [flags]   (--help per subcommand)");
            2
        }
    };
    std::process::exit(code);
}

fn cmd_run(argv: &[String]) -> i32 {
    let cli = Cli::new("srole run", "run one experiment configuration")
        .opt("config", None, "TOML config file (flat keys, see config module)")
        .opt("method", Some("all"), "RL | MARL | SROLE-C | SROLE-D | all")
        .opt("model", Some("vgg16"), "vgg16 | googlenet | rnn")
        .opt("edges", Some("25"), "number of edge nodes")
        .opt("workload", Some("1.0"), "background workload fraction")
        .opt("kappa", Some("100"), "shield penalty κ")
        .opt("seed", Some("1"), "base RNG seed")
        .opt("repetitions", Some("5"), "independent repetitions")
        .opt("iterations", Some("50"), "training iterations per job")
        .opt("threads", Some("0"), "worker threads for multi-method runs (0 = all cores)")
        .opt("trace", None, "arm full tracing and write the JSONL event trace here")
        .flag("real", "use the real-device profile (10 Pis, one cluster)")
        .flag("json", "emit raw metrics as JSON");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::Help) => {
            print!("{}", cli.usage());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };

    let build = || -> Result<ExperimentConfig, String> {
        let mut cfg = if args.has("real") {
            ExperimentConfig::real_device()
        } else {
            ExperimentConfig::default()
        };
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            cfg = ExperimentConfig::from_toml(&text)?;
        }
        cfg.apply("model", args.get("model").unwrap())?;
        if !args.has("real") {
            cfg.apply("edges", args.get("edges").unwrap())?;
        }
        cfg.apply("workload", args.get("workload").unwrap())?;
        cfg.apply("kappa", args.get("kappa").unwrap())?;
        cfg.apply("seed", args.get("seed").unwrap())?;
        cfg.apply("repetitions", args.get("repetitions").unwrap())?;
        cfg.apply("iterations", args.get("iterations").unwrap())?;
        if args.get("trace").is_some() {
            cfg.apply("trace", "full")?;
        }
        cfg.validate()?;
        Ok(cfg)
    };
    let cfg = match build() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };

    let methods: Vec<Method> = match args.get("method") {
        Some("all") | None => Method::ALL.to_vec(),
        Some(m) => match Method::parse(m) {
            Some(m) => vec![m],
            None => {
                eprintln!("unknown method {m}");
                return 2;
            }
        },
    };

    // One scenario per method, run concurrently through the harness
    // (each scenario is deterministic in cfg.seed regardless of thread
    // count or completion order).
    let scenarios: Vec<Scenario> =
        methods.iter().map(|&m| Scenario::new(m, cfg.clone())).collect();
    let threads = args.usize("threads").unwrap_or(0);
    let reports = run_parallel(&scenarios, threads);

    let mut table = Table::new(
        &format!(
            "srole run: model={} edges={} workload={:.0}% κ={} ({} reps)",
            cfg.model.name(),
            cfg.n_edges,
            cfg.workload * 100.0,
            cfg.reward.kappa,
            cfg.repetitions
        ),
        &["method", "jct_median_s", "jct_p95_s", "collisions", "sched_s", "shield_s", "util_cpu_med"],
    );
    for r in &reports {
        let m = r.scenario.method;
        let jct = r.metrics.jct_summary();
        if args.has("json") {
            println!("{{\"method\":\"{}\",\"metrics\":{}}}", m.name(), r.metrics.to_json().to_string());
        }
        table.row(vec![
            m.name().into(),
            f(jct.median),
            f(jct.p95),
            r.metrics.collisions.to_string(),
            format!("{:.3}", r.metrics.mean_sched_secs()),
            format!("{:.3}", r.metrics.mean_shield_secs()),
            r.metrics.util_summary("cpu").map(|s| f(s.median)).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.print();
    if let Some(path) = args.get("trace") {
        // First method's first-repetition trace — each scenario records
        // independently; one file keeps the CLI surface simple.
        let path = std::path::Path::new(path);
        match reports.iter().find_map(|r| r.obs.as_ref()) {
            Some(obs) => match obs.write_trace(path) {
                Ok(chrome) => println!("trace: {} + {}", path.display(), chrome.display()),
                Err(e) => {
                    eprintln!("write {}: {e}", path.display());
                    return 1;
                }
            },
            None => eprintln!("no trace captured (tracer off?)"),
        }
    }
    0
}

/// Offline pre-training with persistence: the paper's "the RL is
/// initially pre-trained and distributed to each edge node".
fn cmd_pretrain(argv: &[String]) -> i32 {
    let cli = Cli::new("srole pretrain", "pre-train the scheduling policy offline")
        .opt("episodes", Some("1000"), "pre-training episodes")
        .opt("model", Some("vgg16"), "vgg16 | googlenet | rnn")
        .opt("seed", Some("1"), "seed")
        .opt("save", Some("policy.json"), "output path for the Q-table");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::Help) => {
            print!("{}", cli.usage());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let mut cfg = ExperimentConfig::default();
    if let Err(e) = cfg.apply("model", args.get("model").unwrap()) {
        eprintln!("{e}");
        return 2;
    }
    cfg.pretrain_episodes = args.usize("episodes").unwrap_or(1000);
    cfg.seed = args.u64("seed").unwrap_or(1);
    let mut policy = srole::rl::TabularQ::new(cfg.lr, cfg.epsilon);
    let mut rng = srole::util::Rng::new(cfg.seed);
    srole::coordinator::pretrain(&mut policy, &cfg, &mut rng);
    let path = args.get("save").unwrap();
    let visited = policy.visits.iter().filter(|&&v| v > 0).count();
    match std::fs::write(path, policy.to_json().to_string()) {
        Ok(()) => {
            println!(
                "pre-trained {} episodes on {}; {}/{} table cells visited; saved to {path}",
                cfg.pretrain_episodes,
                cfg.model.name(),
                visited,
                srole::rl::TABLE_SIZE
            );
            0
        }
        Err(e) => {
            eprintln!("write {path}: {e}");
            1
        }
    }
}

fn cmd_emu(argv: &[String]) -> i32 {
    let cli = Cli::new("srole emu", "live PS-strategy training on the thread emulation")
        .opt("workers", Some("3"), "worker threads (edge nodes)")
        .opt("steps", Some("60"), "training steps")
        .opt("lr", Some("0.5"), "learning rate")
        .opt("seed", Some("1"), "seed")
        .opt("artifacts", None, "artifacts directory (default: auto-detect)");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(CliError::Help) => {
            print!("{}", cli.usage());
            return 0;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(srole::runtime::Engine::default_dir);
    let cfg = srole::emu::PsConfig {
        workers: args.usize("workers").unwrap_or(3),
        steps: args.usize("steps").unwrap_or(60),
        lr: args.f64("lr").unwrap_or(0.5) as f32,
        seed: args.u64("seed").unwrap_or(1),
        log_every: 5,
    };
    match srole::emu::train_data_parallel(&dir, &cfg) {
        Ok(logs) => {
            let mut t = Table::new("PS training (loss curve)", &["step", "loss", "wall_ms"]);
            for l in &logs {
                t.row(vec![l.step.to_string(), format!("{:.4}", l.loss), format!("{:.1}", l.wall_ms)]);
            }
            t.print();
            0
        }
        Err(e) => {
            eprintln!("emu failed: {e:#}");
            1
        }
    }
}
