//! DQN scheduling policy: the Q-function is the AOT-compiled `qnet_*`
//! artifact executed through PJRT — this is the variant where the RL
//! model itself runs on the Rust request path and "keeps training".
//!
//! Action selection masks candidate slots beyond the current candidate
//! count and scores the *scheduler-recorded* state (owner-utilization
//! slots included) through a reused per-session forward buffer; learning
//! converts each finished episode into replay slots of the SoA ring and
//! runs TD mini-batches through `qnet_train` with an in-session target
//! network, filling one reusable [`TdBatch`] scratch per step.  In the
//! host-stub build the steady-state decision path allocates nothing;
//! the vendored-PJRT build still rebuilds one device state literal per
//! forward (see `runtime::qnet::refill_state`).

use crate::dnn::Layer;
use crate::obs;
use crate::runtime::qnet::{QNetSession, TdBatch};
use crate::runtime::Engine;
use crate::util::error::Result;
use crate::util::Rng;

use super::features::{CandidateView, NUM_ACTIONS, STATE_DIM};
use super::replay::Replay;
use super::{Episode, Policy, RewardParams};

/// Greedy-by-utilization fallback pick when the Q-net forward fails:
/// the candidate with the most combined free capacity (ties to the
/// lowest index, deterministic).
fn greedy_by_util(cands: &[CandidateView], n: usize) -> usize {
    let mut best = 0usize;
    let mut best_avail = f64::NEG_INFINITY;
    for (i, c) in cands.iter().enumerate().take(n) {
        let avail = c.avail_cpu + c.avail_mem + c.avail_bw;
        if avail > best_avail {
            best_avail = avail;
            best = i;
        }
    }
    best
}

/// Argmax over the first `n` Q-values (ties to the lowest index).
fn argmax_q(q: &[f32], n: usize) -> usize {
    let mut best = 0usize;
    let mut best_q = f32::NEG_INFINITY;
    for (i, &qi) in q.iter().enumerate().take(n) {
        if qi > best_q {
            best_q = qi;
            best = i;
        }
    }
    best
}

/// DQN policy owning an engine-bound Q-network session.
pub struct DqnPolicy<'e> {
    session: QNetSession<'e>,
    replay: Replay,
    pub epsilon: f64,
    pub lr: f32,
    pub discount: f32,
    pub train_every: usize,
    episodes_seen: usize,
    /// Q-net forward failures absorbed by the greedy-by-utilization
    /// fallback (surfaced through [`Policy::fwd_errors`]).
    qnet_fwd_errors: usize,
    /// Reused per-decision Q-value buffer (allocated once).
    q_buf: Vec<f32>,
    /// Reused batched-decision scratch: greedy row indices, their
    /// gathered states, and the chunk Q-value panel.
    greedy_rows: Vec<usize>,
    greedy_states: Vec<f32>,
    batch_q: Vec<f32>,
    /// Reused TD mini-batch scratch (allocated once, cleared per step).
    batch: TdBatch,
    rng: Rng,
}

impl<'e> DqnPolicy<'e> {
    pub fn new(engine: &'e mut Engine, seed: i32) -> Result<DqnPolicy<'e>> {
        Ok(Self::from_session(QNetSession::new(engine, seed)?, seed))
    }

    /// Pure-host policy over [`QNetSession::new_host`] — runnable in
    /// stub builds with no PJRT client (the decision benches and the
    /// stub-build batched-vs-per-row equivalence tests run on this).
    pub fn new_host(seed: i32) -> DqnPolicy<'static> {
        DqnPolicy::from_session(QNetSession::new_host(seed), seed)
    }

    fn from_session(session: QNetSession<'e>, seed: i32) -> DqnPolicy<'e> {
        assert_eq!(session.state_dim, STATE_DIM, "artifact/feature dim mismatch");
        assert_eq!(session.num_actions, NUM_ACTIONS);
        let train_batch = session.train_batch;
        DqnPolicy {
            session,
            replay: Replay::new(4096, STATE_DIM),
            epsilon: 0.1,
            lr: 0.01,
            discount: 0.95,
            train_every: 1,
            episodes_seen: 0,
            qnet_fwd_errors: 0,
            q_buf: vec![0.0; NUM_ACTIONS],
            greedy_rows: Vec::new(),
            greedy_states: Vec::new(),
            batch_q: Vec::new(),
            batch: TdBatch::with_capacity(train_batch, STATE_DIM),
            rng: Rng::new(seed as u64 ^ 0x9e3779b97f4a7c15),
        }
    }

    /// Arm the session's fault-injection hook (tests): the next `n`
    /// forwards — single rows or whole batch chunks — fail.
    pub fn inject_fwd_faults(&mut self, n: usize) {
        self.session.inject_fwd_faults(n);
    }

    /// Fixed lane width of the batched decision forward.
    pub fn fwd_lanes(&self) -> usize {
        self.session.fwd_lanes()
    }

    /// Dense state for a decision (exposed so the scheduler can record it).
    pub fn featurize(
        layer: &Layer,
        owner_util: [f64; 3],
        cands: &[CandidateView],
    ) -> [f32; STATE_DIM] {
        super::features::state_vector(layer, owner_util, cands)
    }

    /// Replay occupancy (for tests / diagnostics).
    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    fn train_from_replay(&mut self) -> Result<f32> {
        let b = self.session.train_batch;
        self.batch.clear();
        for _ in 0..b {
            let i = self.replay.sample_index(&mut self.rng);
            self.batch.states.extend_from_slice(self.replay.state(i));
            self.batch.actions.push(self.replay.action(i) as i32);
            self.batch.rewards.push(self.replay.reward(i));
            self.batch.next_states.extend_from_slice(self.replay.next_state(i));
            self.batch.dones.push(if self.replay.done(i) { 1.0 } else { 0.0 });
        }
        self.session.train(&self.batch, self.lr, self.discount)
    }
}

impl Policy for DqnPolicy<'_> {
    fn choose(
        &mut self,
        _layer: &Layer,
        state: &[f32; STATE_DIM],
        cands: &[CandidateView],
        rng: &mut Rng,
        explore: bool,
    ) -> usize {
        assert!(!cands.is_empty());
        let n = cands.len().min(NUM_ACTIONS);
        if explore && rng.chance(self.epsilon) {
            return rng.below(n);
        }
        match self.session.fwd_into(state, &mut self.q_buf) {
            Ok(()) => argmax_q(&self.q_buf, n),
            Err(_) => {
                // A failing Q-net must not silently collapse onto action
                // 0 (the old all-zero-Q behavior): count the failure and
                // fall back to greedy-by-utilization.
                self.qnet_fwd_errors += 1;
                greedy_by_util(cands, n)
            }
        }
    }

    /// Whole-round override of the default per-row loop.  Pass 1 replays
    /// the epsilon/explore RNG decisions in row order — exactly the
    /// draws [`DqnPolicy::choose`] would make, so the stream is
    /// untouched (forwards consume no RNG).  Pass 2 scores every greedy
    /// row through fixed-lane batched forwards, one chunk of up to
    /// [`DqnPolicy::fwd_lanes`] rows per call.  A failing chunk degrades
    /// only its own rows to the greedy-by-utilization fallback and
    /// counts one fwd error per degraded row.
    #[allow(clippy::too_many_arguments)]
    fn choose_batch(
        &mut self,
        _layers: &[&Layer],
        states: &[f32],
        cviews: &[CandidateView],
        offsets: &[usize],
        rng: &mut Rng,
        explore: bool,
        out: &mut Vec<usize>,
    ) {
        let rows = offsets.len() - 1;
        out.clear();
        self.greedy_rows.clear();
        self.greedy_states.clear();
        for r in 0..rows {
            let n_cands = offsets[r + 1] - offsets[r];
            assert!(n_cands > 0);
            let n = n_cands.min(NUM_ACTIONS);
            if explore && rng.chance(self.epsilon) {
                out.push(rng.below(n));
            } else {
                self.greedy_rows.push(r);
                self.greedy_states.extend_from_slice(&states[r * STATE_DIM..(r + 1) * STATE_DIM]);
                out.push(usize::MAX); // placeholder — overwritten in pass 2
            }
        }
        // One span covers the whole round's chunked forwards — tracing
        // never reads the clock inside the per-decision loop.
        let _sp = obs::span(obs::Phase::QnetForward);
        let lanes = self.session.fwd_lanes();
        let mut start = 0;
        while start < self.greedy_rows.len() {
            let chunk = lanes.min(self.greedy_rows.len() - start);
            self.batch_q.resize(chunk * NUM_ACTIONS, 0.0);
            let sts = &self.greedy_states[start * STATE_DIM..(start + chunk) * STATE_DIM];
            let ok = self.session.fwd_batch_into(sts, chunk, &mut self.batch_q).is_ok();
            if !ok {
                self.qnet_fwd_errors += chunk;
            }
            for idx in 0..chunk {
                let r = self.greedy_rows[start + idx];
                let cands = &cviews[offsets[r]..offsets[r + 1]];
                let n = cands.len().min(NUM_ACTIONS);
                out[r] = if ok {
                    argmax_q(&self.batch_q[idx * NUM_ACTIONS..(idx + 1) * NUM_ACTIONS], n)
                } else {
                    greedy_by_util(cands, n)
                };
            }
            start += chunk;
        }
    }

    fn learn(&mut self, episode: &Episode, training_time: f64, params: &RewardParams) {
        let terminal = params.completion_reward(training_time) as f32;
        let n = episode.steps.len();
        let zeros = [0.0f32; STATE_DIM];
        for (i, step) in episode.steps.iter().enumerate() {
            let mut reward = step.penalty.value(params) as f32;
            let done = i + 1 == n;
            if done {
                reward += terminal;
            }
            let next_state: &[f32] =
                if done { &zeros } else { &episode.steps[i + 1].state };
            self.replay.push(
                &step.state,
                step.action.min(NUM_ACTIONS - 1),
                reward,
                next_state,
                done,
            );
        }
        self.episodes_seen += 1;
        if self.episodes_seen % self.train_every == 0 && self.replay.len() >= self.session.train_batch
        {
            let _ = self.train_from_replay();
        }
    }

    fn fwd_errors(&self) -> usize {
        self.qnet_fwd_errors
    }

    fn batch_stats(&self) -> (usize, usize, usize) {
        self.session.batch_stats()
    }

    fn name(&self) -> &'static str {
        "dqn_pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;
    use crate::rl::{EpisodeStep, StepPenalty};
    use crate::runtime::test_engine_owned;

    fn cands(n: usize) -> Vec<CandidateView> {
        (0..n)
            .map(|i| CandidateView {
                node: i,
                avail_cpu: 0.1 + 0.8 * (i as f64 / n.max(2) as f64),
                avail_mem: 0.5,
                avail_bw: 0.5,
                bw_to_owner: 100.0,
            })
            .collect()
    }

    #[test]
    fn choose_stays_in_candidate_range() {
        let Some(mut eng) = test_engine_owned() else { return };
        let mut p = DqnPolicy::new(&mut eng, 1).unwrap();
        let layer = ModelKind::Rnn.build().layers[1].clone();
        let mut rng = Rng::new(5);
        for n in [1usize, 2, 5, 11] {
            let cs = cands(n);
            let state = DqnPolicy::featurize(&layer, [0.1, 0.2, 0.3], &cs);
            for _ in 0..5 {
                let a = p.choose(&layer, &state, &cs, &mut rng, true);
                assert!(a < n, "action {a} out of {n}");
            }
        }
        assert_eq!(p.fwd_errors(), 0, "healthy artifacts must not trip the fallback");
    }

    /// Build a `rows`-round batch (varying candidate counts, random
    /// states) for the choose_batch tests.
    fn batch_inputs(
        rows: usize,
        cands_of: impl Fn(usize) -> usize,
    ) -> (Vec<f32>, Vec<CandidateView>, Vec<usize>) {
        let mut seed = Rng::new(21);
        let mut states = Vec::new();
        let mut cviews = Vec::new();
        let mut offsets = vec![0usize];
        for r in 0..rows {
            for _ in 0..STATE_DIM {
                states.push((seed.f64() * 2.0 - 1.0) as f32);
            }
            cviews.extend(cands(cands_of(r)));
            offsets.push(cviews.len());
        }
        (states, cviews, offsets)
    }

    /// The policy-level pin: `choose_batch` must replay per-row `choose`
    /// exactly — same picks, same residual RNG stream — across full and
    /// ragged lane chunks, with exploration drawn in row order.
    #[test]
    fn host_choose_batch_matches_per_row_choose() {
        let graph = ModelKind::Rnn.build();
        let mut a = DqnPolicy::new_host(9);
        let mut b = DqnPolicy::new_host(9);
        a.epsilon = 0.5;
        b.epsilon = 0.5;
        let rows = 2 * a.fwd_lanes() + 6; // two full lanes + a ragged tail
        let layers: Vec<&Layer> =
            (0..rows).map(|r| &graph.layers[r % graph.layers.len()]).collect();
        let (states, cviews, offsets) = batch_inputs(rows, |r| 1 + r % 6);
        for explore in [true, false] {
            let mut rng_a = Rng::new(77);
            let mut rng_b = Rng::new(77);
            let mut batched = Vec::new();
            a.choose_batch(&layers, &states, &cviews, &offsets, &mut rng_a, explore, &mut batched);
            let mut looped = Vec::new();
            for r in 0..rows {
                let state: &[f32; STATE_DIM] =
                    states[r * STATE_DIM..(r + 1) * STATE_DIM].try_into().unwrap();
                let cs = &cviews[offsets[r]..offsets[r + 1]];
                looped.push(b.choose(layers[r], state, cs, &mut rng_b, explore));
            }
            assert_eq!(batched, looped, "explore={explore}");
            // Identical residual RNG state: the next draws agree.
            for _ in 0..8 {
                assert_eq!(rng_a.f64().to_bits(), rng_b.f64().to_bits());
            }
        }
        assert_eq!(a.fwd_errors(), 0);
        assert_eq!(b.fwd_errors(), 0);
        let (fwds, brows, _) = a.batch_stats();
        assert!(fwds >= 3 && brows <= 2 * rows, "batched path issued chunked forwards");
        assert_eq!(b.batch_stats(), (0, 0, 0), "per-row path issues none");
    }

    /// A fault mid-round degrades only its own chunk: those rows fall
    /// back to greedy-by-utilization and count one fwd error each; later
    /// chunks still score through the net.
    #[test]
    fn batch_chunk_fault_falls_back_and_counts() {
        let graph = ModelKind::Rnn.build();
        let mut faulty = DqnPolicy::new_host(4);
        let mut healthy = DqnPolicy::new_host(4);
        let lanes = faulty.fwd_lanes();
        let rows = lanes + 8;
        let layers: Vec<&Layer> =
            (0..rows).map(|r| &graph.layers[r % graph.layers.len()]).collect();
        // cands(4) has strictly increasing free capacity, so the
        // greedy-by-utilization fallback always picks index 3.
        let (states, cviews, offsets) = batch_inputs(rows, |_| 4);
        faulty.inject_fwd_faults(1);
        let mut rng_f = Rng::new(11);
        let mut rng_h = Rng::new(11);
        let mut picks_f = Vec::new();
        let mut picks_h = Vec::new();
        faulty.choose_batch(&layers, &states, &cviews, &offsets, &mut rng_f, false, &mut picks_f);
        healthy.choose_batch(&layers, &states, &cviews, &offsets, &mut rng_h, false, &mut picks_h);
        assert_eq!(faulty.fwd_errors(), lanes, "one error per degraded row");
        assert_eq!(healthy.fwd_errors(), 0);
        for r in 0..lanes {
            assert_eq!(picks_f[r], 3, "row {r} must fall back to greedy-by-utilization");
        }
        for r in lanes..rows {
            assert_eq!(picks_f[r], picks_h[r], "row {r} is past the failed chunk");
        }
        // The failed chunk is not counted as an issued batch forward.
        assert_eq!(faulty.batch_stats(), (1, 8, lanes - 8));
        assert_eq!(healthy.batch_stats(), (2, rows, lanes - 8));
    }

    #[test]
    fn learn_accumulates_and_trains() {
        let Some(mut eng) = test_engine_owned() else { return };
        let mut p = DqnPolicy::new(&mut eng, 2).unwrap();
        let layer = ModelKind::Rnn.build().layers[1].clone();
        let cs = cands(4);
        let params = RewardParams::default();
        // Feed enough episodes to trigger training.
        for e in 0..40 {
            let state = DqnPolicy::featurize(&layer, [0.1, 0.1, 0.1], &cs);
            let ep = Episode {
                steps: vec![EpisodeStep {
                    key: 0,
                    state,
                    action: e % 4,
                    n_candidates: 4,
                    penalty: StepPenalty::default(),
                }],
            };
            p.learn(&ep, 100.0, &params);
        }
        assert!(p.replay_len() >= 40);
    }
}
