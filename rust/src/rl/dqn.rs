//! DQN scheduling policy: the Q-function is the AOT-compiled `qnet_*`
//! artifact executed through PJRT — this is the variant where the RL
//! model itself runs on the Rust request path and "keeps training".
//!
//! Action selection masks candidate slots beyond the current candidate
//! count; learning converts each finished episode into replay transitions
//! and runs TD mini-batches through `qnet_train` with an in-session
//! target network.

use crate::dnn::Layer;
use crate::util::error::Result;
use crate::runtime::qnet::{QNetSession, TdBatch};
use crate::runtime::Engine;
use crate::util::Rng;

use super::features::{state_vector, CandidateView, NUM_ACTIONS, STATE_DIM};
use super::replay::{Replay, Transition};
use super::{Episode, Policy, RewardParams};

/// DQN policy owning an engine-bound Q-network session.
pub struct DqnPolicy<'e> {
    session: QNetSession<'e>,
    replay: Replay,
    pub epsilon: f64,
    pub lr: f32,
    pub discount: f32,
    pub train_every: usize,
    episodes_seen: usize,
    rng: Rng,
}

impl<'e> DqnPolicy<'e> {
    pub fn new(engine: &'e mut Engine, seed: i32) -> Result<DqnPolicy<'e>> {
        let session = QNetSession::new(engine, seed)?;
        assert_eq!(session.state_dim, STATE_DIM, "artifact/feature dim mismatch");
        assert_eq!(session.num_actions, NUM_ACTIONS);
        Ok(DqnPolicy {
            session,
            replay: Replay::new(4096),
            epsilon: 0.1,
            lr: 0.01,
            discount: 0.95,
            train_every: 1,
            episodes_seen: 0,
            rng: Rng::new(seed as u64 ^ 0x9e3779b97f4a7c15),
        })
    }

    /// Dense state for a decision (exposed so the scheduler can record it).
    pub fn featurize(layer: &Layer, owner_util: [f64; 3], cands: &[CandidateView]) -> Vec<f32> {
        state_vector(layer, owner_util, cands)
    }

    fn train_from_replay(&mut self) -> Result<f32> {
        let b = self.session.train_batch;
        let sampled = self.replay.sample(b, &mut self.rng);
        let mut batch = TdBatch {
            states: Vec::with_capacity(b * STATE_DIM),
            actions: Vec::with_capacity(b),
            rewards: Vec::with_capacity(b),
            next_states: Vec::with_capacity(b * STATE_DIM),
            dones: Vec::with_capacity(b),
        };
        for t in sampled {
            batch.states.extend_from_slice(&t.state);
            batch.actions.push(t.action as i32);
            batch.rewards.push(t.reward);
            batch.next_states.extend_from_slice(&t.next_state);
            batch.dones.push(if t.done { 1.0 } else { 0.0 });
        }
        self.session.train(&batch, self.lr, self.discount)
    }
}

impl Policy for DqnPolicy<'_> {
    fn choose(&mut self, layer: &Layer, cands: &[CandidateView], rng: &mut Rng, explore: bool) -> usize {
        assert!(!cands.is_empty());
        let n = cands.len().min(NUM_ACTIONS);
        if explore && rng.chance(self.epsilon) {
            return rng.below(n);
        }
        // Owner utilization features are embedded by the scheduler through
        // featurize(); choose() recomputes with zeros for the owner slot —
        // the candidate features carry the signal that matters for ranking.
        let state = state_vector(layer, [0.0; 3], cands);
        let q = self.session.fwd(&state).unwrap_or_else(|_| vec![0.0; NUM_ACTIONS]);
        let mut best = 0usize;
        let mut best_q = f32::NEG_INFINITY;
        for (i, &qi) in q.iter().enumerate().take(n) {
            if qi > best_q {
                best_q = qi;
                best = i;
            }
        }
        best
    }

    fn learn(&mut self, episode: &Episode, training_time: f64, params: &RewardParams) {
        let terminal = params.completion_reward(training_time) as f32;
        let n = episode.steps.len();
        for (i, step) in episode.steps.iter().enumerate() {
            let mut reward = step.penalty.value(params) as f32;
            let done = i + 1 == n;
            if done {
                reward += terminal;
            }
            let next_state =
                if done { vec![0.0; STATE_DIM] } else { episode.steps[i + 1].state.clone() };
            self.replay.push(Transition {
                state: step.state.clone(),
                action: step.action.min(NUM_ACTIONS - 1),
                reward,
                next_state,
                done,
            });
        }
        self.episodes_seen += 1;
        if self.episodes_seen % self.train_every == 0 && self.replay.len() >= self.session.train_batch
        {
            let _ = self.train_from_replay();
        }
    }

    fn name(&self) -> &'static str {
        "dqn_pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;
    use crate::rl::{EpisodeStep, StepPenalty};
    use crate::runtime::test_engine_owned;

    fn cands(n: usize) -> Vec<CandidateView> {
        (0..n)
            .map(|i| CandidateView {
                node: i,
                avail_cpu: 0.1 + 0.8 * (i as f64 / n.max(2) as f64),
                avail_mem: 0.5,
                avail_bw: 0.5,
                bw_to_owner: 100.0,
            })
            .collect()
    }

    #[test]
    fn choose_stays_in_candidate_range() {
        let Some(mut eng) = test_engine_owned() else { return };
        let mut p = DqnPolicy::new(&mut eng, 1).unwrap();
        let layer = ModelKind::Rnn.build().layers[1].clone();
        let mut rng = Rng::new(5);
        for n in [1usize, 2, 5, 11] {
            let cs = cands(n);
            for _ in 0..5 {
                let a = p.choose(&layer, &cs, &mut rng, true);
                assert!(a < n, "action {a} out of {n}");
            }
        }
    }

    #[test]
    fn learn_accumulates_and_trains() {
        let Some(mut eng) = test_engine_owned() else { return };
        let mut p = DqnPolicy::new(&mut eng, 2).unwrap();
        let layer = ModelKind::Rnn.build().layers[1].clone();
        let cs = cands(4);
        let params = RewardParams::default();
        // Feed enough episodes to trigger training.
        for e in 0..40 {
            let state = DqnPolicy::featurize(&layer, [0.1, 0.1, 0.1], &cs);
            let ep = Episode {
                steps: vec![EpisodeStep {
                    key: 0,
                    state: state.clone(),
                    action: e % 4,
                    n_candidates: 4,
                    penalty: StepPenalty::default(),
                }],
            };
            p.learn(&ep, 100.0, &params);
        }
        assert!(p.replay.len() >= 40);
    }
}
