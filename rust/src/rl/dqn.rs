//! DQN scheduling policy: the Q-function is the AOT-compiled `qnet_*`
//! artifact executed through PJRT — this is the variant where the RL
//! model itself runs on the Rust request path and "keeps training".
//!
//! Action selection masks candidate slots beyond the current candidate
//! count and scores the *scheduler-recorded* state (owner-utilization
//! slots included) through a reused per-session forward buffer; learning
//! converts each finished episode into replay slots of the SoA ring and
//! runs TD mini-batches through `qnet_train` with an in-session target
//! network, filling one reusable [`TdBatch`] scratch per step.  In the
//! host-stub build the steady-state decision path allocates nothing;
//! the vendored-PJRT build still rebuilds one device state literal per
//! forward (see `runtime::qnet::refill_state`).

use crate::dnn::Layer;
use crate::runtime::qnet::{QNetSession, TdBatch};
use crate::runtime::Engine;
use crate::util::error::Result;
use crate::util::Rng;

use super::features::{CandidateView, NUM_ACTIONS, STATE_DIM};
use super::replay::Replay;
use super::{Episode, Policy, RewardParams};

/// DQN policy owning an engine-bound Q-network session.
pub struct DqnPolicy<'e> {
    session: QNetSession<'e>,
    replay: Replay,
    pub epsilon: f64,
    pub lr: f32,
    pub discount: f32,
    pub train_every: usize,
    episodes_seen: usize,
    /// Q-net forward failures absorbed by the greedy-by-utilization
    /// fallback (surfaced through [`Policy::fwd_errors`]).
    qnet_fwd_errors: usize,
    /// Reused per-decision Q-value buffer (allocated once).
    q_buf: Vec<f32>,
    /// Reused TD mini-batch scratch (allocated once, cleared per step).
    batch: TdBatch,
    rng: Rng,
}

impl<'e> DqnPolicy<'e> {
    pub fn new(engine: &'e mut Engine, seed: i32) -> Result<DqnPolicy<'e>> {
        let session = QNetSession::new(engine, seed)?;
        assert_eq!(session.state_dim, STATE_DIM, "artifact/feature dim mismatch");
        assert_eq!(session.num_actions, NUM_ACTIONS);
        let train_batch = session.train_batch;
        Ok(DqnPolicy {
            session,
            replay: Replay::new(4096, STATE_DIM),
            epsilon: 0.1,
            lr: 0.01,
            discount: 0.95,
            train_every: 1,
            episodes_seen: 0,
            qnet_fwd_errors: 0,
            q_buf: vec![0.0; NUM_ACTIONS],
            batch: TdBatch::with_capacity(train_batch, STATE_DIM),
            rng: Rng::new(seed as u64 ^ 0x9e3779b97f4a7c15),
        })
    }

    /// Dense state for a decision (exposed so the scheduler can record it).
    pub fn featurize(
        layer: &Layer,
        owner_util: [f64; 3],
        cands: &[CandidateView],
    ) -> [f32; STATE_DIM] {
        super::features::state_vector(layer, owner_util, cands)
    }

    /// Replay occupancy (for tests / diagnostics).
    pub fn replay_len(&self) -> usize {
        self.replay.len()
    }

    fn train_from_replay(&mut self) -> Result<f32> {
        let b = self.session.train_batch;
        self.batch.clear();
        for _ in 0..b {
            let i = self.replay.sample_index(&mut self.rng);
            self.batch.states.extend_from_slice(self.replay.state(i));
            self.batch.actions.push(self.replay.action(i) as i32);
            self.batch.rewards.push(self.replay.reward(i));
            self.batch.next_states.extend_from_slice(self.replay.next_state(i));
            self.batch.dones.push(if self.replay.done(i) { 1.0 } else { 0.0 });
        }
        self.session.train(&self.batch, self.lr, self.discount)
    }
}

impl Policy for DqnPolicy<'_> {
    fn choose(
        &mut self,
        _layer: &Layer,
        state: &[f32; STATE_DIM],
        cands: &[CandidateView],
        rng: &mut Rng,
        explore: bool,
    ) -> usize {
        assert!(!cands.is_empty());
        let n = cands.len().min(NUM_ACTIONS);
        if explore && rng.chance(self.epsilon) {
            return rng.below(n);
        }
        match self.session.fwd_into(state, &mut self.q_buf) {
            Ok(()) => {
                let mut best = 0usize;
                let mut best_q = f32::NEG_INFINITY;
                for (i, &qi) in self.q_buf.iter().enumerate().take(n) {
                    if qi > best_q {
                        best_q = qi;
                        best = i;
                    }
                }
                best
            }
            Err(_) => {
                // A failing Q-net must not silently collapse onto action
                // 0 (the old all-zero-Q behavior): count the failure and
                // fall back to greedy-by-utilization — the candidate with
                // the most combined free capacity (ties to the lowest
                // index, deterministic).
                self.qnet_fwd_errors += 1;
                let mut best = 0usize;
                let mut best_avail = f64::NEG_INFINITY;
                for (i, c) in cands.iter().enumerate().take(n) {
                    let avail = c.avail_cpu + c.avail_mem + c.avail_bw;
                    if avail > best_avail {
                        best_avail = avail;
                        best = i;
                    }
                }
                best
            }
        }
    }

    fn learn(&mut self, episode: &Episode, training_time: f64, params: &RewardParams) {
        let terminal = params.completion_reward(training_time) as f32;
        let n = episode.steps.len();
        let zeros = [0.0f32; STATE_DIM];
        for (i, step) in episode.steps.iter().enumerate() {
            let mut reward = step.penalty.value(params) as f32;
            let done = i + 1 == n;
            if done {
                reward += terminal;
            }
            let next_state: &[f32] =
                if done { &zeros } else { &episode.steps[i + 1].state };
            self.replay.push(
                &step.state,
                step.action.min(NUM_ACTIONS - 1),
                reward,
                next_state,
                done,
            );
        }
        self.episodes_seen += 1;
        if self.episodes_seen % self.train_every == 0 && self.replay.len() >= self.session.train_batch
        {
            let _ = self.train_from_replay();
        }
    }

    fn fwd_errors(&self) -> usize {
        self.qnet_fwd_errors
    }

    fn name(&self) -> &'static str {
        "dqn_pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;
    use crate::rl::{EpisodeStep, StepPenalty};
    use crate::runtime::test_engine_owned;

    fn cands(n: usize) -> Vec<CandidateView> {
        (0..n)
            .map(|i| CandidateView {
                node: i,
                avail_cpu: 0.1 + 0.8 * (i as f64 / n.max(2) as f64),
                avail_mem: 0.5,
                avail_bw: 0.5,
                bw_to_owner: 100.0,
            })
            .collect()
    }

    #[test]
    fn choose_stays_in_candidate_range() {
        let Some(mut eng) = test_engine_owned() else { return };
        let mut p = DqnPolicy::new(&mut eng, 1).unwrap();
        let layer = ModelKind::Rnn.build().layers[1].clone();
        let mut rng = Rng::new(5);
        for n in [1usize, 2, 5, 11] {
            let cs = cands(n);
            let state = DqnPolicy::featurize(&layer, [0.1, 0.2, 0.3], &cs);
            for _ in 0..5 {
                let a = p.choose(&layer, &state, &cs, &mut rng, true);
                assert!(a < n, "action {a} out of {n}");
            }
        }
        assert_eq!(p.fwd_errors(), 0, "healthy artifacts must not trip the fallback");
    }

    #[test]
    fn learn_accumulates_and_trains() {
        let Some(mut eng) = test_engine_owned() else { return };
        let mut p = DqnPolicy::new(&mut eng, 2).unwrap();
        let layer = ModelKind::Rnn.build().layers[1].clone();
        let cs = cands(4);
        let params = RewardParams::default();
        // Feed enough episodes to trigger training.
        for e in 0..40 {
            let state = DqnPolicy::featurize(&layer, [0.1, 0.1, 0.1], &cs);
            let ep = Episode {
                steps: vec![EpisodeStep {
                    key: 0,
                    state,
                    action: e % 4,
                    n_candidates: 4,
                    penalty: StepPenalty::default(),
                }],
            };
            p.learn(&ep, 100.0, &params);
        }
        assert!(p.replay_len() >= 40);
    }
}
