//! Reinforcement-learning machinery for the schedulers (§III, §IV-B).
//!
//! The scheduling MDP: an agent assigns the layers of a DL job one per
//! timestep to a candidate edge (itself or a neighbor).  States are
//! discretized into low/medium/high buckets exactly as the paper
//! prescribes ("we discretize the continuous space by dividing their
//! value range into a number (e.g., three) of equal-width ranges").
//!
//! Two interchangeable policies implement [`Policy`]:
//!
//! * [`TabularQ`] — the paper-faithful CQ-learning table over the
//!   factored (layer-class × candidate-availability) state;
//! * `rl::dqn::DqnPolicy` — a Q-network executed through the AOT-compiled
//!   PJRT artifact (`qnet_fwd` / `qnet_train`), the "keeps training the
//!   RL model" path.

pub mod dqn;
pub mod features;
pub mod replay;

pub use features::{
    bucket, layer_class, nearest_first, state_vector, state_vector_into, CandidateView,
    NUM_ACTIONS, STATE_DIM,
};

use crate::dnn::Layer;
use crate::util::Rng;

/// Number of buckets per discretized dimension (low / medium / high).
pub const BUCKETS: usize = 3;

/// Reward hyper-parameters (§V-A: α=0.9, ρ=1, γ=50, κ=100).
#[derive(Debug, Clone, Copy)]
pub struct RewardParams {
    /// Overload threshold α on any per-resource utilization.
    pub alpha: f64,
    /// Reward scale ρ in ρ/√O.
    pub rho: f64,
    /// Memory-violation penalty γ (positive; applied as −γ).
    pub gamma: f64,
    /// Shield-correction penalty κ (positive; applied as −κ).
    pub kappa: f64,
}

impl Default for RewardParams {
    fn default() -> Self {
        RewardParams { alpha: 0.9, rho: 1.0, gamma: 50.0, kappa: 100.0 }
    }
}

/// Reward normalization.  The paper leaves the unit of O unspecified; with
/// O in raw seconds ρ/√O ≈ 0.005 while κ = 100, so a single shield
/// correction would permanently dominate every completion signal (and the
/// policy collapses onto never-corrected — i.e. worst — actions).  We keep
/// the paper's *parameters* but normalize both sides to the same scale:
/// completions are measured in `ρ·(100/√O)` (≈1 for a 3-hour job) and
/// penalties in units of [`PENALTY_UNIT`] (κ=100 → −4).
pub const COMPLETION_SCALE: f64 = 100.0;
pub const PENALTY_UNIT: f64 = 100.0;

impl RewardParams {
    /// Terminal reward for a completed job with training time `o` seconds
    /// (paper: r = ρ/√O, normalized — see [`COMPLETION_SCALE`]).
    pub fn completion_reward(&self, o: f64) -> f64 {
        self.rho * COMPLETION_SCALE / o.max(1e-9).sqrt()
    }
}

/// Per-step penalty flags accumulated while an episode runs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepPenalty {
    pub memory_violated: bool,
    pub shielded: bool,
}

impl StepPenalty {
    pub fn value(&self, p: &RewardParams) -> f64 {
        let mut v = 0.0;
        if self.memory_violated {
            v -= p.gamma / PENALTY_UNIT;
        }
        if self.shielded {
            v -= p.kappa / PENALTY_UNIT;
        }
        v
    }
}

/// One recorded decision of an episode (for the episodic update).
#[derive(Debug, Clone)]
pub struct EpisodeStep {
    /// Tabular state-action key.
    pub key: usize,
    /// Dense features (for the DQN path) — a fixed inline array, so
    /// recording a step never heap-allocates.
    pub state: [f32; STATE_DIM],
    pub action: usize,
    pub n_candidates: usize,
    pub penalty: StepPenalty,
}

/// A finished episode: all decisions for one DL job plus the realized
/// training time.
#[derive(Debug, Clone, Default)]
pub struct Episode {
    pub steps: Vec<EpisodeStep>,
}

/// A scheduling policy: picks a candidate index for the current layer.
/// (Not `Send`: the DQN variant holds PJRT handles; the simulator is
/// single-threaded by design for determinism.)
///
/// # Example
///
/// ```
/// use srole::dnn::ModelKind;
/// use srole::rl::{state_vector_into, CandidateView, Policy, TabularQ, STATE_DIM};
/// use srole::util::Rng;
///
/// let graph = ModelKind::Rnn.build();
/// let layer = &graph.layers[0];
/// let cands: Vec<CandidateView> = (0..3)
///     .map(|i| CandidateView {
///         node: i,
///         avail_cpu: 0.2 + 0.3 * i as f64,
///         avail_mem: 0.5,
///         avail_bw: 0.5,
///         bw_to_owner: 100.0,
///     })
///     .collect();
/// // The scheduler records the dense state once and hands it to the
/// // policy — `choose` never re-featurizes.
/// let mut state = [0.0f32; STATE_DIM];
/// state_vector_into(layer, [0.1, 0.2, 0.3], &cands, &mut state);
/// let mut policy = TabularQ::new(0.15, 0.0); // lr 0.15, ε = 0 (greedy)
/// let mut rng = Rng::new(1);
/// let action = policy.choose(layer, &state, &cands, &mut rng, false);
/// assert!(action < cands.len());
/// ```
pub trait Policy {
    /// Choose among `cands` for `layer`; `explore` enables ε-greedy.
    /// `state` is the dense featurization the scheduler already recorded
    /// for this decision (owner-utilization slots included) — policies
    /// that score states must use it rather than re-featurizing.
    fn choose(
        &mut self,
        layer: &Layer,
        state: &[f32; STATE_DIM],
        cands: &[CandidateView],
        rng: &mut Rng,
        explore: bool,
    ) -> usize;

    /// Choose for a whole wave round at once — the batched decision path.
    ///
    /// Row `r` (of `offsets.len() - 1`) is the decision for `layers[r]`
    /// with dense state `states[r·STATE_DIM..]` and candidates
    /// `cviews[offsets[r]..offsets[r + 1]]`; the chosen candidate index
    /// is written to `out[r]`.
    ///
    /// RNG-order contract: implementations must consume `rng` in row
    /// order, drawing exactly what `choose` would draw per row *before*
    /// issuing any forwards (forwards consume no RNG), so a batched round
    /// leaves the stream byte-identical to per-row calls.  The default
    /// implementation simply loops [`Policy::choose`] in row order —
    /// equivalence by construction; [`dqn::DqnPolicy`] overrides it to
    /// score all greedy rows in one fixed-lane batched forward.
    #[allow(clippy::too_many_arguments)]
    fn choose_batch(
        &mut self,
        layers: &[&Layer],
        states: &[f32],
        cviews: &[CandidateView],
        offsets: &[usize],
        rng: &mut Rng,
        explore: bool,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        for r in 0..offsets.len() - 1 {
            let state: &[f32; STATE_DIM] =
                states[r * STATE_DIM..(r + 1) * STATE_DIM].try_into().expect("row width");
            let cands = &cviews[offsets[r]..offsets[r + 1]];
            out.push(self.choose(layers[r], state, cands, rng, explore));
        }
    }

    /// Episodic update once the job's training time is known.
    fn learn(&mut self, episode: &Episode, training_time: f64, params: &RewardParams);

    /// Immediate feedback when the shield replaces this step's action
    /// ("the shield also notifies the edges within the cluster of the
    /// safe action and assigns a constant negative reward (κ)", §IV-C).
    /// Default: no-op (the DQN path gets κ through the episodic replay).
    fn notify_shielded(&mut self, _step: &EpisodeStep, _params: &RewardParams) {}

    /// Q-net forward failures absorbed by the fallback action path so
    /// far (DQN only; tabular policies never fail).  Drivers copy this
    /// into [`RunMetrics::qnet_fwd_errors`](crate::metrics::RunMetrics)
    /// at the end of a run.
    fn fwd_errors(&self) -> usize {
        0
    }

    /// `(batch_fwds, batch_rows, batch_pad_rows)` accumulated by the
    /// batched forward path so far (DQN only; tabular policies decide
    /// without forwards).  Drivers copy these into the `qnet_batch_*`
    /// counters of [`RunMetrics`](crate::metrics::RunMetrics).
    fn batch_stats(&self) -> (usize, usize, usize) {
        (0, 0, 0)
    }

    /// Policy name for reports.
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Tabular CQ-learning
// ---------------------------------------------------------------------------

/// Factored tabular Q: the state of a (layer, candidate) pair is
/// `(layer class, cpu-avail bucket, mem-avail bucket, bw bucket)` —
/// 3⁴ = 81 cells.  Action selection scores every candidate with its own
/// cell and takes the ε-greedy argmax; the episodic update regresses the
/// visited cells toward the realized return.  This is the tractable
/// factorization of the paper's CQ-learning local-state scheme.
#[derive(Debug, Clone)]
pub struct TabularQ {
    pub table: Vec<f64>,
    pub visits: Vec<u32>,
    pub lr: f64,
    pub epsilon: f64,
}

pub const TABLE_SIZE: usize = BUCKETS * BUCKETS * BUCKETS * BUCKETS;

/// Key for a (layer, candidate) pair.
pub fn table_key(layer_cls: usize, cand: &CandidateView) -> usize {
    let c = bucket(cand.avail_cpu);
    let m = bucket(cand.avail_mem);
    let b = bucket(cand.avail_bw);
    ((layer_cls * BUCKETS + c) * BUCKETS + m) * BUCKETS + b
}

impl TabularQ {
    pub fn new(lr: f64, epsilon: f64) -> TabularQ {
        TabularQ { table: vec![0.0; TABLE_SIZE], visits: vec![0; TABLE_SIZE], lr, epsilon }
    }

    pub fn q(&self, key: usize) -> f64 {
        self.table[key]
    }

    /// Serialize to JSON (for `srole pretrain --save`; the paper's
    /// "pre-trained and distributed to each edge node").
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj(vec![
            ("lr", Json::Num(self.lr)),
            ("epsilon", Json::Num(self.epsilon)),
            ("table", Json::Arr(self.table.iter().map(|&v| Json::Num(v)).collect())),
            ("visits", Json::Arr(self.visits.iter().map(|&v| Json::Num(v as f64)).collect())),
        ])
    }

    /// Deserialize from [`TabularQ::to_json`] output.
    pub fn from_json(j: &crate::util::json::Json) -> Result<TabularQ, String> {
        use crate::util::json::Json;
        let num = |k: &str| j.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing {k}"));
        let arr = |k: &str| -> Result<Vec<f64>, String> {
            Ok(j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("missing {k}"))?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0))
                .collect())
        };
        let table = arr("table")?;
        if table.len() != TABLE_SIZE {
            return Err(format!("table size {} != {TABLE_SIZE}", table.len()));
        }
        Ok(TabularQ {
            table,
            visits: arr("visits")?.iter().map(|&v| v as u32).collect(),
            lr: num("lr")?,
            epsilon: num("epsilon")?,
        })
    }
}

impl Policy for TabularQ {
    fn choose(
        &mut self,
        layer: &Layer,
        _state: &[f32; STATE_DIM],
        cands: &[CandidateView],
        rng: &mut Rng,
        explore: bool,
    ) -> usize {
        assert!(!cands.is_empty(), "no candidates");
        if explore && rng.chance(self.epsilon) {
            return rng.below(cands.len());
        }
        let cls = layer_class(layer);
        let mut best = 0usize;
        let mut best_score = f64::NEG_INFINITY;
        for (i, c) in cands.iter().enumerate() {
            let q = self.table[table_key(cls, c)];
            // Prefer higher combined availability among equals; in live
            // (explore) mode add a tiny random jitter — each edge node
            // trains its own RL replica in the paper, so equal-Q agents do
            // not all argmax onto the same node.
            let jitter = if explore { 1e-6 * rng.f64() } else { 0.0 };
            let score = q + 1e-9 * (c.avail_cpu + c.avail_mem + c.avail_bw) + jitter;
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        best
    }

    fn learn(&mut self, episode: &Episode, training_time: f64, params: &RewardParams) {
        let terminal = params.completion_reward(training_time);
        for step in &episode.steps {
            let g = terminal + step.penalty.value(params);
            let k = step.key;
            self.visits[k] += 1;
            self.table[k] += self.lr * (g - self.table[k]);
        }
    }

    fn notify_shielded(&mut self, step: &EpisodeStep, params: &RewardParams) {
        // Immediate TD step toward the κ penalty: within the same run,
        // later decision rounds already avoid the penalized cell.  Higher
        // |κ| → stronger aversion → fewer collisions (Fig 8).
        let k = step.key;
        self.visits[k] += 1;
        self.table[k] += self.lr * (-params.kappa / PENALTY_UNIT - self.table[k]);
    }

    fn name(&self) -> &'static str {
        "tabular_cq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;

    fn cand(cpu: f64, mem: f64, bw: f64) -> CandidateView {
        CandidateView { node: 0, avail_cpu: cpu, avail_mem: mem, avail_bw: bw, bw_to_owner: 100.0 }
    }

    fn some_layer() -> Layer {
        ModelKind::Rnn.build().layers[1].clone()
    }

    #[test]
    fn reward_params_default_match_paper() {
        let p = RewardParams::default();
        assert_eq!(p.alpha, 0.9);
        assert_eq!(p.rho, 1.0);
        assert_eq!(p.gamma, 50.0);
        assert_eq!(p.kappa, 100.0);
    }

    #[test]
    fn completion_reward_decreases_with_time() {
        let p = RewardParams::default();
        assert!(p.completion_reward(100.0) > p.completion_reward(400.0));
        // O = 10_000 s -> rho * 100/100 = 1.0 (the normalization anchor).
        assert!((p.completion_reward(10_000.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn penalties_apply() {
        let p = RewardParams::default();
        let none = StepPenalty::default();
        assert_eq!(none.value(&p), 0.0);
        let mem = StepPenalty { memory_violated: true, shielded: false };
        assert_eq!(mem.value(&p), -50.0 / PENALTY_UNIT);
        let both = StepPenalty { memory_violated: true, shielded: true };
        assert_eq!(both.value(&p), -150.0 / PENALTY_UNIT);
    }

    #[test]
    fn table_keys_in_range_and_distinct() {
        let l = some_layer();
        let cls = layer_class(&l);
        let k_low = table_key(cls, &cand(0.1, 0.1, 0.1));
        let k_high = table_key(cls, &cand(0.9, 0.9, 0.9));
        assert!(k_low < TABLE_SIZE && k_high < TABLE_SIZE);
        assert_ne!(k_low, k_high);
    }

    #[test]
    fn greedy_prefers_higher_q() {
        let mut q = TabularQ::new(0.5, 0.0);
        let l = some_layer();
        let cls = layer_class(&l);
        let good = cand(0.9, 0.9, 0.9);
        let bad = cand(0.1, 0.1, 0.1);
        q.table[table_key(cls, &good)] = 1.0;
        q.table[table_key(cls, &bad)] = -1.0;
        let mut rng = Rng::new(1);
        let pick = q.choose(&l, &[0.0; STATE_DIM], &[bad.clone(), good.clone()], &mut rng, false);
        assert_eq!(pick, 1);
    }

    #[test]
    fn learning_moves_q_toward_return() {
        let mut q = TabularQ::new(0.5, 0.0);
        let l = some_layer();
        let c = cand(0.5, 0.5, 0.5);
        let key = table_key(layer_class(&l), &c);
        let ep = Episode {
            steps: vec![EpisodeStep {
                key,
                state: [0.0; STATE_DIM],
                action: 0,
                n_candidates: 1,
                penalty: StepPenalty::default(),
            }],
        };
        let params = RewardParams::default();
        q.learn(&ep, 10_000.0, &params);
        let expected = 0.5 * params.completion_reward(10_000.0);
        assert!((q.q(key) - expected).abs() < 1e-12);
        assert_eq!(q.visits[key], 1);
    }

    #[test]
    fn kappa_penalty_depresses_q() {
        let params = RewardParams { kappa: 100.0, ..Default::default() };
        let mut q = TabularQ::new(0.3, 0.0);
        let l = some_layer();
        let c = cand(0.5, 0.5, 0.5);
        let key = table_key(layer_class(&l), &c);
        let ep = Episode {
            steps: vec![EpisodeStep {
                key,
                state: [0.0; STATE_DIM],
                action: 0,
                n_candidates: 1,
                penalty: StepPenalty { memory_violated: false, shielded: true },
            }],
        };
        // Immediate shield notification drives the cell negative
        // (κ=100 → −1 in normalized units).
        q.notify_shielded(&ep.steps[0], &params);
        assert!(q.q(key) < -0.2, "q={}", q.q(key));
        // Larger kappa must depress the cell further (Fig 8 mechanism).
        let mut q2 = TabularQ::new(0.3, 0.0);
        let params2 = RewardParams { kappa: 300.0, ..Default::default() };
        q2.notify_shielded(&ep.steps[0], &params2);
        assert!(q2.q(key) < q.q(key));
        // Episodic return also nets the κ penalty against the terminal.
        q.learn(&ep, 10_000.0, &params);
        assert!(q.q(key) < 0.1);
    }

    #[test]
    fn exploration_randomizes() {
        let mut q = TabularQ::new(0.5, 1.0); // always explore
        let l = some_layer();
        let cands = vec![cand(0.1, 0.1, 0.1), cand(0.9, 0.9, 0.9), cand(0.5, 0.5, 0.5)];
        let mut rng = Rng::new(2);
        let picks: Vec<usize> =
            (0..60).map(|_| q.choose(&l, &[0.0; STATE_DIM], &cands, &mut rng, true)).collect();
        for i in 0..3 {
            assert!(picks.contains(&i));
        }
    }

    #[test]
    fn qtable_json_roundtrip() {
        let mut q = TabularQ::new(0.2, 0.05);
        q.table[3] = -1.5;
        q.table[80] = 2.25;
        q.visits[3] = 7;
        let j = q.to_json();
        let q2 = TabularQ::from_json(&crate::util::json::Json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(q2.table, q.table);
        assert_eq!(q2.visits, q.visits);
        assert_eq!(q2.lr, 0.2);
        assert_eq!(q2.epsilon, 0.05);
        // Corrupted input is rejected.
        assert!(TabularQ::from_json(&crate::util::json::Json::parse("{}").unwrap()).is_err());
    }

    /// The default `choose_batch` must replay per-row `choose` exactly:
    /// same picks *and* the same RNG stream afterwards (the batched wave
    /// path relies on this for byte-identical runs with `TabularQ`).
    #[test]
    fn default_choose_batch_matches_per_row_choose() {
        let graph = ModelKind::Rnn.build();
        let mut rng_seed = Rng::new(17);
        let layers: Vec<&Layer> = (0..7).map(|i| &graph.layers[i % graph.layers.len()]).collect();
        let mut states = Vec::new();
        let mut cviews = Vec::new();
        let mut offsets = vec![0usize];
        for r in 0..layers.len() {
            for _ in 0..STATE_DIM {
                states.push(rng_seed.f64() as f32);
            }
            for _ in 0..(1 + r % 4) {
                cviews.push(cand(rng_seed.f64(), rng_seed.f64(), rng_seed.f64()));
            }
            offsets.push(cviews.len());
        }
        let mut a = TabularQ::new(0.2, 0.35);
        let mut b = a.clone();
        for k in 0..TABLE_SIZE {
            a.table[k] = (k as f64 * 0.37).sin();
            b.table[k] = a.table[k];
        }
        let mut rng_a = Rng::new(123);
        let mut rng_b = Rng::new(123);
        let mut batched = Vec::new();
        a.choose_batch(&layers, &states, &cviews, &offsets, &mut rng_a, true, &mut batched);
        let mut looped = Vec::new();
        for r in 0..layers.len() {
            let state: &[f32; STATE_DIM] =
                states[r * STATE_DIM..(r + 1) * STATE_DIM].try_into().unwrap();
            let cands = &cviews[offsets[r]..offsets[r + 1]];
            looped.push(b.choose(layers[r], state, cands, &mut rng_b, true));
        }
        assert_eq!(batched, looped);
        // Identical residual RNG state: the next draws agree.
        for _ in 0..8 {
            assert_eq!(rng_a.f64().to_bits(), rng_b.f64().to_bits());
        }
        assert_eq!(a.batch_stats(), (0, 0, 0), "tabular policies issue no forwards");
    }

    #[test]
    fn no_exploration_when_disabled() {
        let mut q = TabularQ::new(0.5, 1.0);
        let l = some_layer();
        let cands = vec![cand(0.2, 0.2, 0.2), cand(0.9, 0.9, 0.9)];
        let mut rng = Rng::new(3);
        // epsilon=1 but explore=false must be deterministic greedy.
        let first = q.choose(&l, &[0.0; STATE_DIM], &cands, &mut rng, false);
        for _ in 0..20 {
            assert_eq!(q.choose(&l, &[0.0; STATE_DIM], &cands, &mut rng, false), first);
        }
    }
}
