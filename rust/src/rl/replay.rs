//! Experience replay buffer for the DQN policy — a structure-of-arrays
//! ring.
//!
//! The previous implementation stored one `Transition` struct per slot,
//! each owning two heap `Vec<f32>` states; every `push` cloned both and
//! every `sample` chased per-transition pointers.  This layout keeps one
//! contiguous `Vec<f32>` per column (states / next-states indexed by
//! slot, scalars alongside), pre-allocated to capacity at construction:
//! pushing copies two fixed-size slices into place and sampling reads
//! slices back out — zero steady-state allocations.  The column layout
//! mirrors the `qnet_train` artifact batch `(s, a, r, s2, done)`, so
//! filling a [`TdBatch`](crate::runtime::qnet::TdBatch) is straight
//! `extend_from_slice` calls.
//!
//! Semantics (uniform sampling, overwrite-oldest ring) are pinned to a
//! `Vec<Transition>`-based reference model by a randomized ≥1000-step
//! property test below.

use crate::util::Rng;

/// Ring-buffer replay memory over fixed-dimension transitions.
#[derive(Debug)]
pub struct Replay {
    /// Feature dimension of `state` / `next_state`.
    dim: usize,
    capacity: usize,
    len: usize,
    /// Next slot to write (wraps at `capacity`).
    next: usize,
    /// `capacity * dim` floats, slot-major.
    states: Vec<f32>,
    next_states: Vec<f32>,
    actions: Vec<usize>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
}

impl Replay {
    /// Pre-allocate the full ring: `capacity` slots of `dim`-dimensional
    /// transitions.  All memory is committed here — no growth later.
    pub fn new(capacity: usize, dim: usize) -> Replay {
        assert!(capacity > 0);
        assert!(dim > 0);
        Replay {
            dim,
            capacity,
            len: 0,
            next: 0,
            states: vec![0.0; capacity * dim],
            next_states: vec![0.0; capacity * dim],
            actions: vec![0; capacity],
            rewards: vec![0.0; capacity],
            dones: vec![false; capacity],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Record one transition, overwriting the oldest slot when full.
    /// Copies the two state slices into the ring — no allocation.
    pub fn push(
        &mut self,
        state: &[f32],
        action: usize,
        reward: f32,
        next_state: &[f32],
        done: bool,
    ) {
        assert_eq!(state.len(), self.dim, "state dim mismatch");
        assert_eq!(next_state.len(), self.dim, "next-state dim mismatch");
        let slot = self.next;
        let lo = slot * self.dim;
        self.states[lo..lo + self.dim].copy_from_slice(state);
        self.next_states[lo..lo + self.dim].copy_from_slice(next_state);
        self.actions[slot] = action;
        self.rewards[slot] = reward;
        self.dones[slot] = done;
        if self.len < self.capacity {
            self.len += 1;
        }
        self.next = (self.next + 1) % self.capacity;
    }

    /// Draw one uniform slot index (the sampling primitive: `n` batch
    /// rows are `n` calls, matching the old `sample()`'s RNG stream).
    pub fn sample_index(&self, rng: &mut Rng) -> usize {
        assert!(self.len > 0, "sample from empty replay");
        rng.below(self.len)
    }

    /// State slice of slot `i` (`i < len`).
    #[inline]
    pub fn state(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        &self.states[i * self.dim..(i + 1) * self.dim]
    }

    /// Next-state slice of slot `i`.
    #[inline]
    pub fn next_state(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        &self.next_states[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn action(&self, i: usize) -> usize {
        self.actions[i]
    }

    #[inline]
    pub fn reward(&self, i: usize) -> f32 {
        self.rewards[i]
    }

    #[inline]
    pub fn done(&self, i: usize) -> bool {
        self.dones[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_grows_to_capacity() {
        let mut r = Replay::new(3, 1);
        assert!(r.is_empty());
        for i in 0..3 {
            r.push(&[i as f32], 0, i as f32, &[i as f32], false);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.capacity(), 3);
        assert_eq!(r.dim(), 1);
    }

    #[test]
    fn overwrites_oldest_when_full() {
        let mut r = Replay::new(3, 1);
        for i in 0..5 {
            r.push(&[i as f32], 0, i as f32, &[i as f32], false);
        }
        assert_eq!(r.len(), 3);
        let rewards: Vec<f32> = (0..3).map(|i| r.reward(i)).collect();
        // 0 and 1 were overwritten by 3 and 4.
        assert!(rewards.contains(&3.0) && rewards.contains(&4.0) && rewards.contains(&2.0));
        assert!(!rewards.contains(&0.0));
    }

    #[test]
    fn sampled_indices_stay_in_range() {
        let mut r = Replay::new(10, 2);
        for i in 0..4 {
            r.push(&[i as f32, 0.0], i, i as f32, &[0.0, i as f32], i % 2 == 0);
        }
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let i = r.sample_index(&mut rng);
            assert!(i < r.len());
            assert_eq!(r.state(i).len(), 2);
            assert_eq!(r.next_state(i).len(), 2);
        }
    }

    #[test]
    #[should_panic]
    fn sample_empty_panics() {
        let r = Replay::new(4, 1);
        let mut rng = Rng::new(1);
        r.sample_index(&mut rng);
    }

    #[test]
    #[should_panic]
    fn wrong_dim_rejected() {
        let mut r = Replay::new(4, 3);
        r.push(&[1.0], 0, 0.0, &[1.0], false);
    }

    /// Vec-of-structs reference model: the pre-SoA implementation's exact
    /// semantics (grow to capacity, then overwrite at the ring cursor).
    struct RefTransition {
        state: Vec<f32>,
        action: usize,
        reward: f32,
        next_state: Vec<f32>,
        done: bool,
    }

    struct RefReplay {
        buf: Vec<RefTransition>,
        capacity: usize,
        next: usize,
    }

    impl RefReplay {
        fn new(capacity: usize) -> RefReplay {
            RefReplay { buf: Vec::with_capacity(capacity), capacity, next: 0 }
        }

        fn push(&mut self, t: RefTransition) {
            if self.buf.len() < self.capacity {
                self.buf.push(t);
            } else {
                self.buf[self.next] = t;
            }
            self.next = (self.next + 1) % self.capacity;
        }
    }

    #[test]
    fn prop_soa_ring_matches_vec_reference_over_1000_steps() {
        // ≥1000 random pushes with interleaved sampling: every slot of
        // the SoA ring must equal the Vec-based reference model, through
        // growth, wraparound and repeated overwrites, and identical RNG
        // streams must sample identical transitions.
        let mut rng = Rng::new(0x50A);
        for (capacity, dim) in [(7usize, 3usize), (32, 5), (64, 1)] {
            let mut soa = Replay::new(capacity, dim);
            let mut reference = RefReplay::new(capacity);
            for step in 0..1200u64 {
                let state: Vec<f32> = (0..dim).map(|_| rng.f64() as f32).collect();
                let next_state: Vec<f32> = (0..dim).map(|_| rng.f64() as f32).collect();
                let action = rng.below(11);
                let reward = (rng.f64() * 10.0 - 5.0) as f32;
                let done = rng.chance(0.1);
                soa.push(&state, action, reward, &next_state, done);
                reference.push(RefTransition {
                    state: state.clone(),
                    action,
                    reward,
                    next_state: next_state.clone(),
                    done,
                });

                assert_eq!(soa.len(), reference.buf.len(), "step {step}");
                for i in 0..soa.len() {
                    let t = &reference.buf[i];
                    assert_eq!(soa.state(i), &t.state[..], "step {step} slot {i}");
                    assert_eq!(soa.next_state(i), &t.next_state[..], "step {step} slot {i}");
                    assert_eq!(soa.action(i), t.action, "step {step} slot {i}");
                    assert_eq!(soa.reward(i), t.reward, "step {step} slot {i}");
                    assert_eq!(soa.done(i), t.done, "step {step} slot {i}");
                }

                // Identical RNG streams must sample identically.
                if step % 50 == 0 {
                    let mut ra = rng.fork(step);
                    let mut rb = ra.clone();
                    for _ in 0..8 {
                        let i = soa.sample_index(&mut ra);
                        let j = rb.below(reference.buf.len());
                        assert_eq!(i, j);
                        assert_eq!(soa.state(i), &reference.buf[j].state[..]);
                    }
                }
            }
            assert_eq!(soa.len(), capacity, "ring must have filled");
        }
    }
}
