//! Experience replay buffer for the DQN policy.
//!
//! Fixed-capacity ring buffer of transitions; uniform sampling without
//! replacement per mini-batch.  The layout mirrors the `qnet_train`
//! artifact batch: `(s, a, r, s2, done)`.

use crate::util::Rng;

/// One transition.
#[derive(Debug, Clone)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: usize,
    pub reward: f32,
    pub next_state: Vec<f32>,
    pub done: bool,
}

/// Ring-buffer replay memory.
#[derive(Debug)]
pub struct Replay {
    buf: Vec<Transition>,
    capacity: usize,
    next: usize,
}

impl Replay {
    pub fn new(capacity: usize) -> Replay {
        assert!(capacity > 0);
        Replay { buf: Vec::with_capacity(capacity), capacity, next: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.next] = t;
        }
        self.next = (self.next + 1) % self.capacity;
    }

    /// Sample `n` transitions uniformly (with replacement if n > len).
    pub fn sample<'a>(&'a self, n: usize, rng: &mut Rng) -> Vec<&'a Transition> {
        assert!(!self.buf.is_empty(), "sample from empty replay");
        (0..n).map(|_| &self.buf[rng.below(self.buf.len())]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f32) -> Transition {
        Transition { state: vec![v], action: 0, reward: v, next_state: vec![v], done: false }
    }

    #[test]
    fn push_grows_to_capacity() {
        let mut r = Replay::new(3);
        assert!(r.is_empty());
        for i in 0..3 {
            r.push(t(i as f32));
        }
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn overwrites_oldest_when_full() {
        let mut r = Replay::new(3);
        for i in 0..5 {
            r.push(t(i as f32));
        }
        assert_eq!(r.len(), 3);
        let rewards: Vec<f32> = r.buf.iter().map(|x| x.reward).collect();
        // 0 and 1 were overwritten by 3 and 4.
        assert!(rewards.contains(&3.0) && rewards.contains(&4.0) && rewards.contains(&2.0));
        assert!(!rewards.contains(&0.0));
    }

    #[test]
    fn sample_returns_requested_count() {
        let mut r = Replay::new(10);
        for i in 0..4 {
            r.push(t(i as f32));
        }
        let mut rng = Rng::new(1);
        assert_eq!(r.sample(8, &mut rng).len(), 8);
        assert_eq!(r.sample(2, &mut rng).len(), 2);
    }

    #[test]
    #[should_panic]
    fn sample_empty_panics() {
        let r = Replay::new(4);
        let mut rng = Rng::new(1);
        r.sample(1, &mut rng);
    }
}
