//! State featurization shared by the tabular and DQN policies.
//!
//! The dense vector layout MUST stay in sync with
//! `python/compile/model.py` (`STATE_DIM = 3 + 3 + 3*MAX_NEIGHBORS`):
//! 3 layer-demand features, 3 owner-utilization features, then
//! `(cpu_avail, mem_avail, bw)` per candidate, zero-padded/truncated to
//! [`MAX_NEIGHBORS`] + the implicit self slot handled as candidate 0.

use crate::cluster::NodeId;
use crate::dnn::Layer;
use crate::net::Topology;

use super::BUCKETS;

/// Maximum neighbor count encoded in the DQN state (mirrors python).
pub const MAX_NEIGHBORS: usize = 10;
/// DQN state dimension (mirrors python STATE_DIM).
pub const STATE_DIM: usize = 3 + 3 + 3 * MAX_NEIGHBORS;
/// DQN action count (self + MAX_NEIGHBORS, mirrors python NUM_ACTIONS).
pub const NUM_ACTIONS: usize = MAX_NEIGHBORS + 1;

/// What an agent sees about one candidate edge node: availability
/// fractions in [0, 1] per resource (1 = fully idle) and the link
/// bandwidth back to the job owner.
#[derive(Debug, Clone)]
pub struct CandidateView {
    pub node: NodeId,
    pub avail_cpu: f64,
    pub avail_mem: f64,
    pub avail_bw: f64,
    pub bw_to_owner: f64,
}

/// Order candidate nodes nearest-first by *current* distance to
/// `origin` (ties break by ascending node id, so the order is total and
/// deterministic).  Sorts in place with squared-distance keys evaluated
/// in the comparator — no sqrt, no heap allocation on the decision path
/// (candidate lists are at most a cluster degree long, so the extra key
/// evaluations are cheaper than a keyed scratch vector).
///
/// Mobility support: the agent's action space is capped at
/// [`MAX_NEIGHBORS`], and under a time-varying topology the neighbor
/// list is recomputed — not cached at deployment time — so the cap must
/// keep the *closest* live neighbors, whose links the attenuation model
/// prices best, rather than whichever ids happen to sort first.
pub fn nearest_first(topo: &Topology, origin: NodeId, cands: &mut [NodeId]) {
    let o = topo.positions[origin];
    let key = |n: NodeId| {
        let p = topo.positions[n];
        (p.x - o.x) * (p.x - o.x) + (p.y - o.y) * (p.y - o.y)
    };
    // The (key, id) order is total (ids are unique), so the unstable
    // sort is deterministic and matches the old keyed stable sort.
    cands.sort_unstable_by(|&a, &b| key(a).total_cmp(&key(b)).then(a.cmp(&b)));
}

/// Equal-width low/medium/high bucket of a [0, 1] fraction (§IV-B).
pub fn bucket(frac: f64) -> usize {
    let f = frac.clamp(0.0, 1.0);
    ((f * BUCKETS as f64) as usize).min(BUCKETS - 1)
}

/// Size class of a layer (small / medium / large) from its CPU and
/// memory demands — the layer half of the tabular state.
pub fn layer_class(layer: &Layer) -> usize {
    let d = layer.demand();
    // Normalize against an edge-class reference node (1 core, 4 GB).
    let score = (d.cpu / 1.0) + (d.mem / 4096.0);
    if score < 0.03 {
        0
    } else if score < 0.09 {
        1
    } else {
        2
    }
}

/// Dense DQN state for one decision step, written into a caller-owned
/// scratch array — the per-decision hot path (scheduler rounds, DQN
/// forward) featurizes without touching the heap.
pub fn state_vector_into(
    layer: &Layer,
    owner_util: [f64; 3],
    cands: &[CandidateView],
    out: &mut [f32; STATE_DIM],
) {
    let d = layer.demand();
    out[0] = d.cpu as f32;
    out[1] = (d.mem / 4096.0) as f32;
    out[2] = (d.bw / 100.0) as f32;
    for (k, u) in owner_util.iter().enumerate() {
        out[3 + k] = u.clamp(0.0, 2.0) as f32;
    }
    for i in 0..MAX_NEIGHBORS {
        let base = 6 + 3 * i;
        match cands.get(i) {
            Some(c) => {
                out[base] = c.avail_cpu as f32;
                out[base + 1] = c.avail_mem as f32;
                out[base + 2] = (c.bw_to_owner / 1000.0) as f32;
            }
            None => {
                out[base] = 0.0;
                out[base + 1] = 0.0;
                out[base + 2] = 0.0;
            }
        }
    }
}

/// Dense DQN state vector for one decision step (stack-allocated
/// convenience wrapper over [`state_vector_into`]).
pub fn state_vector(
    layer: &Layer,
    owner_util: [f64; 3],
    cands: &[CandidateView],
) -> [f32; STATE_DIM] {
    let mut out = [0.0; STATE_DIM];
    state_vector_into(layer, owner_util, cands, &mut out);
    out
}

/// Heap-allocating reference featurizer — the pre-optimization
/// implementation, kept for the hotpath bench's with/without-scratch
/// cells and pinned to [`state_vector_into`] by an equivalence test.
pub fn state_vector_vec(layer: &Layer, owner_util: [f64; 3], cands: &[CandidateView]) -> Vec<f32> {
    let d = layer.demand();
    let mut v = Vec::with_capacity(STATE_DIM);
    v.push(d.cpu as f32);
    v.push((d.mem / 4096.0) as f32);
    v.push((d.bw / 100.0) as f32);
    for u in owner_util {
        v.push(u.clamp(0.0, 2.0) as f32);
    }
    for i in 0..MAX_NEIGHBORS {
        if let Some(c) = cands.get(i) {
            v.push(c.avail_cpu as f32);
            v.push(c.avail_mem as f32);
            v.push((c.bw_to_owner / 1000.0) as f32);
        } else {
            v.extend_from_slice(&[0.0, 0.0, 0.0]);
        }
    }
    debug_assert_eq!(v.len(), STATE_DIM);
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket(0.0), 0);
        assert_eq!(bucket(0.32), 0);
        assert_eq!(bucket(0.34), 1);
        assert_eq!(bucket(0.65), 1);
        assert_eq!(bucket(0.67), 2);
        assert_eq!(bucket(1.0), 2);
        // Out-of-range clamps.
        assert_eq!(bucket(-0.5), 0);
        assert_eq!(bucket(7.0), 2);
    }

    #[test]
    fn layer_classes_spread() {
        let vgg = ModelKind::Vgg16.build();
        let classes: Vec<usize> = vgg.layers.iter().map(layer_class).collect();
        // VGG has both small (pool) and large (fc1 / late conv) layers.
        assert!(classes.contains(&0) || classes.contains(&1));
        assert!(classes.contains(&2), "{classes:?}");
    }

    #[test]
    fn state_vector_dimension_matches_python() {
        let l = &ModelKind::Rnn.build().layers[0];
        let cands: Vec<CandidateView> = (0..4)
            .map(|i| CandidateView {
                node: i,
                avail_cpu: 0.5,
                avail_mem: 0.5,
                avail_bw: 0.5,
                bw_to_owner: 100.0,
            })
            .collect();
        let v = state_vector(l, [0.1, 0.2, 0.3], &cands);
        assert_eq!(v.len(), STATE_DIM);
        assert_eq!(STATE_DIM, 36);
        assert_eq!(NUM_ACTIONS, 11);
    }

    #[test]
    fn state_vector_pads_missing_candidates() {
        let l = &ModelKind::Rnn.build().layers[0];
        let v = state_vector(l, [0.0; 3], &[]);
        // All candidate slots zero.
        assert!(v[6..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn nearest_first_orders_by_live_distance() {
        use crate::net::Pos;
        use crate::util::Rng;
        let mut rng = Rng::new(4);
        let mut topo = crate::net::Topology::generate(&mut rng, 6, 50.0, 30.0, &[100.0], 0.001);
        // Deterministic line layout: node k at x = 10k.
        for k in 0..6 {
            topo.positions[k] = Pos { x: 10.0 * k as f64, y: 0.0 };
        }
        topo.rebuild_adjacency();
        let mut cands = vec![5, 3, 1, 4, 2];
        nearest_first(&topo, 0, &mut cands);
        assert_eq!(cands, vec![1, 2, 3, 4, 5]);
        // Movement re-ranks: node 5 walks next to the origin.
        topo.positions[5] = Pos { x: 1.0, y: 0.0 };
        topo.rebuild_adjacency();
        nearest_first(&topo, 0, &mut cands);
        assert_eq!(cands, vec![5, 1, 2, 3, 4]);
        // Equidistant candidates tie-break by id.
        topo.positions[5] = topo.positions[1];
        nearest_first(&topo, 0, &mut cands);
        assert_eq!(cands, vec![1, 5, 2, 3, 4]);
    }

    #[test]
    fn scratch_featurizer_matches_allocating_reference() {
        // The zero-allocation writer must produce byte-identical features
        // to the Vec-based reference, across padding and truncation.
        let graph = ModelKind::Vgg16.build();
        for n_cands in [0usize, 1, 4, MAX_NEIGHBORS, MAX_NEIGHBORS + 5] {
            let cands: Vec<CandidateView> = (0..n_cands)
                .map(|i| CandidateView {
                    node: i,
                    avail_cpu: 0.1 + 0.07 * i as f64,
                    avail_mem: 0.9 - 0.05 * i as f64,
                    avail_bw: 0.33,
                    bw_to_owner: 100.0 + 10.0 * i as f64,
                })
                .collect();
            for layer in &graph.layers {
                let util = [0.2, 1.7, 2.5];
                let reference = state_vector_vec(layer, util, &cands);
                let fast = state_vector(layer, util, &cands);
                assert_eq!(&fast[..], &reference[..], "{} cands", n_cands);
                let mut scratch = [7.0f32; STATE_DIM]; // dirty scratch
                state_vector_into(layer, util, &cands, &mut scratch);
                assert_eq!(&scratch[..], &reference[..]);
            }
        }
    }

    #[test]
    fn state_vector_truncates_excess_candidates() {
        let l = &ModelKind::Rnn.build().layers[0];
        let cands: Vec<CandidateView> = (0..20)
            .map(|i| CandidateView {
                node: i,
                avail_cpu: 1.0,
                avail_mem: 1.0,
                avail_bw: 1.0,
                bw_to_owner: 500.0,
            })
            .collect();
        let v = state_vector(l, [0.0; 3], &cands);
        assert_eq!(v.len(), STATE_DIM);
    }
}
