//! # SROLE — Shielded Reinforcement Learning for DL training on edges
//!
//! Production-quality reproduction of *"Distributed Training for Deep
//! Learning Models On An Edge Computing Network Using Shielded
//! Reinforcement Learning"* (Sen & Shen, 2022).
//!
//! The paper schedules the partitions (layers) of DNN training jobs onto
//! a cluster of edge nodes and compares four methods:
//!
//! * **RL** — centralized RL at the cluster head;
//! * **MARL** — every edge node schedules its own jobs with local RL
//!   (action collisions possible);
//! * **SROLE-C** — MARL plus a centralized shield (paper's Algorithm 1)
//!   that detects collisions and substitutes minimal-interference safe
//!   actions;
//! * **SROLE-D** — MARL plus decentralized per-sub-cluster shields that
//!   coordinate through delegates on sub-cluster boundaries.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: edge-network substrate,
//!   discrete-event simulator, MARL agents, shields, metrics and the
//!   figure-regeneration harness.
//! * **L2 (python/compile/model.py)** — JAX compute graphs (Q-network,
//!   transformer LM) AOT-lowered to HLO text under `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (fused dense,
//!   fused causal attention) called from L2.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO text
//! artifacts through PJRT (`xla` crate, behind the `pjrt` feature; a
//! host-literal stub otherwise) and [`emu`] drives real data-parallel
//! training with them.
//!
//! Scale experiments run through [`harness`]: independent
//! `(method × cluster size × workload × seed)` scenarios across OS
//! threads with per-scenario deterministic RNG streams.

// The shield/scheduler hot paths intentionally index parallel per-node
// arrays, and Algorithm 1's signature mirrors the paper's parameters.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]
#![allow(clippy::type_complexity, clippy::field_reassign_with_default)]

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod dnn;
pub mod emu;
pub mod harness;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod rl;
pub mod runtime;
pub mod sched;
pub mod shield;
pub mod sim;
pub mod util;
pub mod workload;

pub use cluster::{ClusterSpec, EdgeNode, NodeId, ResourceKind, Resources};
pub use config::ExperimentConfig;
pub use coordinator::{Experiment, ExperimentResult, Method};
pub use dnn::{Layer, ModelGraph, ModelKind};
pub use harness::{run_parallel, Scenario, ScenarioReport, Sweep};
