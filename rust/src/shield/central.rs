//! Centralized shielding (§IV-C, Algorithm 1): one shield at the cluster
//! head observes the joint action of every agent in the cluster and
//! corrects unsafe actions with minimal interference.

use crate::cluster::Deployment;
use crate::sim::state::ResourceState;
use crate::util::NodeSet;

use super::{algorithm1, ProposedAction, Shield, ShieldOutcome, ShieldScratch, CHECK_SECS_PER_ACTION, FIX_SECS_PER_CORRECTION};

/// The SROLE-C shield.  Runs serially on the cluster head: its modeled
/// cost is linear in the number of reported actions plus the correction
/// work.  The per-round accumulators live in `scratch` and are reused
/// across rounds (allocation-free steady state).
#[derive(Debug, Default)]
pub struct CentralShield {
    /// Lifetime statistics (exposed for the figure harness).
    pub total_checked: usize,
    pub total_corrections: usize,
    pub total_collisions: usize,
    scratch: ShieldScratch,
    /// Dynamic-membership restriction: when set, safe alternatives are
    /// drawn only from this (alive) node set.  `None` (the default, and
    /// the static-deployment case) allows the whole cluster — matching
    /// the scan reference the equivalence tests pin against.
    alive: Option<NodeSet>,
}

impl CentralShield {
    pub fn new() -> CentralShield {
        CentralShield::default()
    }

    /// Restrict correction targets to `alive` nodes (the event core calls
    /// this when membership changes); `None` lifts the restriction.
    pub fn set_alive(&mut self, alive: Option<NodeSet>) {
        self.alive = alive;
    }
}

impl Shield for CentralShield {
    fn check(
        &mut self,
        proposals: &[ProposedAction],
        state: &ResourceState,
        dep: &Deployment,
        alpha: f64,
    ) -> ShieldOutcome {
        let visible: Vec<usize> = (0..proposals.len()).collect();
        let (corrections, collided) = algorithm1(
            proposals, &visible, |_| true, state, dep, alpha, self.alive.as_ref(),
            &mut self.scratch,
        );
        let collisions = collided.len();
        // The single head checks every action serially.
        let shield_secs = proposals.len() as f64 * CHECK_SECS_PER_ACTION
            + corrections.len() as f64 * FIX_SECS_PER_CORRECTION;
        self.total_checked += proposals.len();
        self.total_corrections += corrections.len();
        self.total_collisions += collisions;
        ShieldOutcome { corrections, collisions, shield_secs, checked: proposals.len() }
    }

    fn name(&self) -> &'static str {
        "srole_c"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shield::testutil::*;

    #[test]
    fn corrects_joint_overload_and_counts() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let cap = state.caps(0).cpu;
        let props = vec![
            proposal(0, 1, 0, cap * 0.55, 60.0, 1.0),
            proposal(1, 2, 0, cap * 0.55, 60.0, 1.0),
        ];
        let mut shield = CentralShield::new();
        let out = shield.check(&props, &state, &dep, 0.9);
        assert_eq!(out.collisions, 1);
        assert_eq!(out.corrections.len(), 1);
        assert!(out.shield_secs > 0.0);
        assert_eq!(shield.total_collisions, 1);
    }

    #[test]
    fn minimal_interference_untouched_when_safe() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let props = vec![
            proposal(0, 1, 0, 0.05, 20.0, 0.5),
            proposal(1, 2, 1, 0.05, 20.0, 0.5),
            proposal(2, 3, 2, 0.05, 20.0, 0.5),
        ];
        let mut shield = CentralShield::new();
        let out = shield.check(&props, &state, &dep, 0.9);
        assert!(out.corrections.is_empty(), "criterion 1: only correct on violation");
        assert_eq!(out.collisions, 0);
        assert_eq!(out.checked, 3);
    }

    #[test]
    fn alive_restriction_excludes_dead_correction_targets() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let cap = state.caps(0).cpu;
        let props = vec![
            proposal(0, 1, 0, cap * 0.55, 60.0, 1.0),
            proposal(1, 2, 0, cap * 0.55, 60.0, 1.0),
        ];
        // Unrestricted: a correction lands somewhere in the cluster.
        let mut free = CentralShield::new();
        let unrestricted = free.check(&props, &state, &dep, 0.9);
        assert_eq!(unrestricted.corrections.len(), 1);
        let chosen = unrestricted.corrections[0].1;
        // Kill every node except the overloaded target: no safe
        // alternative remains alive, so the collision must go uncorrected.
        let mut shield = CentralShield::new();
        shield.set_alive(Some(crate::util::NodeSet::from_slice(dep.n(), &[0])));
        let out = shield.check(&props, &state, &dep, 0.9);
        assert_eq!(out.collisions, 1);
        assert!(out.corrections.is_empty(), "corrected onto a dead node");
        // Reviving the previously chosen host restores the correction.
        shield.set_alive(Some(crate::util::NodeSet::from_slice(dep.n(), &[0, chosen])));
        let out = shield.check(&props, &state, &dep, 0.9);
        assert_eq!(out.corrections.len(), 1);
        assert_eq!(out.corrections[0].1, chosen);
    }

    #[test]
    fn shield_cost_scales_with_actions() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let mut shield = CentralShield::new();
        let few: Vec<_> = (0..2).map(|i| proposal(i, 1, i % 5, 0.01, 5.0, 0.1)).collect();
        let many: Vec<_> = (0..20).map(|i| proposal(i, 1, i % 5, 0.01, 5.0, 0.1)).collect();
        let t_few = shield.check(&few, &state, &dep, 0.9).shield_secs;
        let t_many = shield.check(&many, &state, &dep, 0.9).shield_secs;
        assert!(t_many > t_few * 5.0);
    }
}
