//! Scan-based reference shields — the seed's pre-index implementation,
//! kept verbatim.
//!
//! Two consumers rely on this module staying put:
//!
//! * the equivalence property tests in `rust/tests/integration.rs`, which
//!   pin the indexed hot path ([`super::algorithm1`],
//!   [`CentralShield`](super::CentralShield),
//!   [`DecentralShield`](super::DecentralShield)) to report *identical*
//!   corrections and collisions;
//! * `benches/hotpath.rs`, which measures the indexed shields against
//!   these baselines on large clusters.
//!
//! Everything here does membership via `Vec::contains` / linear
//! `position` scans, exactly as the seed did — do not "optimize" it.

use crate::cluster::{Deployment, NodeId, ResourceKind, Resources, SubClusters};
use crate::sim::state::ResourceState;

use super::{
    weight, ProposedAction, Shield, ShieldOutcome, CHECK_SECS_PER_ACTION,
    FIX_SECS_PER_CORRECTION,
};
use super::decentral::DELEGATE_RTT_SECS;

/// Pre-refactor Algorithm 1: O(proposals × nodes) membership scans,
/// `BTreeMap` bookkeeping, `Vec::remove(0)` queue.
pub fn algorithm1_scan(
    proposals: &[ProposedAction],
    visible: &[usize],
    checkable: impl Fn(NodeId) -> bool,
    state: &ResourceState,
    dep: &Deployment,
    alpha: f64,
    allowed_targets: Option<&[NodeId]>,
) -> (Vec<(usize, NodeId)>, Vec<NodeId>) {
    // Virtual placement: extra demand per node from the visible proposals.
    let mut extra: Vec<Resources> = vec![Resources::default(); dep.n()];
    // Which proposals currently land on each node (by visible index).
    let mut on_node: Vec<Vec<usize>> = vec![Vec::new(); dep.n()];
    // Current (possibly corrected) target per proposal idx.
    let mut cur_target: std::collections::BTreeMap<usize, NodeId> = Default::default();
    for &vi in visible {
        let p = &proposals[vi];
        extra[p.target] = extra[p.target].add(&p.demand);
        on_node[p.target].push(vi);
        cur_target.insert(p.idx, p.target);
    }

    let util_with = |node: NodeId, extra: &Resources, k: ResourceKind| -> f64 {
        state.caps(node).utilization(&state.demand(node).add(extra), k)
    };
    let node_overloaded = |node: NodeId, extra: &[Resources]| -> bool {
        ResourceKind::ALL.iter().any(|&k| util_with(node, &extra[node], k) > alpha)
    };

    let mut corrections: Vec<(usize, NodeId)> = Vec::new();
    let mut collided: Vec<NodeId> = Vec::new();

    let mut nodes: Vec<NodeId> =
        on_node.iter().enumerate().filter(|(_, v)| !v.is_empty()).map(|(n, _)| n).collect();
    nodes.sort_unstable();
    for node in nodes {
        if !checkable(node) {
            continue;
        }
        if !node_overloaded(node, &extra) {
            continue;
        }
        collided.push(node);

        let caps = *state.caps(node);
        on_node[node].sort_by(|&a, &b| {
            let wa = weight(&proposals[a].demand, &caps);
            let wb = weight(&proposals[b].demand, &caps);
            wb.partial_cmp(&wa).unwrap()
        });

        let mut cands: Vec<NodeId> = dep
            .cluster_neighbors(node)
            .into_iter()
            .filter(|&c| c != node)
            .filter(|&c| allowed_targets.map(|a| a.contains(&c)).unwrap_or(true))
            .collect();
        cands.sort_by(|&a, &b| {
            let ua = state.caps(a).combined_utilization(&state.demand(a).add(&extra[a]));
            let ub = state.caps(b).combined_utilization(&state.demand(b).add(&extra[b]));
            ua.partial_cmp(&ub).unwrap()
        });

        let mut queue: Vec<usize> = on_node[node].clone();
        while node_overloaded(node, &extra) && !queue.is_empty() {
            let vi = queue.remove(0);
            let p = &proposals[vi];
            let safe = cands.iter().copied().find(|&c| {
                ResourceKind::ALL
                    .iter()
                    .all(|&k| util_with(c, &extra[c].add(&p.demand), k) <= alpha)
            });
            if let Some(new_target) = safe {
                extra[node] = extra[node].sub(&p.demand);
                extra[new_target] = extra[new_target].add(&p.demand);
                corrections.push((p.idx, new_target));
                cur_target.insert(p.idx, new_target);
            }
        }
    }
    (corrections, collided)
}

/// Scan-based SROLE-C shield (seed implementation).
#[derive(Debug, Default)]
pub struct CentralShieldScan {
    pub total_checked: usize,
    pub total_corrections: usize,
    pub total_collisions: usize,
}

impl CentralShieldScan {
    pub fn new() -> CentralShieldScan {
        CentralShieldScan::default()
    }
}

impl Shield for CentralShieldScan {
    fn check(
        &mut self,
        proposals: &[ProposedAction],
        state: &ResourceState,
        dep: &Deployment,
        alpha: f64,
    ) -> ShieldOutcome {
        let visible: Vec<usize> = (0..proposals.len()).collect();
        let (corrections, collided) =
            algorithm1_scan(proposals, &visible, |_| true, state, dep, alpha, None);
        let collisions = collided.len();
        let shield_secs = proposals.len() as f64 * CHECK_SECS_PER_ACTION
            + corrections.len() as f64 * FIX_SECS_PER_CORRECTION;
        self.total_checked += proposals.len();
        self.total_corrections += corrections.len();
        self.total_collisions += collisions;
        ShieldOutcome { corrections, collisions, shield_secs, checked: proposals.len() }
    }

    fn name(&self) -> &'static str {
        "srole_c_scan"
    }
}

// Seed-style scan lookups over the SubClusters raw partition.
fn scan_sub_of(subs: &SubClusters, node: NodeId) -> usize {
    let idx = subs.members.iter().position(|&m| m == node).expect("node not a member");
    subs.assignment[idx]
}

fn scan_members_of(subs: &SubClusters, sub: usize) -> Vec<NodeId> {
    subs.members
        .iter()
        .zip(&subs.assignment)
        .filter(|(_, &a)| a == sub)
        .map(|(&m, _)| m)
        .collect()
}

fn scan_boundary_nodes(subs: &SubClusters) -> Vec<NodeId> {
    let mut out: Vec<NodeId> = Vec::new();
    for (_, nodes) in &subs.boundaries {
        for &n in nodes {
            if !out.contains(&n) {
                out.push(n);
            }
        }
    }
    out.sort_unstable();
    out
}

/// Scan-based SROLE-D shield (seed implementation): every membership,
/// boundary and allowed-target query is a `Vec` scan.
pub struct DecentralShieldScan {
    pub subs: SubClusters,
    pub total_checked: usize,
    pub total_corrections: usize,
    pub total_collisions: usize,
    pub delegate_rounds: usize,
}

impl DecentralShieldScan {
    pub fn new(dep: &Deployment, cluster_members: &[NodeId], k: usize) -> DecentralShieldScan {
        let subs = SubClusters::build(cluster_members, &dep.topo, k);
        DecentralShieldScan {
            subs,
            total_checked: 0,
            total_corrections: 0,
            total_collisions: 0,
            delegate_rounds: 0,
        }
    }
}

impl Shield for DecentralShieldScan {
    fn check(
        &mut self,
        proposals: &[ProposedAction],
        state: &ResourceState,
        dep: &Deployment,
        alpha: f64,
    ) -> ShieldOutcome {
        let boundary = scan_boundary_nodes(&self.subs);
        let is_member = |n: NodeId| self.subs.members.contains(&n);

        let mut corrections: Vec<(usize, NodeId)> = Vec::new();
        let mut collided_nodes: Vec<NodeId> = Vec::new();
        let mut per_shield_secs = vec![0.0f64; self.subs.k];

        // Phase 1: per-sub-cluster shields over interior targets.
        for s in 0..self.subs.k {
            let visible: Vec<usize> = proposals
                .iter()
                .enumerate()
                .filter(|(_, p)| {
                    is_member(p.agent)
                        && scan_sub_of(&self.subs, p.agent) == s
                        && !boundary.contains(&p.target)
                })
                .map(|(i, _)| i)
                .collect();
            let local_members = scan_members_of(&self.subs, s);
            let checkable =
                |n: NodeId| local_members.contains(&n) && !boundary.contains(&n);
            let (corr, coll) = algorithm1_scan(
                proposals,
                &visible,
                checkable,
                state,
                dep,
                alpha,
                Some(&local_members),
            );
            per_shield_secs[s] += visible.len() as f64 * CHECK_SECS_PER_ACTION
                + corr.len() as f64 * FIX_SECS_PER_CORRECTION;
            self.total_checked += visible.len();
            corrections.extend(corr);
            for n in coll {
                if !collided_nodes.contains(&n) {
                    collided_nodes.push(n);
                }
            }
        }

        // Phase 2: delegates per neighboring pair.
        let mut delegate_secs = 0.0f64;
        for ((a, b), nodes) in &self.subs.boundaries.clone() {
            let visible: Vec<usize> = proposals
                .iter()
                .enumerate()
                .filter(|(_, p)| {
                    if !is_member(p.agent) {
                        return false;
                    }
                    let s = scan_sub_of(&self.subs, p.agent);
                    (s == *a || s == *b) && nodes.contains(&p.target)
                })
                .map(|(i, _)| i)
                .collect();
            if visible.is_empty() {
                continue;
            }
            let checkable = |n: NodeId| nodes.contains(&n);
            let allowed: Vec<NodeId> = {
                let mut v = scan_members_of(&self.subs, *a);
                v.extend(scan_members_of(&self.subs, *b));
                v
            };
            let (corr, coll) = algorithm1_scan(
                proposals, &visible, checkable, state, dep, alpha, Some(&allowed),
            );
            let pair_secs = 2.0 * DELEGATE_RTT_SECS
                + visible.len() as f64 * CHECK_SECS_PER_ACTION
                + corr.len() as f64 * FIX_SECS_PER_CORRECTION;
            delegate_secs = delegate_secs.max(pair_secs);
            self.delegate_rounds += 1;
            self.total_checked += visible.len();
            for (idx, tgt) in corr {
                if !corrections.iter().any(|(i, _)| *i == idx) {
                    corrections.push((idx, tgt));
                }
            }
            for n in coll {
                if !collided_nodes.contains(&n) {
                    collided_nodes.push(n);
                }
            }
        }

        let shield_secs =
            per_shield_secs.iter().cloned().fold(0.0, f64::max) + delegate_secs;
        let collisions = collided_nodes.len();
        self.total_corrections += corrections.len();
        self.total_collisions += collisions;
        ShieldOutcome { corrections, collisions, shield_secs, checked: proposals.len() }
    }

    fn name(&self) -> &'static str {
        "srole_d_scan"
    }
}
