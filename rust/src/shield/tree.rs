//! Hierarchical shield tree (shields-of-shields): regional
//! [`DecentralShield`](super::DecentralShield)s grouped under
//! super-shields.
//!
//! The paper's scaling argument — one central shield bottlenecks, so
//! shields decentralize per region with boundary coordination — stops
//! one level short: with hundreds of cluster shields, the *boundary*
//! coordination itself becomes the serial term.  The tree adds one more
//! level: clusters are grouped under super-shields by geographic
//! proximity (`tree_fanout` clusters per group, grid-seeded over the
//! cluster centroids exactly like the sub-cluster partitioner seeds
//! regions over member cells), boundary pairs *interior* to a group are
//! checked group-locally, and only pairs crossing group boundaries
//! escalate to the root.  `coordinator::shard` uses the grouping to
//! bucket cross-region events and handle groups concurrently; the
//! `cross_cluster` knob uses the boundary-pair visible sets to shield
//! placements that leave their home cluster.
//!
//! The grouping is *static*: built once from the t = 0 cluster
//! centroids and topology adjacency.  Under mobility the live adjacency
//! drifts away from the build-time pairs — cross-cluster rescue
//! therefore requires a candidate to be a *current* topology neighbor
//! AND inside the build-time pair visible set, so the tree never
//! launders a placement the boundary shields could not have seen.
//!
//! `tree_fanout = 0` disables the tree entirely (the flat
//! `DecentralShield` + serial driver is the pinned reference);
//! `RunMetrics` is byte-identical for every fanout as long as
//! `cross_cluster` stays off (pinned in `harness` and
//! `coordinator::shard` tests).

use crate::cluster::subcluster::farthest_point_assign;
use crate::cluster::{Deployment, NodeId};
use crate::net::{Pos, SpatialGrid};
use crate::util::NodeSet;

/// The super-shield grouping of a deployment's clusters, plus the
/// cluster-level boundary pairs the groups coordinate over.
#[derive(Debug, Clone)]
pub struct ShieldTree {
    /// The `tree_fanout` the tree was built with (≥ 1).
    pub fanout: usize,
    /// Number of super-shield groups (≤ ceil(clusters / fanout);
    /// degenerate centroid layouts collapse to fewer).
    pub n_groups: usize,
    /// `group_of[cluster]` = super-shield group of that cluster.
    pub group_of: Vec<usize>,
    /// Clusters per group, ascending cluster order.
    pub groups: Vec<Vec<usize>>,
    /// Adjacent cluster pairs `(a, b)`, `a < b`, ascending: clusters
    /// are adjacent when some node of one has a topology neighbor in
    /// the other (at build time).
    pub pairs: Vec<(usize, usize)>,
    /// Per pair (parallel to `pairs`): the nodes of either cluster with
    /// a build-time topology neighbor in the other — the visible set
    /// the pair's boundary shields coordinate over.
    pair_visible: Vec<NodeSet>,
    /// `pairs_of[cluster]` = indices into `pairs` involving the cluster.
    pairs_of: Vec<Vec<usize>>,
}

impl ShieldTree {
    /// Group `dep`'s clusters under super-shields, at most `fanout`
    /// clusters per group (`fanout` is clamped to ≥ 1).
    ///
    /// Grouping is grid-seeded over the cluster centroids, reusing
    /// [`SpatialGrid`] the same way the sub-cluster partitioner does
    /// over member positions: centroids bin into range-sized cells,
    /// occupied-cell centroids are farthest-point-seeded down to
    /// `ceil(clusters / fanout)` seeds, each cell joins its nearest
    /// seed, and every cluster inherits its cell's group.  Degenerate
    /// layouts (one cluster, coincident centroids, fanout beyond the
    /// cluster count) collapse to fewer groups instead of panicking.
    pub fn build(dep: &Deployment, fanout: usize) -> ShieldTree {
        let fanout = fanout.max(1);
        let n_clusters = dep.clusters.len();
        let centroids: Vec<Pos> = dep
            .clusters
            .iter()
            .map(|c| {
                let (sx, sy) = c.members.iter().fold((0.0, 0.0), |(x, y), &m| {
                    (x + dep.topo.positions[m].x, y + dep.topo.positions[m].y)
                });
                let n = c.members.len().max(1) as f64;
                Pos { x: sx / n, y: sy / n }
            })
            .collect();
        let k_groups = n_clusters.div_ceil(fanout).max(1);

        // Grid-seeded grouping: near-coincident centroids share a cell
        // (and therefore a group), exactly like the grid partitioner's
        // cell-merge over member positions.
        let grid = SpatialGrid::build(&centroids, dep.topo.range.max(1e-9));
        let cells: Vec<(Vec<usize>, (f64, f64))> = grid
            .cells()
            .map(|(_, items)| {
                let (sx, sy) = items
                    .iter()
                    .fold((0.0, 0.0), |(x, y), &i| (x + centroids[i].x, y + centroids[i].y));
                let c = (sx / items.len() as f64, sy / items.len() as f64);
                (items.to_vec(), c)
            })
            .collect();
        let cell_centroids: Vec<(f64, f64)> = cells.iter().map(|(_, c)| *c).collect();
        let (cell_group, n_groups) = farthest_point_assign(&cell_centroids, k_groups);
        let mut group_of = vec![0usize; n_clusters];
        for ((clusters, _), &g) in cells.iter().zip(&cell_group) {
            for &ci in clusters {
                group_of[ci] = g;
            }
        }
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        for (ci, &g) in group_of.iter().enumerate() {
            groups[g].push(ci);
        }

        // Cluster-adjacency pairs + visible sets from the build-time
        // topology: every in-range edge crossing a cluster boundary
        // makes its endpoints' clusters adjacent and both endpoints
        // visible to the pair's boundary shields.  O(n·k).
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        for m in 0..dep.n() {
            let ci = dep.cluster_of(m);
            for &nb in dep.topo.neighbors_ref(m) {
                let cj = dep.cluster_of(nb);
                if ci != cj {
                    pairs.push((ci.min(cj), ci.max(cj)));
                }
            }
        }
        pairs.sort_unstable();
        pairs.dedup();
        let mut pair_visible: Vec<NodeSet> =
            pairs.iter().map(|_| NodeSet::with_universe(dep.n())).collect();
        let mut pairs_of: Vec<Vec<usize>> = vec![Vec::new(); n_clusters];
        for (pi, &(a, b)) in pairs.iter().enumerate() {
            pairs_of[a].push(pi);
            pairs_of[b].push(pi);
        }
        for m in 0..dep.n() {
            let ci = dep.cluster_of(m);
            for &nb in dep.topo.neighbors_ref(m) {
                let cj = dep.cluster_of(nb);
                if ci != cj {
                    let pi = pair_index_in(&pairs, ci, cj).expect("pair recorded above");
                    pair_visible[pi].insert(m);
                    pair_visible[pi].insert(nb);
                }
            }
        }

        ShieldTree { fanout, n_groups, group_of, groups, pairs, pair_visible, pairs_of }
    }

    /// Super-shield group of `cluster`.
    #[inline]
    pub fn group_of_cluster(&self, cluster: usize) -> usize {
        self.group_of[cluster]
    }

    /// Clusters of one group, ascending.
    #[inline]
    pub fn clusters_of(&self, group: usize) -> &[usize] {
        &self.groups[group]
    }

    /// Whether the cluster pair is *interior* to one super-shield group
    /// (checked group-locally) rather than crossing group boundaries
    /// (escalates to the tree root).
    #[inline]
    pub fn interior(&self, a: usize, b: usize) -> bool {
        self.group_of[a] == self.group_of[b]
    }

    /// Index into `pairs` of the adjacent cluster pair, if adjacent.
    #[inline]
    pub fn pair_index(&self, a: usize, b: usize) -> Option<usize> {
        pair_index_in(&self.pairs, a, b)
    }

    /// Visible set of pair `pi`: the nodes of either cluster with a
    /// build-time topology neighbor in the other.
    #[inline]
    pub fn pair_visible_set(&self, pi: usize) -> &NodeSet {
        &self.pair_visible[pi]
    }

    /// Indices into `pairs` involving `cluster`, ascending.
    #[inline]
    pub fn pairs_of_cluster(&self, cluster: usize) -> &[usize] {
        &self.pairs_of[cluster]
    }

    /// Pick the cross-cluster rescue target for `owner` among
    /// `candidates` (its alive out-of-cluster topology neighbors,
    /// ascending — see `sched::cross_candidates_into`): the first
    /// candidate inside a boundary-pair visible set whose pair is
    /// *interior* to `owner`'s super-shield group, else the first in
    /// any pair's visible set (an escalation past the group to the
    /// root).  Returns `(target, escalated)`; `None` when no candidate
    /// is visible to any boundary pair.
    pub fn cross_rescue_target(
        &self,
        dep: &Deployment,
        owner: NodeId,
        candidates: &[NodeId],
    ) -> Option<(NodeId, bool)> {
        let co = dep.cluster_of(owner);
        let mut escalated: Option<NodeId> = None;
        for &c in candidates {
            let Some(pi) = self.pair_index(co, dep.cluster_of(c)) else {
                continue;
            };
            if !self.pair_visible[pi].contains(c) || !self.pair_visible[pi].contains(owner) {
                continue;
            }
            if self.interior(co, dep.cluster_of(c)) {
                return Some((c, false));
            }
            if escalated.is_none() {
                escalated = Some(c);
            }
        }
        escalated.map(|c| (c, true))
    }
}

/// Binary search for the normalized pair `(min, max)` in the sorted,
/// deduplicated pair list.
#[inline]
fn pair_index_in(pairs: &[(usize, usize)], a: usize, b: usize) -> Option<usize> {
    let key = (a.min(b), a.max(b));
    pairs.binary_search(&key).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CONTAINER_PROFILE;
    use crate::util::Rng;

    fn dep(n: usize, cluster_size: usize, seed: u64) -> Deployment {
        let mut rng = Rng::new(seed);
        Deployment::generate(&mut rng, n, cluster_size, &CONTAINER_PROFILE)
    }

    fn assert_well_formed(tree: &ShieldTree, dep: &Deployment) {
        assert_eq!(tree.group_of.len(), dep.clusters.len());
        assert_eq!(tree.groups.len(), tree.n_groups);
        let mut covered = 0usize;
        for (g, clusters) in tree.groups.iter().enumerate() {
            assert!(!clusters.is_empty(), "no fabricated empty group {g}");
            assert!(clusters.windows(2).all(|w| w[0] < w[1]), "ascending clusters");
            for &ci in clusters {
                assert_eq!(tree.group_of_cluster(ci), g);
            }
            covered += clusters.len();
        }
        assert_eq!(covered, dep.clusters.len(), "every cluster in exactly one group");
        for (pi, &(a, b)) in tree.pairs.iter().enumerate() {
            assert!(a < b);
            assert_eq!(tree.pair_index(a, b), Some(pi));
            assert_eq!(tree.pair_index(b, a), Some(pi), "pair lookup is symmetric");
            assert!(tree.pairs_of_cluster(a).contains(&pi));
            assert!(tree.pairs_of_cluster(b).contains(&pi));
            let vis = tree.pair_visible_set(pi);
            assert!(vis.len() >= 2, "an adjacent pair has ≥ 1 crossing edge");
            for m in vis.iter() {
                let cm = dep.cluster_of(m);
                assert!(cm == a || cm == b, "visible nodes belong to the pair");
                let other = if cm == a { b } else { a };
                assert!(
                    dep.topo.neighbors_ref(m).iter().any(|&nb| dep.cluster_of(nb) == other),
                    "visible node {m} has no crossing neighbor"
                );
            }
        }
    }

    #[test]
    fn single_cluster_is_one_group_with_no_pairs() {
        let d = dep(8, 8, 3);
        assert_eq!(d.clusters.len(), 1);
        let tree = ShieldTree::build(&d, 4);
        assert_eq!(tree.n_groups, 1);
        assert_eq!(tree.clusters_of(0), &[0]);
        assert!(tree.pairs.is_empty());
        assert_well_formed(&tree, &d);
    }

    #[test]
    fn fanout_beyond_cluster_count_collapses_to_one_group() {
        let d = dep(40, 10, 5);
        assert_eq!(d.clusters.len(), 4);
        let tree = ShieldTree::build(&d, 100);
        assert_eq!(tree.n_groups, 1, "ceil(4/100) = 1 group");
        assert_eq!(tree.clusters_of(0), &[0, 1, 2, 3]);
        for &(a, b) in &tree.pairs {
            assert!(tree.interior(a, b), "one group: every pair is interior");
        }
        assert_well_formed(&tree, &d);
    }

    #[test]
    fn coincident_cluster_centroids_collapse_without_panicking() {
        // Stack every node on one point: all centroids coincide, the
        // centroid grid has a single occupied cell, and the grouping
        // must collapse to one group instead of panicking or
        // fabricating empty ones.
        let mut d = dep(40, 10, 7);
        for p in &mut d.topo.positions {
            *p = Pos { x: 5.0, y: 5.0 };
        }
        d.refresh_adjacency();
        let tree = ShieldTree::build(&d, 2);
        assert_eq!(tree.n_groups, 1, "coincident centroids share a cell");
        assert_well_formed(&tree, &d);
        // Everything in range of everything: all cluster pairs adjacent.
        assert_eq!(tree.pairs.len(), 4 * 3 / 2);
    }

    #[test]
    fn fanout_one_gives_each_cluster_its_own_group_when_spread() {
        // Centroids far enough apart for distinct grid cells.
        let d = dep(60, 10, 11);
        let tree = ShieldTree::build(&d, 1);
        assert!(tree.n_groups >= 1 && tree.n_groups <= d.clusters.len());
        assert_well_formed(&tree, &d);
        // fanout 0 clamps to 1 and is identical.
        let t0 = ShieldTree::build(&d, 0);
        assert_eq!(t0.group_of, tree.group_of);
        assert_eq!(t0.fanout, 1);
    }

    #[test]
    fn build_is_deterministic() {
        let d = dep(80, 10, 13);
        let a = ShieldTree::build(&d, 3);
        let b = ShieldTree::build(&d, 3);
        assert_eq!(a.group_of, b.group_of);
        assert_eq!(a.pairs, b.pairs);
        assert_well_formed(&a, &d);
    }

    #[test]
    fn cross_rescue_prefers_interior_pairs_and_is_deterministic() {
        let d = dep(40, 10, 17);
        let tree = ShieldTree::build(&d, 2);
        // Find any owner with cross-cluster neighbors.
        let mut checked = 0usize;
        for owner in 0..d.n() {
            let co = d.cluster_of(owner);
            let candidates: Vec<NodeId> = d
                .topo
                .neighbors_ref(owner)
                .iter()
                .copied()
                .filter(|&nb| d.cluster_of(nb) != co)
                .collect();
            let Some((t, escalated)) = tree.cross_rescue_target(&d, owner, &candidates)
            else {
                continue;
            };
            checked += 1;
            assert!(candidates.contains(&t));
            assert_ne!(d.cluster_of(t), co);
            assert_eq!(escalated, !tree.interior(co, d.cluster_of(t)));
            if !escalated {
                // Interior wins over any earlier escalated candidate.
                let first_interior = candidates
                    .iter()
                    .copied()
                    .find(|&c| {
                        tree.pair_index(co, d.cluster_of(c)).is_some_and(|pi| {
                            tree.pair_visible_set(pi).contains(c)
                                && tree.pair_visible_set(pi).contains(owner)
                        }) && tree.interior(co, d.cluster_of(c))
                    })
                    .unwrap();
                assert_eq!(t, first_interior);
            }
            // Deterministic.
            assert_eq!(tree.cross_rescue_target(&d, owner, &candidates), Some((t, escalated)));
        }
        assert!(checked > 0, "no node ever had a visible cross-cluster neighbor");
    }
}
