//! Shielding (§IV-C, §IV-D): collision detection and minimal-interference
//! safe-action substitution on top of MARL.
//!
//! A shield observes the *joint action* of one decision round before it
//! is applied.  If the joint action would drive any edge node's
//! per-resource utilization above α, the shield reassigns the
//! highest-demand-weight layers to nearby under-utilized nodes
//! (Algorithm 1) and notifies the owning agents with the −κ penalty.
//!
//! * [`central::CentralShield`] — one shield at the cluster head sees
//!   every action (SROLE-C).
//! * [`decentral::DecentralShield`] — one shield per sub-cluster plus
//!   delegate checks on sub-cluster boundaries (SROLE-D).
//! * [`tree::ShieldTree`] — regional shields grouped under
//!   super-shields (`tree_fanout` knob): group-local boundary checks,
//!   root escalation only across groups, and the visible sets behind
//!   opt-in cross-cluster placement.

pub mod central;
pub mod decentral;
pub mod reference;
pub mod tree;

pub use central::CentralShield;
pub use decentral::DecentralShield;
pub use tree::ShieldTree;

use crate::cluster::{Deployment, NodeId, ResourceKind, Resources};
use crate::sim::state::ResourceState;
use crate::util::NodeSet;

/// Per-action shield-check cost (seconds): one utilization evaluation
/// against the reporting edge's state, on cluster-head-class hardware.
pub const CHECK_SECS_PER_ACTION: f64 = 0.0015;
/// Cost of synthesizing one safe action (ranking + candidate scan).
pub const FIX_SECS_PER_CORRECTION: f64 = 0.004;

/// One agent's proposed assignment of one layer in the current round.
#[derive(Debug, Clone)]
pub struct ProposedAction {
    /// Index of the proposal in the round (stable identifier).
    pub idx: usize,
    /// The deciding agent (job owner).
    pub agent: NodeId,
    pub job: usize,
    pub layer_id: usize,
    /// Estimated demand of the layer.
    pub demand: Resources,
    /// Proposed host edge.
    pub target: NodeId,
}

/// The shield's verdict for a round.
#[derive(Debug, Clone, Default)]
pub struct ShieldOutcome {
    /// `(proposal idx, replacement target)` — the κ-penalized actions.
    pub corrections: Vec<(usize, NodeId)>,
    /// Action collisions detected pre-correction (per overloaded node).
    pub collisions: usize,
    /// Modeled wall-clock the shielding step would take.
    pub shield_secs: f64,
    /// Number of actions examined.
    pub checked: usize,
}

/// A shield checks one round's joint action against the live state.
///
/// # Example
///
/// ```
/// use srole::cluster::{Deployment, Resources, CONTAINER_PROFILE};
/// use srole::shield::{CentralShield, DecentralShield, ProposedAction, Shield};
/// use srole::sim::ResourceState;
/// use srole::util::Rng;
///
/// let mut rng = Rng::new(7);
/// let dep = Deployment::generate(&mut rng, 10, 5, &CONTAINER_PROFILE);
/// let state = ResourceState::new(&dep);
/// // Two agents pile heavy layers onto node 0 in the same round —
/// // neither sees the other's pick (the action-collision source).
/// let cap = *state.caps(0);
/// let proposals: Vec<ProposedAction> = (0..2)
///     .map(|i| ProposedAction {
///         idx: i,
///         agent: dep.clusters[0].members[i],
///         job: i,
///         layer_id: 0,
///         demand: Resources::new(cap.cpu * 0.8, cap.mem * 0.3, 1.0),
///         target: 0,
///     })
///     .collect();
/// // SROLE-C: one shield at the cluster head sees the whole round.
/// let mut central = CentralShield::new();
/// let out = central.check(&proposals, &state, &dep, 0.9);
/// assert_eq!(out.checked, 2);
/// assert!(out.collisions >= 1, "1.6 CPU on one node must collide at α = 0.9");
/// // SROLE-D: same contract, one shield per sub-cluster + delegates.
/// let mut decentral = DecentralShield::new(&dep, &dep.clusters[0].members, 2);
/// assert_eq!(decentral.check(&proposals, &state, &dep, 0.9).checked, 2);
/// ```
pub trait Shield {
    fn check(
        &mut self,
        proposals: &[ProposedAction],
        state: &ResourceState,
        dep: &Deployment,
        alpha: f64,
    ) -> ShieldOutcome;

    fn name(&self) -> &'static str;
}

/// Reusable per-shield buffers for [`algorithm1`]: dense per-node load
/// accumulators and proposal lists, sized to the deployment once and
/// cleaned incrementally (only the nodes actually touched last round),
/// so a shield check costs O(proposals + corrections·candidates) rather
/// than O(proposals × nodes).
#[derive(Debug, Default)]
pub struct ShieldScratch {
    /// Virtual extra demand per node from the visible proposals (plus
    /// any corrections applied so far this round).
    extra: Vec<Resources>,
    /// Visible-proposal indices currently landing on each node.
    on_node: Vec<Vec<usize>>,
    /// Nodes whose `extra`/`on_node` entries need resetting next round.
    dirty: Vec<NodeId>,
}

impl ShieldScratch {
    /// Prepare for a round over `n` nodes: grow the tables if needed and
    /// reset only the entries the previous round touched.
    fn begin(&mut self, n: usize) {
        if self.extra.len() < n {
            self.extra.resize(n, Resources::default());
            self.on_node.resize_with(n, Vec::new);
        }
        for &d in &self.dirty {
            self.extra[d] = Resources::default();
            self.on_node[d].clear();
        }
        self.dirty.clear();
    }
}

/// Shared core of Algorithm 1, scoped to a set of *checkable* nodes and
/// the subset of proposals the invoking shield can see.
///
/// Returns `(corrections, collided_nodes)`.  The virtual state is
/// `state` plus every proposal in `visible`; safe alternatives are
/// searched among `dep` cluster-neighbors of the overloaded node
/// restricted to `allowed_targets` (None = whole cluster of the node).
///
/// This is the indexed rewrite of the seed's scan-based implementation
/// (kept verbatim in [`reference::algorithm1_scan`]): membership tests
/// are O(1) [`NodeSet`] lookups, the per-node accumulators live in
/// `scratch` across rounds, and the layer queue walks by cursor instead
/// of `Vec::remove(0)`.  Output is bit-identical to the reference —
/// pinned by property tests in `rust/tests/integration.rs`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn algorithm1(
    proposals: &[ProposedAction],
    visible: &[usize],
    checkable: impl Fn(NodeId) -> bool,
    state: &ResourceState,
    dep: &Deployment,
    alpha: f64,
    allowed_targets: Option<&NodeSet>,
    scratch: &mut ShieldScratch,
) -> (Vec<(usize, NodeId)>, Vec<NodeId>) {
    scratch.begin(dep.n());
    // Virtual placement of the visible proposals.
    let mut nodes: Vec<NodeId> = Vec::with_capacity(visible.len());
    for &vi in visible {
        let p = &proposals[vi];
        if scratch.on_node[p.target].is_empty() {
            nodes.push(p.target);
            scratch.dirty.push(p.target);
        }
        scratch.extra[p.target] = scratch.extra[p.target].add(&p.demand);
        scratch.on_node[p.target].push(vi);
    }
    nodes.sort_unstable();

    let util_with = |node: NodeId, extra: &Resources, k: ResourceKind| -> f64 {
        state.caps(node).utilization(&state.demand(node).add(extra), k)
    };

    let mut corrections: Vec<(usize, NodeId)> = Vec::new();
    let mut collided: Vec<NodeId> = Vec::new();

    // Line 4: for each edge node that received proposals and is checkable.
    for node in nodes {
        if !checkable(node) {
            continue;
        }
        let overloaded = |extra: &[Resources]| {
            ResourceKind::ALL.iter().any(|&k| util_with(node, &extra[node], k) > alpha)
        };
        if !overloaded(&scratch.extra) {
            continue;
        }
        // Pre-correction overload from the joint action = one collision
        // event on this node in this round (the quantity Fig 8 counts);
        // callers de-duplicate by node across shield phases.
        collided.push(node);

        // Line 6: rank assigned layers by resource-demand weight ω
        // (Eq. 3) in descending order.
        let caps = *state.caps(node);
        scratch.on_node[node].sort_by(|&a, &b| {
            let wa = weight(&proposals[a].demand, &caps);
            let wb = weight(&proposals[b].demand, &caps);
            wb.partial_cmp(&wa).unwrap()
        });

        // Candidate alternatives: nearby edges of the overloaded node,
        // ordered once by combined virtual utilization ascending (the
        // paper ranks per overloaded node, not per moved layer).  The
        // sort key is precomputed — the virtual state does not change
        // while sorting.
        let mut cands: Vec<(f64, NodeId)> = dep
            .cluster_neighbors_ref(node)
            .iter()
            .copied()
            .filter(|&c| c != node)
            .filter(|&c| allowed_targets.map(|a| a.contains(c)).unwrap_or(true))
            .map(|c| {
                let u = state
                    .caps(c)
                    .combined_utilization(&state.demand(c).add(&scratch.extra[c]));
                (u, c)
            })
            .collect();
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

        // Line 8: while overloaded, move the top layer elsewhere
        // (cursor walk; the ranked list is not mutated).
        let mut qi = 0usize;
        while overloaded(&scratch.extra) && qi < scratch.on_node[node].len() {
            let vi = scratch.on_node[node][qi];
            qi += 1;
            let p = &proposals[vi];
            let safe = cands.iter().map(|&(_, c)| c).find(|&c| {
                ResourceKind::ALL
                    .iter()
                    .all(|&k| util_with(c, &scratch.extra[c].add(&p.demand), k) <= alpha)
            });
            if let Some(new_target) = safe {
                // Move the layer in the virtual state.
                scratch.extra[node] = scratch.extra[node].sub(&p.demand);
                if scratch.on_node[new_target].is_empty() {
                    // First write to a pure correction target: mark it
                    // for cleanup (duplicates are harmless).
                    scratch.dirty.push(new_target);
                }
                scratch.extra[new_target] = scratch.extra[new_target].add(&p.demand);
                corrections.push((p.idx, new_target));
            }
            // If no safe host exists the layer stays (the overload will be
            // visible at execution) — matches the paper's residual unsafe
            // actions.
        }
    }
    (corrections, collided)
}

/// Resource-demand weight ω(l) = Π_k b_k(l)/C_k(d) (Eq. 3).
pub(crate) fn weight(demand: &Resources, caps: &Resources) -> f64 {
    (demand.cpu / caps.cpu) * (demand.mem / caps.mem) * (demand.bw / caps.bw)
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::cluster::{Deployment, CONTAINER_PROFILE};
    use crate::util::Rng;

    pub fn small_dep() -> Deployment {
        let mut rng = Rng::new(11);
        Deployment::generate(&mut rng, 5, 5, &CONTAINER_PROFILE)
    }

    pub fn proposal(idx: usize, agent: NodeId, target: NodeId, cpu: f64, mem: f64, bw: f64) -> ProposedAction {
        ProposedAction {
            idx,
            agent,
            job: 0,
            layer_id: idx,
            demand: Resources { cpu, mem, bw },
            target,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;
    use crate::sim::state::ResourceState;

    #[test]
    fn weight_formula() {
        let caps = Resources::new(1.0, 1000.0, 100.0);
        let d = Resources::new(0.5, 500.0, 50.0);
        assert!((weight(&d, &caps) - 0.125).abs() < 1e-12);
    }

    #[test]
    fn algorithm1_no_overload_no_action() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let props = vec![proposal(0, 0, 1, 0.05, 50.0, 1.0)];
        let mut scratch = ShieldScratch::default();
        let (corr, coll) =
            algorithm1(&props, &[0], |_| true, &state, &dep, 0.9, None, &mut scratch);
        assert!(corr.is_empty());
        assert!(coll.is_empty());
    }

    #[test]
    fn algorithm1_detects_and_fixes_collision() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let target = 0usize;
        let cap = state.caps(target).cpu;
        // Two agents both pile CPU onto node 0 past alpha.
        let props = vec![
            proposal(0, 1, target, cap * 0.6, 50.0, 1.0),
            proposal(1, 2, target, cap * 0.6, 50.0, 1.0),
        ];
        let (corr, coll) = algorithm1(
            &props, &[0, 1], |_| true, &state, &dep, 0.9, None,
            &mut ShieldScratch::default(),
        );
        assert_eq!(coll.len(), 1);
        assert_eq!(corr.len(), 1, "one layer moved suffices");
        let (_, new_target) = corr[0];
        assert_ne!(new_target, target);
    }

    #[test]
    fn algorithm1_moves_highest_weight_first() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let target = 0usize;
        let cap = state.caps(target).cpu;
        let heavy = proposal(0, 1, target, cap * 0.7, 400.0, 10.0);
        let light = proposal(1, 2, target, cap * 0.3, 20.0, 1.0);
        let (corr, _) = algorithm1(
            &[heavy, light],
            &[0, 1],
            |_| true,
            &state,
            &dep,
            0.9,
            None,
            &mut ShieldScratch::default(),
        );
        // Moving the heavy one (idx 0) fixes the overload with minimal
        // interference (criterion 2).
        assert_eq!(corr.len(), 1);
        assert_eq!(corr[0].0, 0);
    }

    #[test]
    fn algorithm1_leaves_unfixable_overload() {
        let dep = small_dep();
        let mut state = ResourceState::new(&dep);
        // Saturate every node so no safe alternative exists.
        for n in 0..dep.n() {
            let caps = *state.caps(n);
            state.place(n, caps.scale(0.85), caps.scale(0.85), false);
        }
        let cap = state.caps(0).cpu;
        let props = vec![proposal(0, 1, 0, cap * 0.3, 10.0, 1.0)];
        let (corr, coll) = algorithm1(
            &props, &[0], |_| true, &state, &dep, 0.9, None,
            &mut ShieldScratch::default(),
        );
        assert_eq!(coll.len(), 1);
        assert!(corr.is_empty(), "no safe host anywhere");
    }

    #[test]
    fn algorithm1_respects_checkable_scope() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let cap = state.caps(0).cpu;
        let props = vec![
            proposal(0, 1, 0, cap * 0.8, 50.0, 1.0),
            proposal(1, 2, 0, cap * 0.8, 50.0, 1.0),
        ];
        // Node 0 not checkable: the collision goes unseen.
        let (corr, coll) = algorithm1(
            &props, &[0, 1], |n| n != 0, &state, &dep, 0.9, None,
            &mut ShieldScratch::default(),
        );
        assert!(coll.is_empty());
        assert!(corr.is_empty());
    }

    #[test]
    fn algorithm1_correction_target_is_safe() {
        let dep = small_dep();
        let state = ResourceState::new(&dep);
        let cap = state.caps(0).cpu;
        let props: Vec<ProposedAction> = (0..3)
            .map(|i| proposal(i, (i + 1) % 5, 0, cap * 0.45, 100.0, 2.0))
            .collect();
        let (corr, _) = algorithm1(
            &props,
            &[0, 1, 2],
            |_| true,
            &state,
            &dep,
            0.9,
            None,
            &mut ShieldScratch::default(),
        );
        for &(idx, new_target) in &corr {
            let d = &props[idx].demand;
            // New host must not exceed alpha with just this layer (state
            // was empty apart from proposals we can recompute).
            for k in ResourceKind::ALL {
                let u = state.caps(new_target).utilization(d, k);
                assert!(u <= 0.9 + 1e-9, "unsafe correction");
            }
        }
    }
}
