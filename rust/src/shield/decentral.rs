//! Decentralized shielding (§IV-D): sub-cluster shields plus delegate
//! checks on sub-cluster boundaries.
//!
//! Each sub-cluster's shield runs Algorithm 1 over the actions *it
//! receives* (those whose deciding agent lives in its sub-cluster),
//! restricted to target nodes of its own sub-cluster that are not on a
//! boundary.  For every pair of neighboring sub-clusters, the two shields
//! send boundary-node actions and states to an elected delegate, which
//! runs the same check for the boundary nodes and returns alternative
//! actions.
//!
//! Fidelity notes (the paper's observed SROLE-D gap emerges from these):
//!
//! * sub-shields run in parallel, so the modeled shielding latency is
//!   `max` over shields (+ the delegate exchange), below SROLE-C's serial
//!   cost — Fig 7/12;
//! * a node on the boundary of ≥3 sub-clusters is checked by pairwise
//!   delegates that each see only their pair's actions, and local
//!   corrections can retarget layers onto boundary nodes after the
//!   delegate already ran — both leak collisions, Fig 8/13.

use crate::cluster::{Deployment, NodeId, SubClusters};
use crate::obs;
use crate::sim::state::ResourceState;
use crate::util::NodeSet;

use super::{algorithm1, ProposedAction, Shield, ShieldOutcome, ShieldScratch, CHECK_SECS_PER_ACTION, FIX_SECS_PER_CORRECTION};

/// One delegate round-trip (shield → delegate → shield) per boundary pair.
pub const DELEGATE_RTT_SECS: f64 = 0.001;

/// The SROLE-D shield set for one cluster.
///
/// Every membership question on the per-round hot path — which
/// sub-cluster an agent belongs to, whether a target is on a boundary,
/// which nodes a delegate may retarget onto — is answered by the
/// precomputed [`SubClusters`] index tables in O(1), and the round's
/// collided-node de-duplication uses a reusable [`NodeSet`].
pub struct DecentralShield {
    pub subs: SubClusters,
    pub total_checked: usize,
    pub total_corrections: usize,
    pub total_collisions: usize,
    /// Number of delegate exchanges performed.
    pub delegate_rounds: usize,
    scratch: ShieldScratch,
    /// Collided-node set of the current round (cleared per check).
    collided: NodeSet,
}

impl DecentralShield {
    /// Build shields for `cluster_members`, split into `k` sub-clusters.
    pub fn new(dep: &Deployment, cluster_members: &[NodeId], k: usize) -> DecentralShield {
        let _sp = obs::span(obs::Phase::PartitionBuild);
        let subs = SubClusters::build(cluster_members, &dep.topo, k);
        DecentralShield {
            subs,
            total_checked: 0,
            total_corrections: 0,
            total_collisions: 0,
            delegate_rounds: 0,
            scratch: ShieldScratch::default(),
            collided: NodeSet::with_universe(dep.n()),
        }
    }

    /// Membership-change handler (node failed or left): drop the node
    /// from the shield's region structures and re-partition boundary
    /// responsibility for the affected sub-cluster pairs — incrementally,
    /// via [`SubClusters::remove_member`].  Returns false when the node
    /// was not part of this shield's cluster.
    pub fn node_failed(&mut self, dep: &Deployment, node: NodeId) -> bool {
        self.subs.remove_member(node, &dep.topo)
    }

    /// Membership-change handler (node joined or rejoined): attach the
    /// node to the nearest sub-cluster and re-derive that sub-cluster's
    /// boundary pairs.  Returns false when the node is already covered.
    pub fn node_joined(&mut self, dep: &Deployment, node: NodeId) -> bool {
        self.subs.add_member(node, &dep.topo)
    }

    /// Mobility handler: `node`'s position changed.  Re-evaluates the
    /// node's shield region and re-derives only the affected boundary
    /// pairs ([`SubClusters::handoff_member`]) — no k-means re-run, no
    /// full rescan.  Returns true when the node was handed off between
    /// sub-shields (a region *handoff*); same-region moves still refresh
    /// the region's boundary pairs.  Non-members (other clusters' nodes)
    /// are a no-op.
    pub fn node_moved(&mut self, dep: &Deployment, node: NodeId) -> bool {
        self.subs.handoff_member(node, &dep.topo)
    }

    /// Batched mobility handler: all of a tick's moved nodes at once.
    /// Region decisions replay the per-node [`DecentralShield::node_moved`]
    /// path exactly (same order, same tables — pinned by equivalence
    /// tests), but the boundary-pair refreshes are deferred and issued
    /// at most once per affected sub-cluster
    /// ([`SubClusters::handoff_members`]) — the ROADMAP's batched
    /// per-tick region refresh.  Returns the number of region handoffs.
    pub fn nodes_moved(&mut self, dep: &Deployment, nodes: &[NodeId]) -> usize {
        let _sp = obs::span(obs::Phase::PartitionBuild);
        self.subs.handoff_members(nodes, &dep.topo)
    }
}

impl Shield for DecentralShield {
    fn check(
        &mut self,
        proposals: &[ProposedAction],
        state: &ResourceState,
        dep: &Deployment,
        alpha: f64,
    ) -> ShieldOutcome {
        let mut corrections: Vec<(usize, NodeId)> = Vec::new();
        // Proposal idxs corrected so far (a local shield correction wins
        // over a later delegate correction for the same proposal).
        let mut corrected = NodeSet::with_universe(proposals.len());
        // Collision events are counted once per overloaded node per round,
        // even when several shields/delegates observe it — e.g. a node on
        // the boundary of several sub-cluster pairs is checked by every
        // pair's delegate.
        self.collided.clear();
        let mut collided_nodes: Vec<NodeId> = Vec::new();
        let mut per_shield_secs = vec![0.0f64; self.subs.k];

        // Region-local fast path: one O(proposals) bucketing pass builds
        // every shield's and every delegate's visible set, replacing the
        // per-sub and per-pair rescans (O(P·k + P·pairs)).  A proposal
        // lands in its agent's sub-shield bucket when it targets an
        // interior node, and in the bucket of each boundary pair that
        // involves the agent's sub-cluster and covers its target.  The
        // outer loop walks proposals in index order, so every bucket is
        // ascending — the exact visible sets (and hence corrections,
        // collisions and latency figures) the rescans produced, pinned by
        // the `shield::reference` equivalence tests.
        let mut sub_visible: Vec<Vec<usize>> = vec![Vec::new(); self.subs.k];
        let mut pair_visible: Vec<Vec<usize>> = vec![Vec::new(); self.subs.boundaries.len()];
        let mut pairs_of_sub: Vec<Vec<usize>> = vec![Vec::new(); self.subs.k];
        for (bi, ((a, b), _)) in self.subs.boundaries.iter().enumerate() {
            pairs_of_sub[*a].push(bi);
            pairs_of_sub[*b].push(bi);
        }
        for (i, p) in proposals.iter().enumerate() {
            if !self.subs.is_member(p.agent) {
                continue;
            }
            let s = self.subs.sub_of(p.agent);
            if !self.subs.is_boundary(p.target) {
                if self.subs.in_sub(p.agent, s) {
                    sub_visible[s].push(i);
                }
            }
            for &bi in &pairs_of_sub[s] {
                if self.subs.pair_boundary_set(bi).contains(p.target) {
                    pair_visible[bi].push(i);
                }
            }
        }

        // Phase 1: each sub-cluster shield checks the actions reported by
        // its own agents that target *interior* nodes of its sub-cluster;
        // boundary-targeted actions are forwarded to the delegates instead
        // ("the shields send the actions of the edge nodes in the boundary
        // to the delegate").  Interior nodes can only be targeted by the
        // sub-cluster's own agents (any out-of-sub agent in range would
        // make the node a boundary node), so the local view is complete.
        for s in 0..self.subs.k {
            let visible = std::mem::take(&mut sub_visible[s]);
            let subs = &self.subs;
            let checkable = |n: NodeId| subs.in_sub(n, s) && !subs.is_boundary(n);
            // Safe alternatives are drawn from the shield's own sub-cluster
            // (it does not know other sub-clusters' planned load).
            let (corr, coll) = algorithm1(
                proposals,
                &visible,
                checkable,
                state,
                dep,
                alpha,
                Some(subs.sub_set(s)),
                &mut self.scratch,
            );
            per_shield_secs[s] += visible.len() as f64 * CHECK_SECS_PER_ACTION
                + corr.len() as f64 * FIX_SECS_PER_CORRECTION;
            self.total_checked += visible.len();
            for &(idx, _) in &corr {
                corrected.insert(idx);
            }
            corrections.extend(corr);
            for n in coll {
                if self.collided.insert(n) {
                    collided_nodes.push(n);
                }
            }
        }

        // Phase 2: delegates handle boundary nodes per neighboring pair.
        // Both shields of the pair forward their agents' actions that
        // target the pair's boundary nodes.
        let mut delegate_secs = 0.0f64;
        for bi in 0..self.subs.boundaries.len() {
            // Actions already corrected in phase 1 keep their original
            // target in `proposals`; the delegate sees the *reported*
            // action — a second fidelity leak matching the paper.
            let visible = std::mem::take(&mut pair_visible[bi]);
            if visible.is_empty() {
                continue;
            }
            let subs = &self.subs;
            let checkable = |n: NodeId| subs.pair_boundary_set(bi).contains(n);
            let (corr, coll) = algorithm1(
                proposals,
                &visible,
                checkable,
                state,
                dep,
                alpha,
                Some(subs.pair_allowed_set(bi)),
                &mut self.scratch,
            );
            // Each pair's delegate exchange runs concurrently with the
            // other pairs: the phase costs the slowest exchange.
            let pair_secs = 2.0 * DELEGATE_RTT_SECS
                + visible.len() as f64 * CHECK_SECS_PER_ACTION
                + corr.len() as f64 * FIX_SECS_PER_CORRECTION;
            delegate_secs = delegate_secs.max(pair_secs);
            self.delegate_rounds += 1;
            self.total_checked += visible.len();
            // Drop duplicate corrections for the same proposal (a local
            // shield correction wins).
            for (idx, tgt) in corr {
                if corrected.insert(idx) {
                    corrections.push((idx, tgt));
                }
            }
            for n in coll {
                if self.collided.insert(n) {
                    collided_nodes.push(n);
                }
            }
        }

        // Sub-shields run in parallel; the delegate phase follows them.
        let shield_secs =
            per_shield_secs.iter().cloned().fold(0.0, f64::max) + delegate_secs;
        let collisions = collided_nodes.len();
        self.total_corrections += corrections.len();
        self.total_collisions += collisions;
        ShieldOutcome { corrections, collisions, shield_secs, checked: proposals.len() }
    }

    fn name(&self) -> &'static str {
        "srole_d"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, CONTAINER_PROFILE};
    use crate::shield::central::CentralShield;
    use crate::shield::testutil::proposal;
    use crate::util::Rng;

    fn dep10() -> Deployment {
        let mut rng = Rng::new(21);
        Deployment::generate(&mut rng, 10, 10, &CONTAINER_PROFILE)
    }

    #[test]
    fn builds_subclusters_over_cluster() {
        let dep = dep10();
        let members = dep.clusters[0].members.clone();
        let d = DecentralShield::new(&dep, &members, 2);
        assert_eq!(d.subs.k, 2);
        assert_eq!(d.subs.members.len(), 10);
    }

    #[test]
    fn interior_collision_detected_locally() {
        let dep = dep10();
        let members = dep.clusters[0].members.clone();
        let mut d = DecentralShield::new(&dep, &members, 2);
        let state = ResourceState::new(&dep);
        // Find an interior (non-boundary) node and two same-sub agents.
        let boundary = d.subs.boundary_nodes();
        let interior = members.iter().copied().find(|n| !boundary.contains(n));
        let Some(target) = interior else {
            eprintln!("all nodes on boundary in this layout; skipping");
            return;
        };
        let sub = d.subs.sub_of(target);
        let agents: Vec<NodeId> =
            d.subs.members_of(sub).into_iter().filter(|&n| n != target).collect();
        if agents.len() < 2 {
            return;
        }
        let cap = state.caps(target).cpu;
        let props = vec![
            proposal(0, agents[0], target, cap * 0.55, 40.0, 1.0),
            proposal(1, agents[1], target, cap * 0.55, 40.0, 1.0),
        ];
        let out = d.check(&props, &state, &dep, 0.9);
        assert_eq!(out.collisions, 1);
        assert!(!out.corrections.is_empty());
    }

    #[test]
    fn boundary_collision_goes_to_delegate() {
        let dep = dep10();
        let members = dep.clusters[0].members.clone();
        let mut d = DecentralShield::new(&dep, &members, 2);
        let state = ResourceState::new(&dep);
        let Some(((a, b), nodes)) = d.subs.boundaries.first().cloned() else {
            eprintln!("no boundary between sub-clusters; skipping");
            return;
        };
        let target = nodes[0];
        let agent_a = d.subs.members_of(a).into_iter().find(|&n| n != target).unwrap();
        let agent_b = d.subs.members_of(b).into_iter().find(|&n| n != target).unwrap();
        let cap = state.caps(target).cpu;
        let props = vec![
            proposal(0, agent_a, target, cap * 0.55, 40.0, 1.0),
            proposal(1, agent_b, target, cap * 0.55, 40.0, 1.0),
        ];
        let out = d.check(&props, &state, &dep, 0.9);
        assert_eq!(out.collisions, 1, "delegate must see the union");
        assert!(d.delegate_rounds >= 1);
    }

    #[test]
    fn decentral_catches_no_more_than_central(){
        // Over random rounds, SROLE-D detects a subset of SROLE-C's
        // collisions (global view is strictly more informed).
        let dep = dep10();
        let members = dep.clusters[0].members.clone();
        let state = ResourceState::new(&dep);
        let mut rng = Rng::new(33);
        let mut total_c = 0usize;
        let mut total_d = 0usize;
        for round in 0..50 {
            let mut props = Vec::new();
            for i in 0..3 {
                let agent = members[rng.below(members.len())];
                let target = members[rng.below(members.len())];
                let cap = state.caps(target).cpu;
                props.push(proposal(i, agent, target, cap * rng.range_f64(0.3, 0.8), 60.0, 1.5));
            }
            let mut c = CentralShield::new();
            let mut dsh = DecentralShield::new(&dep, &members, 3);
            total_c += c.check(&props, &state, &dep, 0.9).collisions;
            total_d += dsh.check(&props, &state, &dep, 0.9).collisions;
            let _ = round;
        }
        assert!(total_d <= total_c, "d={total_d} c={total_c}");
        assert!(total_c > 0, "test vacuous");
    }

    #[test]
    fn multi_delegate_collision_counted_once() {
        // Regression (collision accounting): one node that lies on the
        // boundary of SEVERAL sub-cluster pairs is checked by every
        // pair's delegate; when two delegates both observe it overloaded
        // in the same round, the round must still count ONE collision.
        // (A node can never collide at both a local shield and a
        // delegate in the same round — local shields only check interior
        // nodes — so the cross-phase NodeSet dedupe is exercised through
        // the multi-pair case, plus the phase-1 + phase-2 union below.)
        use crate::cluster::{ClusterSpec, EdgeNode, Resources};
        use crate::net::{Pos, Topology};

        // Hand-built geometry: three tight groups at triangle corners,
        // plus one junction node within boundary range of all of them.
        let mut positions = Vec::new();
        let corners = [(0.0, 0.0), (30.0, 0.0), (15.0, 26.0)];
        for &(cx, cy) in &corners {
            for i in 0..3 {
                positions.push(Pos { x: cx + i as f64 * 0.5, y: cy });
            }
        }
        let center = positions.len();
        positions.push(Pos { x: 15.0, y: 9.0 }); // within 60% of range 40 of all groups
        let n = positions.len();
        let topo =
            Topology::from_parts(positions, 40.0, crate::net::LinkParams::uniform(n, 100.0, 0.001));
        let nodes: Vec<EdgeNode> = (0..n)
            .map(|id| EdgeNode { id, caps: Resources::new(1.0, 2048.0, 100.0) })
            .collect();
        let members: Vec<NodeId> = (0..n).collect();
        let clusters = vec![ClusterSpec { members: members.clone(), head: 0 }];
        let dep = Deployment::new(nodes, topo, clusters);

        let mut d = DecentralShield::new(&dep, &members, 3);
        // The junction node must sit on at least two pair boundaries for
        // the scenario to be meaningful.
        let pairs_with_center = d
            .subs
            .boundaries
            .iter()
            .enumerate()
            .filter(|(bi, _)| d.subs.pair_boundary_set(*bi).contains(center))
            .map(|(_, ((a, b), _))| (*a, *b))
            .collect::<Vec<_>>();
        assert!(
            pairs_with_center.len() >= 2,
            "junction node on {} pairs; geometry broken: {:?}",
            pairs_with_center.len(),
            d.subs.boundaries
        );
        let center_sub = d.subs.sub_of(center);
        // Two agents from two *different* non-center sub-clusters, each
        // proposing a load that alone overloads the junction node.
        let mut agents = Vec::new();
        for s in 0..3 {
            if s != center_sub {
                agents.push(d.subs.members_of(s)[0]);
            }
        }
        let state = ResourceState::new(&dep);
        let cap = state.caps(center).cpu;
        let props = vec![
            proposal(0, agents[0], center, cap * 0.95, 40.0, 1.0),
            proposal(1, agents[1], center, cap * 0.95, 40.0, 1.0),
        ];
        let out = d.check(&props, &state, &dep, 0.9);
        assert_eq!(
            out.collisions, 1,
            "node colliding at two delegates must be counted exactly once"
        );
        assert_eq!(d.total_collisions, 1);
        assert!(d.delegate_rounds >= 2, "both pair delegates must have run");

        // Union accounting across phases: an *interior* collision in the
        // same round adds exactly one more collision event.
        let mut d2 = DecentralShield::new(&dep, &members, 3);
        // The only interior nodes here are the junction's own sub-mates:
        // every other-sub node sits within boundary range of the junction
        // and is therefore itself a boundary node.
        let interior = members
            .iter()
            .copied()
            .find(|&m| !d2.subs.is_boundary(m) && m != center)
            .expect("an interior node exists");
        let isub = d2.subs.sub_of(interior);
        let iagent = d2
            .subs
            .members_of(isub)
            .into_iter()
            .find(|&m| m != interior)
            .expect("a same-sub agent exists");
        let icap = state.caps(interior).cpu;
        let mut props2 = props.clone();
        props2.push(proposal(2, iagent, interior, icap * 0.95, 40.0, 1.0));
        let out2 = d2.check(&props2, &state, &dep, 0.9);
        assert_eq!(out2.collisions, 2, "one boundary + one interior event");
    }

    #[test]
    fn repartition_on_failure_stops_targeting_dead_nodes() {
        // After a node fails, the shield's re-partitioned region tables
        // must neither check it as a boundary node nor offer it as a
        // correction target, and must match a from-scratch rebuild.
        use crate::cluster::SubClusters;
        let dep = dep10();
        let members = dep.clusters[0].members.clone();
        let mut d = DecentralShield::new(&dep, &members, 3);
        let dead = members[3];
        assert!(d.node_failed(&dep, dead));
        assert!(!d.node_failed(&dep, dead), "double failure is a no-op");
        assert!(!d.subs.is_member(dead));
        assert!(!d.subs.is_boundary(dead));
        for bi in 0..d.subs.boundaries.len() {
            assert!(!d.subs.pair_boundary_set(bi).contains(dead));
            assert!(!d.subs.pair_allowed_set(bi).contains(dead));
        }
        let reference = SubClusters::from_assignment(
            d.subs.members.clone(),
            d.subs.assignment.clone(),
            d.subs.k,
            &dep.topo,
        );
        assert_eq!(d.subs, reference, "incremental re-partition != rebuild");

        // Overload an alive node: any corrections must avoid the dead one.
        let state = ResourceState::new(&dep);
        let alive: Vec<NodeId> = members.iter().copied().filter(|&m| m != dead).collect();
        let target = alive[0];
        let cap = state.caps(target).cpu;
        let props = vec![
            proposal(0, alive[1], target, cap * 0.6, 40.0, 1.0),
            proposal(1, alive[2], target, cap * 0.6, 40.0, 1.0),
        ];
        let out = d.check(&props, &state, &dep, 0.9);
        for &(_, tgt) in &out.corrections {
            assert_ne!(tgt, dead, "corrected onto a failed node");
        }

        // Rejoin restores coverage.
        assert!(d.node_joined(&dep, dead));
        assert!(d.subs.is_member(dead));
    }

    #[test]
    fn region_handoff_on_movement_matches_rebuild_and_keeps_checking() {
        // A node walking into another sub-cluster's area must be handed
        // off between sub-shields, the region tables must match a
        // from-scratch re-partition, and the shield must keep producing
        // valid corrections afterwards.
        use crate::cluster::SubClusters;
        let mut dep = dep10();
        let members = dep.clusters[0].members.clone();
        let mut d = DecentralShield::new(&dep, &members, 3);
        let probe = members[0];
        let home = d.subs.sub_of(probe);
        // Park the probe on top of the out-of-region member farthest
        // from its home region's centroid — the clearest cross-region
        // move this geometry offers.
        let home_members = d.subs.members_of(home);
        let (hx, hy) = home_members.iter().filter(|&&m| m != probe).fold((0.0, 0.0), |(x, y), &m| {
            (x + dep.topo.positions[m].x, y + dep.topo.positions[m].y)
        });
        let hn = (home_members.len() - 1).max(1) as f64;
        let hcent = crate::net::Pos { x: hx / hn, y: hy / hn };
        let anchor = members
            .iter()
            .copied()
            .filter(|&m| d.subs.sub_of(m) != home)
            .max_by(|&a, &b| {
                hcent
                    .dist(&dep.topo.positions[a])
                    .total_cmp(&hcent.dist(&dep.topo.positions[b]))
            })
            .expect("another region exists");
        dep.topo.positions[probe] = dep.topo.positions[anchor];
        dep.topo.rebuild_adjacency();
        dep.refresh_adjacency();
        assert!(d.node_moved(&dep, probe), "crossing regions must hand off");
        let new_sub = d.subs.sub_of(probe);
        assert_ne!(new_sub, home, "handoff must leave the home region");
        let reference = SubClusters::from_assignment(
            d.subs.members.clone(),
            d.subs.assignment.clone(),
            d.subs.k,
            &dep.topo,
        );
        assert_eq!(d.subs, reference, "incremental handoff != rebuild");
        // The shield still detects a collision on the probe in its new
        // region (agents from that region, so the overload is visible to
        // its local sub-shield or its delegates).  The new region kept
        // its prior members — the handoff rule never migrates into an
        // empty region — so same-region agents exist.
        let state = ResourceState::new(&dep);
        let cap = state.caps(probe).cpu;
        let agents: Vec<NodeId> =
            d.subs.members_of(new_sub).into_iter().filter(|&m| m != probe).collect();
        assert!(!agents.is_empty(), "handoff target region kept its members");
        let a0 = agents[0];
        let a1 = agents.get(1).copied().unwrap_or(a0);
        let props = vec![
            proposal(0, a0, probe, cap * 0.55, 40.0, 1.0),
            proposal(1, a1, probe, cap * 0.55, 40.0, 1.0),
        ];
        let out = d.check(&props, &state, &dep, 0.9);
        assert_eq!(out.collisions, 1);
        for &(_, tgt) in &out.corrections {
            assert!(d.subs.is_member(tgt), "correction onto a non-member");
        }
    }

    #[test]
    fn batched_moves_match_per_node_handoffs() {
        // The tick-level batching must leave the shield in exactly the
        // state the per-node handler produces, with the same handoff
        // count, and keep producing valid checks afterwards.
        let mut dep = dep10();
        let members = dep.clusters[0].members.clone();
        let mut batched = DecentralShield::new(&dep, &members, 3);
        let mut per_node = DecentralShield::new(&dep, &members, 3);
        let mut rng = Rng::new(0x30f);
        let mut total = 0usize;
        for _ in 0..30 {
            let mut moved: Vec<NodeId> = Vec::new();
            for _ in 0..1 + rng.below(4) {
                let node = members[rng.below(members.len())];
                if !moved.contains(&node) {
                    moved.push(node);
                }
                dep.topo.positions[node] = crate::net::Pos {
                    x: rng.range_f64(0.0, 60.0),
                    y: rng.range_f64(0.0, 60.0),
                };
            }
            moved.sort_unstable();
            dep.topo.rebuild_adjacency();
            dep.refresh_adjacency();
            let a = batched.nodes_moved(&dep, &moved);
            let mut b = 0usize;
            for &node in &moved {
                if per_node.node_moved(&dep, node) {
                    b += 1;
                }
            }
            assert_eq!(a, b, "handoff counts diverged");
            assert_eq!(batched.subs, per_node.subs, "region tables diverged");
            total += a;
        }
        assert!(total > 0, "vacuous: no handoff in 30 ticks");
        // On their (identical) post-motion tables, both shields must
        // produce the same round outcome.
        let state = ResourceState::new(&dep);
        let target = members[0];
        let cap = state.caps(target).cpu;
        let props = vec![
            proposal(0, members[1], target, cap * 0.55, 40.0, 1.0),
            proposal(1, members[2], target, cap * 0.55, 40.0, 1.0),
        ];
        let a = batched.check(&props, &state, &dep, 0.9);
        let b = per_node.check(&props, &state, &dep, 0.9);
        assert_eq!(a.collisions, b.collisions);
        assert_eq!(a.corrections, b.corrections);
        for &(_, tgt) in &a.corrections {
            assert!(batched.subs.is_member(tgt), "correction onto a non-member");
        }
    }

    #[test]
    fn parallel_shields_cheaper_than_serial_central() {
        let dep = dep10();
        let members = dep.clusters[0].members.clone();
        let state = ResourceState::new(&dep);
        // Many safe actions spread across agents: no corrections, pure
        // check cost.  SROLE-D splits the work across shields.
        let props: Vec<ProposedAction> = (0..30)
            .map(|i| proposal(i, members[i % members.len()], members[(i + 1) % members.len()], 0.01, 4.0, 0.1))
            .collect();
        let mut c = CentralShield::new();
        let mut d = DecentralShield::new(&dep, &members, 3);
        let tc = c.check(&props, &state, &dep, 0.9).shield_secs;
        let td = d.check(&props, &state, &dep, 0.9).shield_secs;
        assert!(td < tc, "td={td} tc={tc}");
    }
}
