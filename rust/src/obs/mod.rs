//! In-sim tracing and per-phase self-profiling.
//!
//! The engine's fast paths (zero-alloc decisions, sparse links, sharded
//! lanes, batched forwards) are pinned byte-identical — but opaque: at
//! 100k nodes nothing says whether wall-clock goes to partitioning,
//! shield checks, link repricing or Q-net forwards.  This module is the
//! observability layer: scoped **span timers** accumulated into a
//! per-phase [`PhaseProfile`] with per-lane attribution, a bounded
//! **ring-buffer event trace** ([`TraceRecord`]) exported as JSONL and
//! as a Chrome-`trace_event` document, and **windowed time-series
//! samplers** riding the existing `EventKind::Sample` hook.
//!
//! ## The contract
//!
//! * **Zero overhead when off.**  Nothing is installed unless a run was
//!   started through `Experiment::run_once_traced` with `trace !=
//!   off`.  Every instrumentation point ([`span`], [`event`],
//!   [`sample`], [`sim_time`]) first reads one thread-local pointer;
//!   when it is null the call does no allocation and — critically — no
//!   clock read.  Phase timers wrap whole rounds / events, never
//!   individual decisions, so even armed runs batch their clock reads
//!   at round granularity.
//! * **Tracing never perturbs the simulation.**  The recorder only
//!   *reads* state and wall-clock; it draws no RNG and mutates nothing
//!   the engine observes, so `RunMetrics` stays byte-identical across
//!   `trace` modes, shard counts and thread counts (pinned by harness
//!   tests).
//! * **Per-lane attribution.**  The sharded engine installs one
//!   [`Recorder`] per lane for the duration of its epoch advance;
//!   barrier and driver work lands on the driver recorder.  Lane
//!   recorders are merged into the driver in cluster order — the same
//!   merge rule as metrics — so the profile is independent of how lanes
//!   were chunked across worker threads.
//!
//! ## Modes
//!
//! * `off` — nothing armed (the default; the per-decision loop keeps
//!   its PR 7 cost).
//! * `profile` — span timers + samplers only; the trace ring stays
//!   empty.
//! * `full` — everything: spans also append [`TraceRecord`]s, and
//!   instant records (arrival / placement / collision / correction /
//!   handoff / failure / join) are captured with sim-time + wall-time.

use std::cell::Cell;
use std::time::Instant;

use crate::util::json::{obj, Json};

/// Hot phases attributed by the span timers, in profile-column order.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Phase {
    /// Sub-cluster partition construction / re-partition (SROLE-D).
    PartitionBuild = 0,
    /// `Shield::check` — collision detection + correction.
    ShieldCheck = 1,
    /// Batched Q-net forward chunks of one decision round.
    QnetForward = 2,
    /// Link reprice after motion (`Topology::advance_links`).
    LinkReprice = 3,
    /// One simulation event popped + handled (inclusive of the above).
    EventDispatch = 4,
    /// Serial barrier section of the sharded engine (driver events +
    /// lane merges between epochs).
    EpochBarrier = 5,
    /// Group-parallel section of a shield-tree epoch barrier: one
    /// super-shield group's worth of cross-region work, attributed to
    /// the lanes the group worker touched (`tree_fanout >= 1` only).
    GroupDispatch = 6,
}

/// Number of phases (array sizes in [`PhaseProfile`]).
pub const N_PHASES: usize = 7;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [
        Phase::PartitionBuild,
        Phase::ShieldCheck,
        Phase::QnetForward,
        Phase::LinkReprice,
        Phase::EventDispatch,
        Phase::EpochBarrier,
        Phase::GroupDispatch,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::PartitionBuild => "partition_build",
            Phase::ShieldCheck => "shield_check",
            Phase::QnetForward => "qnet_forward",
            Phase::LinkReprice => "link_reprice",
            Phase::EventDispatch => "event_dispatch",
            Phase::EpochBarrier => "epoch_barrier",
            Phase::GroupDispatch => "group_dispatch",
        }
    }
}

/// Trace verbosity knob (`ExperimentConfig::trace`).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum TraceMode {
    /// Nothing armed; instrumentation points are inert pointer checks.
    #[default]
    Off,
    /// Span timers + time-series samplers (no per-event records).
    Profile,
    /// Profile plus the bounded ring-buffer event trace.
    Full,
}

impl TraceMode {
    pub fn parse(s: &str) -> Option<TraceMode> {
        match s {
            "off" => Some(TraceMode::Off),
            "profile" => Some(TraceMode::Profile),
            "full" => Some(TraceMode::Full),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TraceMode::Off => "off",
            TraceMode::Profile => "profile",
            TraceMode::Full => "full",
        }
    }
}

/// Instant (zero-duration) trace record kinds.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TraceKind {
    /// A wave of jobs arrived (`a` = cluster, `b` = jobs in the wave).
    Arrival,
    /// A wave committed placements (`a` = cluster, `b` = jobs placed).
    Placement,
    /// Collisions detected in a wave (`a` = cluster, `b` = count).
    Collision,
    /// Shield corrections applied in a wave (`a` = cluster, `b` = count).
    Correction,
    /// Shield-region handoffs after motion (`a` = cluster, `b` = count).
    Handoff,
    /// A node failed (`a` = node, `b` = 1 if a correlated blast victim).
    Failure,
    /// A failed node rejoined (`a` = node).
    Join,
}

impl TraceKind {
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Arrival => "arrival",
            TraceKind::Placement => "placement",
            TraceKind::Collision => "collision",
            TraceKind::Correction => "correction",
            TraceKind::Handoff => "handoff",
            TraceKind::Failure => "failure",
            TraceKind::Join => "join",
        }
    }
}

/// Windowed time-series sampled on the `EventKind::Sample` hook.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Series {
    /// Pending events across every live queue at sample time.
    QueueDepth = 0,
    /// Mean actual CPU utilization over all nodes.
    UtilCpu = 1,
    /// Mean actual memory utilization over all nodes.
    UtilMem = 2,
    /// Mean actual bandwidth utilization over all nodes.
    UtilBw = 3,
    /// Collisions detected since the previous sample (per-window delta).
    CollisionsWindow = 4,
    /// Batched-forward occupancy so far: rows / (rows + pad rows).
    QnetOccupancy = 5,
}

/// Number of sampled series.
pub const N_SERIES: usize = 6;

impl Series {
    pub const ALL: [Series; N_SERIES] = [
        Series::QueueDepth,
        Series::UtilCpu,
        Series::UtilMem,
        Series::UtilBw,
        Series::CollisionsWindow,
        Series::QnetOccupancy,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Series::QueueDepth => "queue_depth",
            Series::UtilCpu => "util_cpu",
            Series::UtilMem => "util_mem",
            Series::UtilBw => "util_bw",
            Series::CollisionsWindow => "collisions_window",
            Series::QnetOccupancy => "qnet_occupancy",
        }
    }
}

/// One time-series sample: sim-time, wall-µs since the run anchor, value.
pub type SamplePoint = (f64, f64, f64);

/// Per-phase accumulated wall-clock (seconds) and span counts.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseProfile {
    pub secs: [f64; N_PHASES],
    pub count: [u64; N_PHASES],
}

impl PhaseProfile {
    pub fn add(&mut self, phase: Phase, secs: f64) {
        self.secs[phase as usize] += secs;
        self.count[phase as usize] += 1;
    }

    pub fn total_secs(&self) -> f64 {
        // EventDispatch/EpochBarrier are inclusive wrappers around the
        // leaf phases; the attributable total is the wrapper sum.
        self.secs[Phase::EventDispatch as usize] + self.secs[Phase::EpochBarrier as usize]
    }
}

/// One trace record: a completed span (`ph == 'X'`), an instant event
/// (`ph == 'i'`), or a counter sample (`ph == 'C'`) — the three Chrome
/// `trace_event` phases the exporters emit.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Wall-clock µs since the run anchor (span start for `'X'`).
    pub ts_us: f64,
    /// Span duration in µs (0 for instants and counters).
    pub dur_us: f64,
    /// Phase, instant-kind or `series:*` name.
    pub name: &'static str,
    /// Chrome phase char: `'X'` span, `'i'` instant, `'C'` counter.
    pub ph: char,
    /// Simulation time when the record was captured.
    pub sim_t: f64,
    /// Owning lane (cluster index), or [`DRIVER_LANE`].
    pub lane: u32,
    /// Kind-specific payload (node / cluster / count / sample value).
    pub a: f64,
    /// Second payload slot (see [`TraceKind`]).
    pub b: f64,
}

impl TraceRecord {
    fn to_json(&self) -> Json {
        obj(vec![
            ("ts_us", Json::Num(self.ts_us)),
            ("dur_us", Json::Num(self.dur_us)),
            ("name", Json::Str(self.name.to_string())),
            ("ph", Json::Str(self.ph.to_string())),
            ("sim_t", Json::Num(self.sim_t)),
            ("lane", Json::Num(self.lane as f64)),
            ("a", Json::Num(self.a)),
            ("b", Json::Num(self.b)),
        ])
    }
}

/// Lane id of the driver / single-stream recorder.
pub const DRIVER_LANE: u32 = u32::MAX;

/// Default trace-ring capacity per recorder (records; oldest overwritten).
pub const RING_CAP: usize = 1 << 16;

/// One thread's (or lane's) trace collector: a phase profile, a bounded
/// record ring and the sampled series.  Install with [`with_recorder`];
/// the instrumentation free functions find it through a thread-local.
pub struct Recorder {
    pub mode: TraceMode,
    pub lane: u32,
    anchor: Instant,
    sim_now: f64,
    pub profile: PhaseProfile,
    /// Bounded ring: once `cap` records exist, new pushes overwrite the
    /// oldest (`head` marks the oldest slot) and count as `dropped`.
    ring: Vec<TraceRecord>,
    head: usize,
    cap: usize,
    dropped: u64,
    series: [Vec<SamplePoint>; N_SERIES],
    /// Lane profiles merged into this (driver) recorder, cluster order.
    merged_lanes: Vec<(u32, PhaseProfile)>,
}

impl Recorder {
    pub fn new(mode: TraceMode, lane: u32) -> Recorder {
        Recorder::with_anchor(mode, lane, Instant::now())
    }

    /// Lane recorders share the driver's anchor so every record's
    /// `ts_us` lives on one run-relative timeline.
    pub fn with_anchor(mode: TraceMode, lane: u32, anchor: Instant) -> Recorder {
        Recorder {
            mode,
            lane,
            anchor,
            sim_now: 0.0,
            profile: PhaseProfile::default(),
            ring: Vec::new(),
            head: 0,
            cap: RING_CAP,
            dropped: 0,
            series: Default::default(),
            merged_lanes: Vec::new(),
        }
    }

    fn wall_us(&self) -> f64 {
        self.anchor.elapsed().as_secs_f64() * 1e6
    }

    fn push(&mut self, rec: TraceRecord) {
        if self.ring.len() < self.cap {
            self.ring.push(rec);
        } else {
            self.ring[self.head] = rec;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Records in push (chronological-per-lane) order.
    fn drain_ring(&mut self) -> Vec<TraceRecord> {
        let head = std::mem::take(&mut self.head);
        let mut ring = std::mem::take(&mut self.ring);
        ring.rotate_left(head);
        ring
    }

    /// Absorb a finished lane recorder (driver side, called in cluster
    /// order): its profile is kept as a per-lane row, its records and
    /// samples append to the driver's.
    pub fn absorb_lane(&mut self, mut lane: Recorder) {
        self.merged_lanes.push((lane.lane, lane.profile.clone()));
        for rec in lane.drain_ring() {
            self.push(rec);
        }
        self.dropped += lane.dropped;
        for (dst, src) in self.series.iter_mut().zip(lane.series.iter_mut()) {
            dst.append(src);
        }
    }

    /// Finish the recorder into an exportable report.
    pub fn into_report(mut self) -> ObsReport {
        let wall_secs = self.anchor.elapsed().as_secs_f64();
        let mut lanes = std::mem::take(&mut self.merged_lanes);
        lanes.push((self.lane, self.profile.clone()));
        let records = self.drain_ring();
        ObsReport {
            mode: self.mode,
            lanes,
            records,
            dropped: self.dropped,
            series: self.series,
            wall_secs,
        }
    }
}

/// Finished, exportable observation report: per-lane phase profiles
/// (driver row last), the merged trace records, and the sampled series.
#[derive(Debug)]
pub struct ObsReport {
    pub mode: TraceMode,
    /// `(lane, profile)` rows — lanes in cluster order, then the driver
    /// row ([`DRIVER_LANE`]).
    pub lanes: Vec<(u32, PhaseProfile)>,
    pub records: Vec<TraceRecord>,
    /// Records overwritten by the bounded ring (0 means the trace is
    /// complete).
    pub dropped: u64,
    pub series: [Vec<SamplePoint>; N_SERIES],
    /// Wall-clock of the whole traced run.
    pub wall_secs: f64,
}

impl ObsReport {
    /// Human label for a profile row.
    pub fn lane_label(lane: u32) -> String {
        if lane == DRIVER_LANE {
            "driver".to_string()
        } else {
            format!("lane {lane}")
        }
    }

    /// Whole-run profile: every lane row plus the driver row, summed.
    pub fn total_profile(&self) -> PhaseProfile {
        let mut total = PhaseProfile::default();
        for (_, p) in &self.lanes {
            for i in 0..N_PHASES {
                total.secs[i] += p.secs[i];
                total.count[i] += p.count[i];
            }
        }
        total
    }

    /// JSONL export: one JSON object per line — first every trace
    /// record, then every series sample as a `ph: "C"` counter line
    /// (`name` = the series name, value in `a`).  Schema keys:
    /// `ts_us, dur_us, name, ph, sim_t, lane, a, b`.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        for (si, s) in Series::ALL.iter().enumerate() {
            for &(sim_t, wall_us, v) in &self.series[si] {
                let rec = TraceRecord {
                    ts_us: wall_us,
                    dur_us: 0.0,
                    name: s.name(),
                    ph: 'C',
                    sim_t,
                    lane: DRIVER_LANE,
                    a: v,
                    b: 0.0,
                };
                out.push_str(&rec.to_json().to_string());
                out.push('\n');
            }
        }
        out
    }

    /// Chrome `trace_event` document (`chrome://tracing` / Perfetto):
    /// spans as `"X"` duration events (tid = lane), instants as `"i"`,
    /// series samples as `"C"` counter events.
    pub fn to_chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::with_capacity(self.records.len());
        for r in &self.records {
            let mut fields = vec![
                ("name", Json::Str(r.name.to_string())),
                ("ph", Json::Str(r.ph.to_string())),
                ("ts", Json::Num(r.ts_us)),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(r.lane as f64)),
            ];
            if r.ph == 'X' {
                fields.push(("dur", Json::Num(r.dur_us)));
            }
            if r.ph == 'i' {
                // Thread-scoped instant marker.
                fields.push(("s", Json::Str("t".to_string())));
            }
            fields.push((
                "args",
                obj(vec![
                    ("sim_t", Json::Num(r.sim_t)),
                    ("a", Json::Num(r.a)),
                    ("b", Json::Num(r.b)),
                ]),
            ));
            events.push(obj(fields));
        }
        for (si, s) in Series::ALL.iter().enumerate() {
            for &(sim_t, wall_us, v) in &self.series[si] {
                events.push(obj(vec![
                    ("name", Json::Str(s.name().to_string())),
                    ("ph", Json::Str("C".to_string())),
                    ("ts", Json::Num(wall_us)),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num(0.0)),
                    (
                        "args",
                        obj(vec![("value", Json::Num(v)), ("sim_t", Json::Num(sim_t))]),
                    ),
                ]));
            }
        }
        obj(vec![("traceEvents", Json::Arr(events))])
    }

    /// Write the JSONL trace to `path` and the Chrome trace next to it
    /// (`<stem>.chrome.json`).  Returns the Chrome-trace path.
    pub fn write_trace(&self, path: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        std::fs::write(path, self.to_jsonl())?;
        let chrome = path.with_extension("chrome.json");
        std::fs::write(&chrome, self.to_chrome_trace().to_string())?;
        Ok(chrome)
    }
}

thread_local! {
    /// The thread's installed recorder (null = tracing off).
    static CURRENT: Cell<*mut Recorder> = const { Cell::new(std::ptr::null_mut()) };
}

/// Run `f` with `rec` installed as this thread's recorder, restoring
/// the previous installation afterwards (panic-safe).  Scoped-TLS: the
/// recorder is only reachable through the instrumentation functions
/// while `f` runs.
pub fn with_recorder<R>(rec: &mut Recorder, f: impl FnOnce() -> R) -> R {
    struct Restore(*mut Recorder);
    impl Drop for Restore {
        fn drop(&mut self) {
            CURRENT.with(|c| c.set(self.0));
        }
    }
    let prev = CURRENT.with(|c| c.replace(rec as *mut Recorder));
    let _restore = Restore(prev);
    f()
}

/// The thread's installed recorder, if any (transient borrow).
#[inline]
fn current<'a>() -> Option<&'a mut Recorder> {
    let p = CURRENT.with(|c| c.get());
    // SAFETY: non-null only inside a `with_recorder` scope, which holds
    // the exclusive `&mut Recorder` for its whole extent; access is
    // confined to short instrumentation calls that never re-enter.
    if p.is_null() {
        None
    } else {
        Some(unsafe { &mut *p })
    }
}

/// True when a recorder is installed on this thread.  Gate any
/// sampler-value computation behind this so trace-off runs skip it.
#[inline]
pub fn active() -> bool {
    CURRENT.with(|c| !c.get().is_null())
}

/// Installed mode, if a recorder is armed on this thread.
#[inline]
pub fn mode() -> Option<TraceMode> {
    current().map(|r| r.mode)
}

/// The installed recorder's wall anchor (for lane recorders sharing the
/// driver's timeline).
#[inline]
pub fn anchor() -> Option<Instant> {
    current().map(|r| r.anchor)
}

/// Note the current simulation time (called at event dispatch; spans
/// and records completed afterwards carry it).
#[inline]
pub fn sim_time(t: f64) {
    if let Some(rec) = current() {
        rec.sim_now = t;
    }
}

/// Merge a finished lane recorder into the thread's (driver) recorder.
pub fn merge_lane(lane: Recorder) {
    if let Some(rec) = current() {
        rec.absorb_lane(lane);
    }
}

/// Scoped phase timer.  Inert (no clock read, no allocation) unless a
/// recorder is installed; on drop it adds the elapsed wall-clock to the
/// recorder's profile and, in `full` mode, appends a span record.
pub struct SpanGuard {
    armed: Option<(Phase, Instant)>,
}

/// Start a phase span (see [`SpanGuard`]).
#[inline]
pub fn span(phase: Phase) -> SpanGuard {
    let armed = if active() { Some((phase, Instant::now())) } else { None };
    SpanGuard { armed }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some((phase, t0)) = self.armed else { return };
        let dur = t0.elapsed().as_secs_f64();
        if let Some(rec) = current() {
            rec.profile.add(phase, dur);
            if rec.mode == TraceMode::Full {
                let ts_us = t0.duration_since(rec.anchor).as_secs_f64() * 1e6;
                let rec_lane = rec.lane;
                let sim_t = rec.sim_now;
                rec.push(TraceRecord {
                    ts_us,
                    dur_us: dur * 1e6,
                    name: phase.name(),
                    ph: 'X',
                    sim_t,
                    lane: rec_lane,
                    a: 0.0,
                    b: 0.0,
                });
            }
        }
    }
}

/// Record an instant trace event (`full` mode only; inert otherwise).
#[inline]
pub fn event(kind: TraceKind, sim_t: f64, a: f64, b: f64) {
    if let Some(rec) = current() {
        if rec.mode == TraceMode::Full {
            let ts_us = rec.wall_us();
            let lane = rec.lane;
            rec.push(TraceRecord {
                ts_us,
                dur_us: 0.0,
                name: kind.name(),
                ph: 'i',
                sim_t,
                lane,
                a,
                b,
            });
        }
    }
}

/// Record one time-series sample (`profile` and `full` modes).
#[inline]
pub fn sample(series: Series, sim_t: f64, v: f64) {
    if let Some(rec) = current() {
        let wall = rec.wall_us();
        rec.series[series as usize].push((sim_t, wall, v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_when_nothing_installed() {
        assert!(!active());
        assert_eq!(mode(), None);
        // None of these may panic or observe state without a recorder.
        let _s = span(Phase::ShieldCheck);
        drop(_s);
        event(TraceKind::Arrival, 1.0, 2.0, 3.0);
        sample(Series::QueueDepth, 1.0, 4.0);
        sim_time(9.0);
        assert!(!active());
    }

    #[test]
    fn spans_accumulate_into_the_profile() {
        let mut rec = Recorder::new(TraceMode::Profile, DRIVER_LANE);
        with_recorder(&mut rec, || {
            assert!(active());
            assert_eq!(mode(), Some(TraceMode::Profile));
            for _ in 0..3 {
                let _s = span(Phase::ShieldCheck);
            }
            let _outer = span(Phase::EventDispatch);
            let _inner = span(Phase::QnetForward);
        });
        assert!(!active(), "installation must be scoped");
        assert_eq!(rec.profile.count[Phase::ShieldCheck as usize], 3);
        assert_eq!(rec.profile.count[Phase::QnetForward as usize], 1);
        assert_eq!(rec.profile.count[Phase::EventDispatch as usize], 1);
        assert!(rec.profile.secs[Phase::ShieldCheck as usize] >= 0.0);
        // Profile mode records no ring entries.
        assert!(rec.into_report().records.is_empty());
    }

    #[test]
    fn full_mode_records_spans_and_instants() {
        let mut rec = Recorder::new(TraceMode::Full, 3);
        with_recorder(&mut rec, || {
            sim_time(42.0);
            let _s = span(Phase::LinkReprice);
            drop(_s);
            event(TraceKind::Failure, 50.0, 7.0, 1.0);
            sample(Series::UtilCpu, 60.0, 0.5);
        });
        let report = rec.into_report();
        assert_eq!(report.records.len(), 2);
        let sp = &report.records[0];
        assert_eq!((sp.name, sp.ph, sp.lane), ("link_reprice", 'X', 3));
        assert_eq!(sp.sim_t, 42.0);
        let ev = &report.records[1];
        assert_eq!((ev.name, ev.ph, ev.a, ev.b), ("failure", 'i', 7.0, 1.0));
        assert_eq!(report.series[Series::UtilCpu as usize].len(), 1);
        assert_eq!(report.dropped, 0);
    }

    #[test]
    fn ring_is_bounded_and_overwrites_oldest() {
        let mut rec = Recorder::new(TraceMode::Full, 0);
        rec.cap = 4;
        with_recorder(&mut rec, || {
            for i in 0..10 {
                event(TraceKind::Arrival, i as f64, i as f64, 0.0);
            }
        });
        let report = rec.into_report();
        assert_eq!(report.records.len(), 4);
        assert_eq!(report.dropped, 6);
        // Chronological order, oldest surviving record first.
        let kept: Vec<f64> = report.records.iter().map(|r| r.sim_t).collect();
        assert_eq!(kept, vec![6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn lane_merge_keeps_per_lane_attribution() {
        let mut driver = Recorder::new(TraceMode::Full, DRIVER_LANE);
        let anchor = driver.anchor;
        for lane_id in 0..2u32 {
            let mut lane = Recorder::with_anchor(TraceMode::Full, lane_id, anchor);
            with_recorder(&mut lane, || {
                let _s = span(Phase::ShieldCheck);
                drop(_s);
                event(TraceKind::Placement, 1.0, lane_id as f64, 2.0);
            });
            driver.absorb_lane(lane);
        }
        with_recorder(&mut driver, || {
            let _b = span(Phase::EpochBarrier);
        });
        let report = driver.into_report();
        assert_eq!(report.lanes.len(), 3, "two lanes + the driver row");
        assert_eq!(report.lanes[0].0, 0);
        assert_eq!(report.lanes[1].0, 1);
        assert_eq!(report.lanes[2].0, DRIVER_LANE);
        assert_eq!(report.lanes[0].1.count[Phase::ShieldCheck as usize], 1);
        assert_eq!(report.lanes[2].1.count[Phase::EpochBarrier as usize], 1);
        let total = report.total_profile();
        assert_eq!(total.count[Phase::ShieldCheck as usize], 2);
    }

    #[test]
    fn jsonl_lines_parse_with_the_schema_keys() {
        let mut rec = Recorder::new(TraceMode::Full, 1);
        with_recorder(&mut rec, || {
            sim_time(5.0);
            let _s = span(Phase::QnetForward);
            drop(_s);
            event(TraceKind::Collision, 5.0, 0.0, 2.0);
            sample(Series::CollisionsWindow, 5.0, 2.0);
        });
        let jsonl = rec.into_report().to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            let doc = Json::parse(line).expect("JSONL line parses");
            for key in ["ts_us", "dur_us", "name", "ph", "sim_t", "lane", "a", "b"] {
                assert!(doc.get(key).is_some(), "missing {key} in {line}");
            }
        }
    }

    #[test]
    fn chrome_trace_parses_and_carries_all_record_types() {
        let mut rec = Recorder::new(TraceMode::Full, 0);
        with_recorder(&mut rec, || {
            let _s = span(Phase::EventDispatch);
            drop(_s);
            event(TraceKind::Handoff, 1.0, 0.0, 3.0);
            sample(Series::QueueDepth, 1.0, 12.0);
        });
        let doc = rec.into_report().to_chrome_trace();
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("chrome trace parses");
        let events = parsed.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(events.len(), 3);
        let phases: Vec<&str> =
            events.iter().map(|e| e.get("ph").and_then(|p| p.as_str()).unwrap()).collect();
        assert_eq!(phases, vec!["X", "i", "C"]);
        assert!(events[0].get("dur").is_some(), "X events need dur");
        assert_eq!(events[1].get("s").and_then(|s| s.as_str()), Some("t"));
    }

    #[test]
    fn trace_mode_parses_and_defaults_off() {
        assert_eq!(TraceMode::default(), TraceMode::Off);
        assert_eq!(TraceMode::parse("off"), Some(TraceMode::Off));
        assert_eq!(TraceMode::parse("profile"), Some(TraceMode::Profile));
        assert_eq!(TraceMode::parse("full"), Some(TraceMode::Full));
        assert_eq!(TraceMode::parse("verbose"), None);
    }

    #[test]
    fn nested_installation_restores_the_outer_recorder() {
        let mut outer = Recorder::new(TraceMode::Profile, DRIVER_LANE);
        let mut inner = Recorder::new(TraceMode::Profile, 0);
        with_recorder(&mut outer, || {
            with_recorder(&mut inner, || {
                let _s = span(Phase::ShieldCheck);
            });
            let _s = span(Phase::EpochBarrier);
        });
        assert_eq!(inner.profile.count[Phase::ShieldCheck as usize], 1);
        assert_eq!(outer.profile.count[Phase::ShieldCheck as usize], 0);
        assert_eq!(outer.profile.count[Phase::EpochBarrier as usize], 1);
    }
}
