//! Minimal declarative CLI flag parser (offline substitute for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
enum Kind {
    Bool,
    Value { default: Option<String> },
}

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    kind: Kind,
    help: String,
}

/// Declarative argument parser.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    program: String,
    about: String,
    specs: Vec<Spec>,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid(String, String),
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(n) => write!(f, "unknown flag --{n}"),
            CliError::MissingValue(n) => write!(f, "flag --{n} requires a value"),
            CliError::Invalid(n, v) => write!(f, "invalid value for --{n}: {v}"),
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

impl Cli {
    pub fn new(program: &str, about: &str) -> Cli {
        Cli { program: program.into(), about: about.into(), specs: Vec::new() }
    }

    /// Register a `--name <value>` flag with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            kind: Kind::Value { default: default.map(|s| s.into()) },
            help: help.into(),
        });
        self
    }

    /// Register a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec { name: name.into(), kind: Kind::Bool, help: help.into() });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nflags:\n", self.program, self.about);
        for s in &self.specs {
            let lhs = match &s.kind {
                Kind::Bool => format!("--{}", s.name),
                Kind::Value { default: Some(d) } => format!("--{} <v> [{}]", s.name, d),
                Kind::Value { default: None } => format!("--{} <v>", s.name),
            };
            out.push_str(&format!("  {lhs:<28} {}\n", s.help));
        }
        out
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for s in &self.specs {
            if let Kind::Value { default: Some(d) } = &s.kind {
                args.values.insert(s.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::Help);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                match &spec.kind {
                    Kind::Bool => {
                        args.flags.push(name);
                    }
                    Kind::Value { .. } => {
                        let v = match inline {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| CliError::MissingValue(name.clone()))?
                            }
                        };
                        args.values.insert(name, v);
                    }
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::Invalid(name.into(), v.into()))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::Invalid(name.into(), v.into()))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.get(name).ok_or_else(|| CliError::MissingValue(name.into()))?;
        v.parse().map_err(|_| CliError::Invalid(name.into(), v.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("edges", Some("25"), "number of edges")
            .opt("model", None, "model name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&argv(&[])).unwrap();
        assert_eq!(a.usize("edges").unwrap(), 25);
        assert!(a.get("model").is_none());
        assert!(!a.has("verbose"));
    }

    #[test]
    fn parses_values_and_flags() {
        let a = cli().parse(&argv(&["--edges", "10", "--verbose", "--model=vgg16", "pos1"])).unwrap();
        assert_eq!(a.usize("edges").unwrap(), 10);
        assert_eq!(a.get("model"), Some("vgg16"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(cli().parse(&argv(&["--nope"])), Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(cli().parse(&argv(&["--model"])), Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_number_rejected() {
        let a = cli().parse(&argv(&["--edges", "abc"])).unwrap();
        assert!(matches!(a.usize("edges"), Err(CliError::Invalid(..))));
    }

    #[test]
    fn help_flag() {
        assert!(matches!(cli().parse(&argv(&["-h"])), Err(CliError::Help)));
        assert!(cli().usage().contains("--edges"));
    }
}
