//! Minimal JSON: an emitter and a recursive-descent parser.
//!
//! Used to read `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and to dump experiment metrics.  Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not needed for the manifest).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------------------------------------------------------
    // accessors
    // ---------------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["artifacts", "qnet_fwd", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------------------------------------------------------------
    // emit
    // ---------------------------------------------------------------

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out);
        out
    }

    fn emit(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.emit(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_str(k, out);
                    out.push(':');
                    v.emit(out);
                }
                out.push('}');
            }
        }
    }

    // ---------------------------------------------------------------
    // parse
    // ---------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]), Some(&Json::Null));
        let b = v.as_obj().unwrap()["a"].as_arr().unwrap()[2].get("b").unwrap();
        assert_eq!(b.as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn emit_escapes_roundtrip() {
        let v = Json::Str("line1\nline2\t\"q\"\\".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "artifacts": {
            "qnet_fwd": {
              "file": "qnet_fwd.hlo.txt",
              "inputs": [{"dtype": "f32", "name": "w1", "shape": [36, 64]}],
              "outputs": [{"dtype": "f32", "name": "qvalues", "shape": [1, 11]}]
            }
          },
          "meta": {"qnet": {"state_dim": 36}}
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.at(&["artifacts", "qnet_fwd", "file"]).unwrap().as_str(),
            Some("qnet_fwd.hlo.txt")
        );
        assert_eq!(v.at(&["meta", "qnet", "state_dim"]).unwrap().as_usize(), Some(36));
        let shape = v.at(&["artifacts", "qnet_fwd", "inputs"]).unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.iter().map(|x| x.as_usize().unwrap()).collect::<Vec<_>>(), vec![36, 64]);
    }

    #[test]
    fn numbers_precise() {
        let v = Json::parse("[1e3, -2.5e-2, 123456789]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1000.0));
        assert_eq!(a[1].as_f64(), Some(-0.025));
        assert_eq!(a[2].as_f64(), Some(123456789.0));
    }
}
