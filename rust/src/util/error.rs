//! Minimal error type (offline substitute for `anyhow`).
//!
//! [`Error`] carries a human-readable context chain; [`Context`] mirrors
//! `anyhow::Context` for both `Result` and `Option`; the crate-root
//! [`bail!`](crate::bail) and [`format_err!`](crate::format_err) macros
//! replace `anyhow::bail!` / `anyhow::anyhow!`.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and thus `?` on any std error)
//! coherent.

use std::fmt;

/// An error with a context chain (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

/// `Result` defaulting to [`Error`], as `anyhow::Result` does.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to errors (and missing `Option` values).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`](crate::util::error::Error) from a format string.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::format_err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failing_io() -> Result<String> {
        let text = std::fs::read_to_string("/definitely/not/a/path")
            .with_context(|| "reading config".to_string())?;
        Ok(text)
    }

    #[test]
    fn io_error_converts_and_carries_context() {
        let err = failing_io().unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("reading config: "), "{msg}");
    }

    #[test]
    fn option_context() {
        let v: Option<usize> = None;
        let err = v.context("missing key").unwrap_err();
        assert_eq!(err.to_string(), "missing key");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn bail_and_format_err() {
        fn f(flag: bool) -> Result<usize> {
            if flag {
                bail!("bad flag {}", 42);
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap_err().to_string(), "bad flag 42");
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format_err!("x={}", 1).to_string(), "x=1");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = Error::msg("root").wrap("mid").wrap("outer");
        assert_eq!(e.to_string(), "outer: mid: root");
        assert_eq!(format!("{e:?}"), "outer: mid: root");
    }
}
