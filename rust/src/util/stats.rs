//! Summary statistics used by the metric collectors and figure harness.
//!
//! The paper reports medians with 5th/95th-percentile error bars
//! (Figures 4–13); [`Summary`] carries exactly those fields plus
//! min/max/mean for the task-per-device and utilization plots.

/// Percentile by linear interpolation on the sorted sample (inclusive).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn median(values: &[f64]) -> f64 {
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, 50.0)
}

pub fn mean(values: &[f64]) -> f64 {
    assert!(!values.is_empty());
    values.iter().sum::<f64>() / values.len() as f64
}

/// Mean of a possibly-empty slice (empty → 0.0) — the shared helper
/// behind the `RunMetrics::mean_*` accessors, where "no samples yet"
/// must read as zero overhead rather than panic.
pub fn mean_of(values: &[f64]) -> f64 {
    if values.is_empty() {
        0.0
    } else {
        values.iter().sum::<f64>() / values.len() as f64
    }
}

pub fn stddev(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    (values.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (values.len() - 1) as f64).sqrt()
}

/// Five-number-style summary matching the paper's plotting convention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub p5: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
    pub mean: f64,
    pub stddev: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        assert!(!values.is_empty(), "Summary::of(empty)");
        let mut v = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: v.len(),
            min: v[0],
            p5: percentile(&v, 5.0),
            median: percentile(&v, 50.0),
            p95: percentile(&v, 95.0),
            max: v[v.len() - 1],
            mean: mean(&v),
            stddev: stddev(&v),
        }
    }

    /// Spread of the error bars (max − min), the paper's variance proxy.
    pub fn spread(&self) -> f64 {
        self.max - self.min
    }
}

/// Tail-latency percentile summary (p50/p90/p99/p999) — the serving
/// workload's reporting convention, also used by `figures profile` for
/// per-phase span distributions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pcts {
    pub n: usize,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
}

impl Pcts {
    /// `None` on an empty sample (tail percentiles of nothing are
    /// meaningless, unlike [`mean_of`]'s zero convention).
    pub fn of(values: &[f64]) -> Option<Pcts> {
        if values.is_empty() {
            return None;
        }
        let mut v = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Pcts {
            n: v.len(),
            p50: percentile(&v, 50.0),
            p90: percentile(&v, 90.0),
            p99: percentile(&v, 99.0),
            p999: percentile(&v, 99.9),
        })
    }
}

/// Streaming accumulator when samples are too many to keep.
#[derive(Debug, Clone, Default)]
pub struct Accum {
    pub n: usize,
    sum: f64,
    sumsq: f64,
    pub min: f64,
    pub max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum { n: 0, sum: 0.0, sumsq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sumsq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sumsq / self.n as f64 - m * m).max(0.0) * self.n as f64 / (self.n - 1) as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_endpoints() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 25.0), 2.5);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.mean, 3.0);
        assert!(s.spread() == 4.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.min, 7.0);
        assert_eq!(s.p5, 7.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.max, 7.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn accum_matches_batch() {
        let vals = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut a = Accum::new();
        for &v in &vals {
            a.push(v);
        }
        assert!((a.mean() - mean(&vals)).abs() < 1e-12);
        assert!((a.stddev() - stddev(&vals)).abs() < 1e-9);
        assert_eq!(a.min, 2.0);
        assert_eq!(a.max, 9.0);
    }

    #[test]
    #[should_panic]
    fn percentile_empty_panics() {
        percentile(&[], 50.0);
    }

    #[test]
    fn mean_of_empty_is_zero() {
        assert_eq!(mean_of(&[]), 0.0);
        assert_eq!(mean_of(&[3.0]), 3.0);
        assert!((mean_of(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pcts_orders_the_tail() {
        let v: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let p = Pcts::of(&v).unwrap();
        assert_eq!(p.n, 1000);
        assert!(p.p50 <= p.p90 && p.p90 <= p.p99 && p.p99 <= p.p999);
        assert!((p.p50 - 500.5).abs() < 1.0);
        assert!(p.p999 > 990.0 && p.p999 <= 1000.0);
        assert_eq!(Pcts::of(&[]), None);
        let single = Pcts::of(&[4.0]).unwrap();
        assert_eq!((single.p50, single.p999), (4.0, 4.0));
    }

    #[test]
    fn pcts_empty_is_none_and_single_is_flat() {
        assert_eq!(Pcts::of(&[]), None);
        let p = Pcts::of(&[2.5]).unwrap();
        assert_eq!(p.n, 1);
        assert_eq!((p.p50, p.p90, p.p99, p.p999), (2.5, 2.5, 2.5, 2.5));
    }

    #[test]
    fn pcts_all_equal_values_collapse() {
        // A degenerate latency series (every request identical) must
        // report that value at every percentile, with no interpolation
        // drift.
        for n in [2usize, 3, 17, 1000] {
            let v = vec![0.125f64; n];
            let p = Pcts::of(&v).unwrap();
            assert_eq!(p.n, n);
            assert_eq!((p.p50, p.p90, p.p99, p.p999), (0.125, 0.125, 0.125, 0.125));
        }
    }

    #[test]
    fn pcts_is_order_invariant() {
        // `of` sorts internally: an unsorted (even adversarially
        // reversed or interleaved) sample must summarize identically to
        // its sorted twin, bit for bit.
        let sorted: Vec<f64> = (1..=101).map(|i| i as f64 * 0.37).collect();
        let mut reversed = sorted.clone();
        reversed.reverse();
        let mut interleaved = Vec::with_capacity(sorted.len());
        for (i, &x) in sorted.iter().enumerate() {
            if i % 2 == 0 {
                interleaved.push(x);
            } else {
                interleaved.insert(0, x);
            }
        }
        let p0 = Pcts::of(&sorted).unwrap();
        for v in [&reversed, &interleaved] {
            let p = Pcts::of(v).unwrap();
            assert_eq!(p.n, p0.n);
            assert_eq!(p.p50.to_bits(), p0.p50.to_bits());
            assert_eq!(p.p90.to_bits(), p0.p90.to_bits());
            assert_eq!(p.p99.to_bits(), p0.p99.to_bits());
            assert_eq!(p.p999.to_bits(), p0.p999.to_bits());
        }
    }
}
