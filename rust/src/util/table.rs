//! Aligned console tables for the figure-regeneration harness.
//!
//! Every `figures` subcommand prints the paper's series as one of these
//! tables so the rows can be diffed against the corresponding figure.

/// A simple right-padded text table with a title and column headers.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with 3 significant decimals, trimming noise.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let ax = x.abs();
    if ax >= 1000.0 {
        format!("{x:.0}")
    } else if ax >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

/// Format a value as a percentage string.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "jct"]);
        t.row(vec!["SROLE-C".into(), "123.4".into()]);
        t.row(vec!["RL".into(), "7.0".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // right-aligned: both data rows end at same column
        assert_eq!(lines[3].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn float_format() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(12345.6), "12346");
        assert_eq!(f(12.34), "12.3");
        assert_eq!(f(1.2345), "1.234");
        assert_eq!(pct(0.125), "12.5%");
    }
}
