//! Deterministic PRNG: PCG-XSH-RR 64/32 (O'Neill 2014).
//!
//! Every stochastic component of the simulator (node placement, workload
//! arrivals, epsilon-greedy exploration, demand noise) draws from an
//! explicitly seeded [`Rng`], so whole experiments replay bit-identically
//! from `ExperimentConfig::seed`.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotated output.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seed the generator. `seq` selects an independent stream.
    pub fn with_stream(seed: u64, seq: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (seq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child stream (for per-agent / per-node rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = (self.next_u64()).wrapping_add(tag.wrapping_mul(MULT));
        Rng::with_stream(seed, tag.wrapping_mul(2).wrapping_add(1))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's unbiased method.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = (((x as u128 * n as u128) >> 64) as u64, (x as u128 * n as u128) as u64);
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given rate (for Poisson arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn below_never_exceeds_bound() {
        let mut r = Rng::new(11);
        for n in 1..=17 {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }
}
