//! Criterion-style measurement harness (offline substitute).
//!
//! Each `benches/*.rs` binary (built with `harness = false`) constructs a
//! [`Bench`], registers closures, and gets warmup, repeated timed samples,
//! outlier-robust statistics and a rendered report.  Figure benches also
//! use [`Bench::report_series`] to print paper-figure series next to the
//! timing numbers.

use std::time::{Duration, Instant};

use super::json::{obj, Json};
use super::stats::Summary;
use super::table::{f, Table};

/// Configuration for one measurement run.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub samples: usize,
    /// Soft wall-clock cap per benchmark; sampling stops early past this.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 3, samples: 20, max_time: Duration::from_secs(60) }
    }
}

impl BenchConfig {
    /// Configuration for the whole-sweep figure benches: a full
    /// experiment grid per sample is expensive, so no warmup and few
    /// samples.  `SROLE_BENCH_FAST=1` drops to a single sample.
    pub fn sweep() -> BenchConfig {
        if std::env::var("SROLE_BENCH_FAST").is_ok() {
            BenchConfig { warmup_iters: 0, samples: 1, max_time: Duration::from_secs(60) }
        } else {
            BenchConfig { warmup_iters: 0, samples: 3, max_time: Duration::from_secs(300) }
        }
    }
}

/// Result of one registered benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn median_secs(&self) -> f64 {
        self.summary.median
    }
}

/// A named collection of benchmarks (one per paper table/figure cell).
pub struct Bench {
    pub title: String,
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(title: &str) -> Bench {
        let mut config = BenchConfig::default();
        // Fast mode for CI / smoke runs: SROLE_BENCH_FAST=1.
        if std::env::var("SROLE_BENCH_FAST").is_ok() {
            config.warmup_iters = 1;
            config.samples = 5;
            config.max_time = Duration::from_secs(10);
        }
        Bench { title: title.to_string(), config, results: Vec::new() }
    }

    pub fn with_config(title: &str, config: BenchConfig) -> Bench {
        Bench { title: title.to_string(), config, results: Vec::new() }
    }

    /// Measure `op` and record statistics under `name`.  The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn measure<T, F: FnMut() -> T>(&mut self, name: &str, mut op: F) -> &BenchResult {
        for _ in 0..self.config.warmup_iters {
            black_box(op());
        }
        let started = Instant::now();
        let mut samples = Vec::with_capacity(self.config.samples);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            black_box(op());
            samples.push(t0.elapsed().as_secs_f64());
            if started.elapsed() > self.config.max_time && samples.len() >= 3 {
                break;
            }
        }
        let summary = Summary::of(&samples);
        self.results.push(BenchResult { name: name.to_string(), summary, samples });
        self.results.last().unwrap()
    }

    /// Measure an op and report derived throughput (items/sec).
    pub fn measure_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        items: usize,
        op: F,
    ) -> f64 {
        let r = self.measure(name, op);
        items as f64 / r.summary.median
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render the timing table.
    pub fn report(&self) -> String {
        let mut t = Table::new(
            &format!("bench: {}", self.title),
            &["name", "median_s", "mean_s", "p5_s", "p95_s", "n"],
        );
        for r in &self.results {
            t.row(vec![
                r.name.clone(),
                format!("{:.6}", r.summary.median),
                format!("{:.6}", r.summary.mean),
                format!("{:.6}", r.summary.p5),
                format!("{:.6}", r.summary.p95),
                r.summary.n.to_string(),
            ]);
        }
        t.render()
    }

    pub fn print_report(&self) {
        print!("{}", self.report());
    }

    /// Write a machine-readable report `BENCH_<title>.json` into `dir`:
    /// mean/p50/p95 wall-milliseconds per registered benchmark, so the
    /// perf trajectory is tracked across PRs.
    pub fn write_json(&self, dir: &std::path::Path) -> std::io::Result<std::path::PathBuf> {
        let entries = Json::Arr(
            self.results
                .iter()
                .map(|r| {
                    obj(vec![
                        ("name", Json::Str(r.name.clone())),
                        ("mean_ms", Json::Num(r.summary.mean * 1e3)),
                        ("p50_ms", Json::Num(r.summary.median * 1e3)),
                        ("p95_ms", Json::Num(r.summary.p95 * 1e3)),
                        ("n", Json::Num(r.summary.n as f64)),
                    ])
                })
                .collect(),
        );
        let doc = obj(vec![("bench", Json::Str(self.title.clone())), ("entries", entries)]);
        let path = dir.join(format!("BENCH_{}.json", self.title));
        std::fs::write(&path, doc.to_string())?;
        Ok(path)
    }

    /// Print a paper-figure series (x, per-method values) alongside timings.
    pub fn report_series(title: &str, x_label: &str, methods: &[&str], rows: &[(String, Vec<f64>)]) {
        let mut headers = vec![x_label];
        headers.extend_from_slice(methods);
        let mut t = Table::new(title, &headers);
        for (x, vals) in rows {
            let mut cells = vec![x.clone()];
            cells.extend(vals.iter().map(|v| f(*v)));
            t.row(cells);
        }
        t.print();
    }
}

/// Optimizer barrier (stable-rust substitute for `std::hint::black_box`
/// semantics; uses a volatile read).
pub fn black_box<T>(x: T) -> T {
    unsafe {
        let y = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::with_config(
            "t",
            BenchConfig { warmup_iters: 1, samples: 5, max_time: Duration::from_secs(5) },
        );
        b.measure("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(b.results().len(), 1);
        let r = &b.results()[0];
        assert!(r.summary.median > 0.0);
        assert!(b.report().contains("spin"));
    }

    #[test]
    fn throughput_positive() {
        let mut b = Bench::with_config(
            "t",
            BenchConfig { warmup_iters: 0, samples: 3, max_time: Duration::from_secs(5) },
        );
        let thr = b.measure_throughput("noop", 1000, || 1 + 1);
        assert!(thr > 0.0);
    }

    #[test]
    fn json_report_round_trips() {
        let mut b = Bench::with_config(
            "jsontest",
            BenchConfig { warmup_iters: 0, samples: 3, max_time: Duration::from_secs(5) },
        );
        b.measure("noop", || 1 + 1);
        let dir = std::env::temp_dir();
        let path = b.write_json(&dir).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap() == "BENCH_jsontest.json");
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let entries = parsed.get("entries").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("name").and_then(|n| n.as_str()), Some("noop"));
        assert!(entries[0].get("p95_ms").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn black_box_identity() {
        assert_eq!(black_box(42), 42);
        assert_eq!(black_box(String::from("x")), "x");
    }
}
