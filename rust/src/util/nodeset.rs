//! Dense bitset keyed by `NodeId` — the O(1) membership index behind the
//! shield hot path.
//!
//! The seed implementation answered "is this node a member / on a
//! boundary / an allowed target?" with `Vec::contains` scans, making each
//! shield round O(proposals × nodes).  A [`NodeSet`] answers the same
//! question with one word load, and the sub-cluster / shield structures
//! precompute one per membership relation.

/// A fixed-universe bitset over node ids (`0..n`).  Queries outside the
/// universe return `false` rather than panicking, matching the semantics
/// of a `Vec::contains` scan.
#[derive(Debug, Clone, Default)]
pub struct NodeSet {
    words: Vec<u64>,
    len: usize,
}

/// Equality is by membership, not allocation: sets with the same members
/// but different universe sizes compare equal.
impl PartialEq for NodeSet {
    fn eq(&self, other: &NodeSet) -> bool {
        if self.len != other.len {
            return false;
        }
        let common = self.words.len().min(other.words.len());
        self.words[..common] == other.words[..common]
            && self.words[common..].iter().all(|&w| w == 0)
            && other.words[common..].iter().all(|&w| w == 0)
    }
}

impl Eq for NodeSet {}

impl NodeSet {
    /// Empty set over the universe `0..n`.
    pub fn with_universe(n: usize) -> NodeSet {
        NodeSet { words: vec![0; n.div_ceil(64)], len: 0 }
    }

    /// Build from a slice of members (universe `0..n`).
    pub fn from_slice(n: usize, members: &[usize]) -> NodeSet {
        let mut s = NodeSet::with_universe(n);
        for &m in members {
            s.insert(m);
        }
        s
    }

    /// Insert; grows the universe if needed.  Returns true when newly
    /// inserted.
    pub fn insert(&mut self, node: usize) -> bool {
        let w = node / 64;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        let mask = 1u64 << (node % 64);
        if self.words[w] & mask == 0 {
            self.words[w] |= mask;
            self.len += 1;
            true
        } else {
            false
        }
    }

    /// Remove; returns true when the node was a member.  Out-of-universe
    /// removals are no-ops (nothing to remove).
    pub fn remove(&mut self, node: usize) -> bool {
        let w = node / 64;
        if w >= self.words.len() {
            return false;
        }
        let mask = 1u64 << (node % 64);
        if self.words[w] & mask != 0 {
            self.words[w] &= !mask;
            self.len -= 1;
            true
        } else {
            false
        }
    }

    #[inline]
    pub fn contains(&self, node: usize) -> bool {
        self.words
            .get(node / 64)
            .map(|w| w & (1u64 << (node % 64)) != 0)
            .unwrap_or(false)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Remove every member, keeping the allocated universe.
    pub fn clear(&mut self) {
        self.words.fill(0);
        self.len = 0;
    }

    /// Members in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }

    /// Union in place.
    pub fn union_with(&mut self, other: &NodeSet) {
        if other.words.len() > self.words.len() {
            self.words.resize(other.words.len(), 0);
        }
        for (a, &b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
        self.len = self.words.iter().map(|w| w.count_ones() as usize).sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_len() {
        let mut s = NodeSet::with_universe(10);
        assert!(!s.contains(3));
        assert!(s.insert(3));
        assert!(!s.insert(3), "double insert reports false");
        assert!(s.insert(9));
        assert!(s.contains(3) && s.contains(9));
        assert_eq!(s.len(), 2);
        assert!(!s.contains(99), "out-of-universe query is false, not a panic");
    }

    #[test]
    fn from_slice_and_iter_ascending() {
        let s = NodeSet::from_slice(200, &[150, 3, 64, 63, 3]);
        assert_eq!(s.len(), 4);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![3, 63, 64, 150]);
    }

    #[test]
    fn matches_vec_contains_semantics() {
        let members = vec![1usize, 5, 17, 64, 65, 127];
        let s = NodeSet::from_slice(128, &members);
        for node in 0..140 {
            assert_eq!(s.contains(node), members.contains(&node), "node {node}");
        }
    }

    #[test]
    fn remove_matches_membership() {
        let mut s = NodeSet::from_slice(128, &[3, 64, 100]);
        assert!(s.remove(64));
        assert!(!s.remove(64), "double remove reports false");
        assert!(!s.remove(5), "removing a non-member reports false");
        assert!(!s.remove(500), "out-of-universe removal is a no-op");
        assert_eq!(s.len(), 2);
        assert!(s.contains(3) && s.contains(100) && !s.contains(64));
        assert!(s.insert(64), "removed nodes can be re-inserted");
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn clear_keeps_universe() {
        let mut s = NodeSet::from_slice(100, &[10, 70]);
        s.clear();
        assert_eq!(s.len(), 0);
        assert!(!s.contains(10));
        s.insert(70);
        assert!(s.contains(70));
    }

    #[test]
    fn insert_grows_universe() {
        let mut s = NodeSet::with_universe(1);
        assert!(s.insert(500));
        assert!(s.contains(500));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn equality_ignores_universe_size() {
        assert_eq!(NodeSet::from_slice(64, &[1, 2]), NodeSet::from_slice(256, &[1, 2]));
        assert_ne!(NodeSet::from_slice(64, &[1]), NodeSet::from_slice(256, &[1, 2]));
        assert_ne!(NodeSet::from_slice(256, &[1, 200]), NodeSet::from_slice(256, &[1, 2]));
        assert_eq!(NodeSet::with_universe(0), NodeSet::with_universe(512));
    }

    #[test]
    fn union() {
        let mut a = NodeSet::from_slice(64, &[1, 2]);
        let b = NodeSet::from_slice(256, &[2, 200]);
        a.union_with(&b);
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![1, 2, 200]);
        assert_eq!(a.len(), 3);
    }
}
