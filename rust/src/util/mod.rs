//! Self-contained utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate
//! closure vendored, so the roles usually played by `rand`, `serde_json`,
//! `clap` and `criterion` are implemented here from scratch:
//!
//! * [`rng`] — PCG-XSH-RR 64/32 deterministic PRNG;
//! * [`stats`] — medians, percentiles, summary statistics;
//! * [`json`] — a small JSON emitter + recursive-descent parser (used for
//!   `artifacts/manifest.json` and metric dumps);
//! * [`table`] — aligned console tables for the figure harness;
//! * [`cli`] — a minimal declarative flag parser for the binaries;
//! * [`benchkit`] — a criterion-style measurement harness for `benches/`.

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
