//! Self-contained utility substrates.
//!
//! The build environment is fully offline (no external crates at all),
//! so the roles usually played by `rand`, `serde_json`, `clap`,
//! `criterion` and `anyhow` are implemented here from scratch:
//!
//! * [`rng`] — PCG-XSH-RR 64/32 deterministic PRNG;
//! * [`stats`] — medians, percentiles, summary statistics;
//! * [`json`] — a small JSON emitter + recursive-descent parser (used for
//!   `artifacts/manifest.json` and metric dumps);
//! * [`table`] — aligned console tables for the figure harness;
//! * [`cli`] — a minimal declarative flag parser for the binaries;
//! * [`benchkit`] — a criterion-style measurement harness for `benches/`;
//! * [`error`] — an `anyhow`-style error type with context chains;
//! * [`nodeset`] — a dense bitset keyed by `NodeId` (the shield-hot-path
//!   membership index).

pub mod benchkit;
pub mod cli;
pub mod error;
pub mod json;
pub mod nodeset;
pub mod rng;
pub mod stats;
pub mod table;

pub use nodeset::NodeSet;
pub use rng::Rng;
