//! Typed view of `artifacts/manifest.json`.
//!
//! The manifest is the contract between `python/compile/aot.py` and the
//! Rust runtime: for every artifact, the ordered input/output tensor
//! names, shapes and dtypes, plus model hyper-parameters under `meta`.

use std::collections::BTreeMap;

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Tensor dtypes used by the artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

/// One tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's file and signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    meta: Json,
}

fn tensor_list(j: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = j.as_arr().with_context(|| format!("{what} not an array"))?;
    arr.iter()
        .map(|t| {
            let name = t.get("name").and_then(Json::as_str).context("tensor name")?.to_string();
            let dtype = Dtype::parse(t.get("dtype").and_then(Json::as_str).context("dtype")?)?;
            let shape = t
                .get("shape")
                .and_then(Json::as_arr)
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest json")?;
        let arts = j.get("artifacts").and_then(Json::as_obj).context("artifacts key")?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in arts {
            let file = a.get("file").and_then(Json::as_str).context("file")?.to_string();
            let inputs = tensor_list(a.get("inputs").context("inputs")?, "inputs")?;
            let outputs = tensor_list(a.get("outputs").context("outputs")?, "outputs")?;
            artifacts.insert(name.clone(), ArtifactSpec { file, inputs, outputs });
        }
        let meta = j.get("meta").cloned().unwrap_or(Json::Obj(BTreeMap::new()));
        Ok(Manifest { artifacts, meta })
    }

    /// Lookup `meta.<section>.<key>` as usize.
    pub fn meta_usize(&self, section: &str, key: &str) -> Result<usize> {
        self.meta
            .at(&[section, key])
            .and_then(Json::as_usize)
            .with_context(|| format!("meta.{section}.{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "qnet_fwd": {
          "file": "qnet_fwd.hlo.txt",
          "inputs": [
            {"dtype": "f32", "name": "w1", "shape": [36, 64]},
            {"dtype": "f32", "name": "states", "shape": [1, 36]}
          ],
          "outputs": [{"dtype": "f32", "name": "qvalues", "shape": [1, 11]}]
        }
      },
      "meta": {"qnet": {"state_dim": 36, "num_actions": 11}}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["qnet_fwd"];
        assert_eq!(a.file, "qnet_fwd.hlo.txt");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![36, 64]);
        assert_eq!(a.inputs[0].dtype, Dtype::F32);
        assert_eq!(a.inputs[0].elems(), 36 * 64);
        assert_eq!(a.outputs[0].name, "qvalues");
        assert_eq!(m.meta_usize("qnet", "state_dim").unwrap(), 36);
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_meta_key_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.meta_usize("qnet", "nope").is_err());
        assert!(m.meta_usize("lm", "vocab").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = crate::runtime::Engine::default_dir();
        let path = dir.join("manifest.json");
        let Ok(text) = std::fs::read_to_string(&path) else {
            eprintln!("skipping: no {}", path.display());
            return;
        };
        let m = Manifest::parse(&text).unwrap();
        for name in ["qnet_init", "qnet_fwd", "qnet_train", "lm_init", "lm_grad", "lm_update", "lm_eval"] {
            assert!(m.artifacts.contains_key(name), "missing {name}");
        }
        // Manifests regenerated since the batched decision path also
        // carry the fixed-lane forward; its states slot must agree with
        // `meta.qnet.fwd_batch`.
        if let Some(batch) = m.artifacts.get("qnet_fwd_batch") {
            let lanes = m.meta_usize("qnet", "fwd_batch").unwrap();
            let state_dim = m.meta_usize("qnet", "state_dim").unwrap();
            let num_actions = m.meta_usize("qnet", "num_actions").unwrap();
            let states = batch.inputs.last().unwrap();
            assert_eq!(states.shape, vec![lanes, state_dim]);
            assert_eq!(batch.outputs[0].shape, vec![lanes, num_actions]);
        }
    }
}
