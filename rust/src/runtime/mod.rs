//! PJRT runtime: loads the AOT-compiled HLO text artifacts and executes
//! them on the request path — Python is never involved at run time.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO *text* is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.
//!
//! [`manifest`] mirrors `artifacts/manifest.json` (written by
//! `python/compile/aot.py`); [`Engine`] compiles artifacts on demand and
//! validates every call against the declared input/output signature.

pub mod lm;
pub mod manifest;
pub mod qnet;

/// Host-literal stand-in for the vendored `xla` crate.  The stub is
/// *always* compiled — `cargo test --features pjrt --no-run` type-checks
/// the PJRT-facing code in every build (CI's stub-feature gate).  The
/// real crate takes over only when it is actually vendored into
/// `[dependencies]` and the build sets `--cfg pjrt_vendored` (declared in
/// Cargo.toml's `[lints.rust]` check-cfg list).  Public because the
/// runtime's public API (literal helpers, session parameter vectors)
/// exposes its types.
pub mod pjrt_stub;

pub use manifest::{Dtype, Manifest, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::bail;
use crate::util::error::{Context, Result};

#[cfg(not(pjrt_vendored))]
use self::pjrt_stub as xla;

/// Whether artifact execution is actually backed by PJRT in this build:
/// the `pjrt` feature requested *and* the vendored crate present.
pub const PJRT_AVAILABLE: bool = cfg!(all(feature = "pjrt", pjrt_vendored));

/// A compiled artifact ready to execute.
pub struct Artifact {
    pub name: String,
    pub spec: manifest::ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with positional literal inputs; returns the decomposed
    /// output tuple as literals, validated against the manifest.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let out = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = out[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }
}

/// The PJRT engine: one CPU client plus lazily compiled artifacts.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    compiled: HashMap<String, Artifact>,
}

impl Engine {
    /// Open `dir` (containing `manifest.json` + `*.hlo.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, dir, manifest, compiled: HashMap::new() })
    }

    /// Default artifacts directory: `$SROLE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SROLE_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            // Walk up from cwd to find an `artifacts/manifest.json`.
            let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            loop {
                let cand = cur.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !cur.pop() {
                    return PathBuf::from("artifacts");
                }
            }
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the named artifact.
    pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .get(name)
                .with_context(|| format!("artifact {name} not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            self.compiled
                .insert(name.to_string(), Artifact { name: name.to_string(), spec, exe });
        }
        Ok(&self.compiled[name])
    }

    /// Convenience: run an artifact by name.
    pub fn run(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.artifact(name)?.run(inputs)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an f32 literal of the given shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32: shape {:?} wants {} elems, got {}", shape, n, data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32: shape {:?} wants {} elems, got {}", shape, n, data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Scalar literals.
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read back an f32 literal as a vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read back a scalar f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != 1 {
        bail!("expected scalar, got {} elems", v.len());
    }
    Ok(v[0])
}

/// Test helper: open a fresh engine if artifacts exist and PJRT is
/// available, else None (lets `cargo test` pass before `make artifacts`
/// and in stub builds).
#[cfg(test)]
pub(crate) fn test_engine_owned() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime test: no artifacts at {}", dir.display());
        return None;
    }
    match Engine::open(dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping runtime test: {e}");
            None
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
        assert!(lit_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn engine_opens_and_compiles_qnet_fwd() {
        let Some(mut eng) = test_engine_owned() else { return };
        assert_eq!(eng.platform(), "cpu");
        let art = eng.artifact("qnet_fwd").unwrap();
        assert_eq!(art.spec.inputs.len(), 7);
        assert_eq!(art.spec.outputs.len(), 1);
    }

    #[test]
    fn qnet_init_then_fwd_roundtrip() {
        let Some(mut eng) = test_engine_owned() else { return };
        let params = eng.run("qnet_init", &[scalar_i32(0)]).unwrap();
        assert_eq!(params.len(), 6);
        let state_dim = eng.manifest.meta_usize("qnet", "state_dim").unwrap();
        let na = eng.manifest.meta_usize("qnet", "num_actions").unwrap();
        let state = lit_f32(&[1, state_dim], &vec![0.1; state_dim]).unwrap();
        let mut inputs: Vec<xla::Literal> = params;
        inputs.push(state);
        let out = eng.run("qnet_fwd", &inputs).unwrap();
        let q = to_vec_f32(&out[0]).unwrap();
        assert_eq!(q.len(), na);
        assert!(q.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn wrong_arity_is_rejected() {
        let Some(mut eng) = test_engine_owned() else { return };
        let err = eng.run("qnet_fwd", &[scalar_i32(0)]);
        assert!(err.is_err());
    }

    #[test]
    fn qnet_init_deterministic_in_seed() {
        let Some(mut eng) = test_engine_owned() else { return };
        let a = eng.run("qnet_init", &[scalar_i32(7)]).unwrap();
        let b = eng.run("qnet_init", &[scalar_i32(7)]).unwrap();
        let c = eng.run("qnet_init", &[scalar_i32(8)]).unwrap();
        assert_eq!(to_vec_f32(&a[0]).unwrap(), to_vec_f32(&b[0]).unwrap());
        assert_ne!(to_vec_f32(&a[0]).unwrap(), to_vec_f32(&c[0]).unwrap());
    }
}
