//! Q-network session over the `qnet_*` artifacts.
//!
//! Parameters live either as PJRT literals (every call a pure PJRT
//! execution) or in a pure-host MLP mirror with the same geometry
//! ([`QNetSession::new_host`]), which runs in stub builds with no PJRT
//! client.  This is the function approximator behind
//! [`DqnPolicy`](crate::rl::dqn::DqnPolicy): `fwd_into` scores a single
//! decision state (B=1 artifact), `fwd_batch_into` scores a whole wave
//! round of states in fixed-lane chunks (the batched decision path),
//! `train` runs one TD mini-batch step against a target-network copy.

use crate::bail;
use crate::util::error::Result;
use crate::util::Rng;

use super::{lit_f32, lit_i32, scalar_f32, scalar_i32, to_scalar_f32, Engine};

#[cfg(not(pjrt_vendored))]
use super::pjrt_stub as xla;

/// Host-backend geometry — mirrors `meta.qnet` in the compiled manifest
/// (`python/compile/model.py`: 36 → 64 → 64 → 11).
const HOST_STATE_DIM: usize = 36;
const HOST_HIDDEN: usize = 64;
const HOST_NUM_ACTIONS: usize = 11;
/// Fixed batch-lane width of the host backend (the compiled
/// `qnet_fwd_batch` artifact publishes its own via `meta.qnet.fwd_batch`).
pub const HOST_FWD_LANES: usize = 32;

/// Pure-host parameter set: `[w1, b1, w2, b2, w3, b3]`, weights stored
/// input-major (`w[i * n_out + j]`), exactly the layout and order of the
/// compiled artifact's parameter tuple.
struct HostNet {
    params: [Vec<f32>; 6],
    target: [Vec<f32>; 6],
    /// Hidden-activation panels for the batched forward
    /// (`HOST_FWD_LANES × HOST_HIDDEN`, reused across calls).
    h1: Vec<f32>,
    h2: Vec<f32>,
}

/// Owned Q-network parameters + target-network copy.
pub struct QNetSession<'e> {
    engine: Option<&'e mut Engine>,
    host: Option<HostNet>,
    pub params: Vec<xla::Literal>,
    pub target: Vec<xla::Literal>,
    pub state_dim: usize,
    pub num_actions: usize,
    pub train_batch: usize,
    train_steps: usize,
    /// Sync the target network every this many train steps.
    pub target_sync_every: usize,
    /// Cached `qnet_fwd` input vector: the cloned parameter literals
    /// plus one reusable state slot at the end.  Rebuilt lazily after
    /// every parameter update; on the steady-state decision path each
    /// forward only overwrites the state slot in place.
    fwd_inputs: Option<Vec<xla::Literal>>,
    /// Cached `qnet_fwd_batch` input vector, same lifecycle as
    /// `fwd_inputs` but with a `[fwd_lanes, state_dim]` states slot.
    batch_inputs: Option<Vec<xla::Literal>>,
    /// Rows per batched forward: the fixed lane width every chunk is
    /// padded up to.
    fwd_lanes: usize,
    /// Padded lane-size staging area for the current chunk's states.
    batch_scratch: Vec<f32>,
    /// Lane-size output staging (`fwd_lanes × num_actions`).
    batch_out: Vec<f32>,
    batch_fwds: usize,
    batch_rows: usize,
    batch_pad_rows: usize,
    /// Fault-injection hook: each pending fault fails one forward call
    /// (chunk or single row) with an error, exercising the
    /// greedy-by-utilization fallback path end to end.
    faults_to_inject: usize,
}

/// One TD training batch (row-major, `len == batch`).
pub struct TdBatch {
    pub states: Vec<f32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub next_states: Vec<f32>,
    pub dones: Vec<f32>,
}

impl TdBatch {
    /// Pre-sized scratch for `batch` rows of `state_dim` features —
    /// reused across train steps via [`TdBatch::clear`].
    pub fn with_capacity(batch: usize, state_dim: usize) -> TdBatch {
        TdBatch {
            states: Vec::with_capacity(batch * state_dim),
            actions: Vec::with_capacity(batch),
            rewards: Vec::with_capacity(batch),
            next_states: Vec::with_capacity(batch * state_dim),
            dones: Vec::with_capacity(batch),
        }
    }

    /// Empty every column, keeping the allocations.
    pub fn clear(&mut self) {
        self.states.clear();
        self.actions.clear();
        self.rewards.clear();
        self.next_states.clear();
        self.dones.clear();
    }
}

/// Overwrite the cached state slot with a fresh state (host stub: an
/// in-place copy; vendored PJRT: rebuild the device literal).
#[cfg(not(pjrt_vendored))]
fn refill_state(slot: &mut xla::Literal, _dims: &[usize], state: &[f32]) -> Result<()> {
    slot.copy_from_f32(state)
}

#[cfg(pjrt_vendored)]
fn refill_state(slot: &mut xla::Literal, dims: &[usize], state: &[f32]) -> Result<()> {
    *slot = lit_f32(dims, state)?;
    Ok(())
}

/// Read the Q-value row into a caller buffer (host stub: no allocation).
#[cfg(not(pjrt_vendored))]
fn read_q_row(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_to_f32(out)
}

#[cfg(pjrt_vendored)]
fn read_q_row(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != out.len() {
        crate::bail!("q row has {} elems, sink has {}", v.len(), out.len());
    }
    out.copy_from_slice(&v);
    Ok(())
}

/// Refill the cached `[lanes, state_dim]` batch states slot: `rows` real
/// rows, zero pad tail (host stub: one vectorized in-place row copy;
/// vendored PJRT: rebuild the device literal from a padded buffer).
#[cfg(not(pjrt_vendored))]
fn refill_batch_states(
    slot: &mut xla::Literal,
    _dims: &[usize],
    states: &[f32],
    rows: usize,
    row_len: usize,
) -> Result<()> {
    slot.copy_rows_from_f32(states, rows, row_len)
}

#[cfg(pjrt_vendored)]
fn refill_batch_states(
    slot: &mut xla::Literal,
    dims: &[usize],
    states: &[f32],
    rows: usize,
    row_len: usize,
) -> Result<()> {
    let mut padded = vec![0.0f32; dims.iter().product()];
    padded[..rows * row_len].copy_from_slice(&states[..rows * row_len]);
    *slot = lit_f32(dims, &padded)?;
    Ok(())
}

/// One dense output row: `out[j] = act(b[j] + Σ_i x[i]·w[i·n + j])`,
/// accumulating `i` in ascending order — the accumulation-order contract
/// shared with [`dense_panel`], which makes the per-row and batched host
/// forwards bitwise identical.  Weight reads stride by `n`: this is the
/// natural one-row kernel and the in-tree reference the batch kernel is
/// pinned against.
fn dense_row(x: &[f32], w: &[f32], b: &[f32], n: usize, relu: bool, out: &mut [f32]) {
    for (j, o) in out[..n].iter_mut().enumerate() {
        let mut acc = b[j];
        for (i, &xi) in x.iter().enumerate() {
            acc += xi * w[i * n + j];
        }
        *o = if relu && acc < 0.0 { 0.0 } else { acc };
    }
}

/// Batched dense layer over a row panel: for each input feature `i`
/// (ascending), stream weight row `w[i·n..]` across every panel row —
/// `out[r][j] += x[r][i]·w[i][j]`.  Every accumulator `out[r][j]` sums
/// the same terms in the same ascending-`i` order as [`dense_row`], so
/// results are bitwise identical row-for-row; the difference is the
/// unit-stride inner loop over a contiguous weight row, which the
/// one-row kernel cannot have — that is where the measured batch
/// speedup comes from.
#[allow(clippy::too_many_arguments)]
fn dense_panel(
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    b: &[f32],
    n: usize,
    relu: bool,
    out: &mut [f32],
) {
    for r in 0..rows {
        let xr = &x[r * k..r * k + k];
        let or = &mut out[r * n..r * n + n];
        or.copy_from_slice(&b[..n]);
        for (i, &xi) in xr.iter().enumerate() {
            let wr = &w[i * n..i * n + n];
            for (o, &wj) in or.iter_mut().zip(wr) {
                *o += xi * wj;
            }
        }
    }
    if relu {
        for v in &mut out[..rows * n] {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

/// Panel forward through the full 36 → 64 → 64 → 11 MLP with the given
/// parameter set, leaving hidden activations in `h1`/`h2`.
fn mlp_panel(
    p: &[Vec<f32>; 6],
    x: &[f32],
    rows: usize,
    h1: &mut [f32],
    h2: &mut [f32],
    out: &mut [f32],
) {
    dense_panel(x, rows, HOST_STATE_DIM, &p[0], &p[1], HOST_HIDDEN, true, h1);
    dense_panel(h1, rows, HOST_HIDDEN, &p[2], &p[3], HOST_HIDDEN, true, h2);
    dense_panel(h2, rows, HOST_HIDDEN, &p[4], &p[5], HOST_NUM_ACTIONS, false, out);
}

/// Box-Muller standard normal off the deterministic experiment stream.
fn normal(rng: &mut Rng) -> f64 {
    let u1 = rng.f64().max(1e-12);
    let u2 = rng.f64();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

/// He-initialized host parameters, matching the compiled `qnet_init`
/// scheme (normal · √(2/fan_in) weights, zero biases) on the crate RNG.
fn host_init(rng: &mut Rng) -> [Vec<f32>; 6] {
    let he = |rng: &mut Rng, fan_in: usize, len: usize| -> Vec<f32> {
        let sd = (2.0 / fan_in as f64).sqrt();
        (0..len).map(|_| (normal(rng) * sd) as f32).collect()
    };
    [
        he(rng, HOST_STATE_DIM, HOST_STATE_DIM * HOST_HIDDEN),
        vec![0.0; HOST_HIDDEN],
        he(rng, HOST_HIDDEN, HOST_HIDDEN * HOST_HIDDEN),
        vec![0.0; HOST_HIDDEN],
        he(rng, HOST_HIDDEN, HOST_HIDDEN * HOST_NUM_ACTIONS),
        vec![0.0; HOST_NUM_ACTIONS],
    ]
}

/// Backprop one dense layer: SGD-update `w`/`b` from the output-side
/// gradient `g_out` and return the input-side gradient (masked by the
/// input activations' ReLU derivative when `mask` — the inputs of every
/// hidden-to-hidden layer are post-ReLU, so `x > 0` is exactly `relu'`).
#[allow(clippy::too_many_arguments)]
fn backprop_dense(
    w: &mut [f32],
    b: &mut [f32],
    x: &[f32],
    g_out: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    lr: f32,
    mask: bool,
) -> Vec<f32> {
    let mut g_in = vec![0.0f32; rows * k];
    for r in 0..rows {
        let go = &g_out[r * n..r * n + n];
        let xr = &x[r * k..r * k + k];
        let gr = &mut g_in[r * k..r * k + k];
        for i in 0..k {
            if mask && xr[i] <= 0.0 {
                continue;
            }
            let wr = &w[i * n..i * n + n];
            let mut acc = 0.0f32;
            for (gj, &wj) in go.iter().zip(wr) {
                acc += gj * wj;
            }
            gr[i] = acc;
        }
    }
    for r in 0..rows {
        let go = &g_out[r * n..r * n + n];
        let xr = &x[r * k..r * k + k];
        for i in 0..k {
            let wr = &mut w[i * n..i * n + n];
            let xi = xr[i];
            for (wj, &gj) in wr.iter_mut().zip(go) {
                *wj -= lr * xi * gj;
            }
        }
        for (bj, &gj) in b.iter_mut().zip(go) {
            *bj -= lr * gj;
        }
    }
    g_in
}

impl HostNet {
    /// Per-row reference forward (see [`dense_row`]).
    fn fwd_row(&mut self, state: &[f32], out: &mut [f32]) {
        let h1 = &mut self.h1[..HOST_HIDDEN];
        let h2 = &mut self.h2[..HOST_HIDDEN];
        dense_row(state, &self.params[0], &self.params[1], HOST_HIDDEN, true, h1);
        dense_row(h1, &self.params[2], &self.params[3], HOST_HIDDEN, true, h2);
        dense_row(h2, &self.params[4], &self.params[5], HOST_NUM_ACTIONS, false, out);
    }

    /// One TD SGD step over a full batch; returns the (squared-error)
    /// loss.  The host trainer is a lightweight stand-in for the compiled
    /// Huber-loss artifact, not bitwise-pinned to it — the host backend
    /// is its own reference (its row and batch *forwards* are what the
    /// equivalence tests pin to each other).
    fn train_step(&mut self, batch: &TdBatch, b: usize, lr: f32, gamma: f32) -> f32 {
        const H: usize = HOST_HIDDEN;
        const A: usize = HOST_NUM_ACTIONS;
        let mut h1 = vec![0.0f32; b * H];
        let mut h2 = vec![0.0f32; b * H];
        let mut q = vec![0.0f32; b * A];
        mlp_panel(&self.params, &batch.states, b, &mut h1, &mut h2, &mut q);
        let mut th1 = vec![0.0f32; b * H];
        let mut th2 = vec![0.0f32; b * H];
        let mut tq = vec![0.0f32; b * A];
        mlp_panel(&self.target, &batch.next_states, b, &mut th1, &mut th2, &mut tq);
        let mut g3 = vec![0.0f32; b * A];
        let mut loss = 0.0f32;
        for r in 0..b {
            let best = tq[r * A..r * A + A].iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let target = batch.rewards[r] + gamma * (1.0 - batch.dones[r]) * best;
            let a = (batch.actions[r].max(0) as usize).min(A - 1);
            let err = q[r * A + a] - target;
            loss += 0.5 * err * err;
            g3[r * A + a] = err / b as f32;
        }
        let [w1, b1, w2, b2, w3, b3] = &mut self.params;
        let g2 = backprop_dense(w3, b3, &h2, &g3, b, H, A, lr, true);
        let g1 = backprop_dense(w2, b2, &h1, &g2, b, H, H, lr, true);
        backprop_dense(w1, b1, &batch.states, &g1, b, HOST_STATE_DIM, H, lr, false);
        loss / b as f32
    }
}

impl<'e> QNetSession<'e> {
    /// Initialize from the `qnet_init` artifact with the given seed.
    pub fn new(engine: &'e mut Engine, seed: i32) -> Result<QNetSession<'e>> {
        let state_dim = engine.manifest.meta_usize("qnet", "state_dim")?;
        let num_actions = engine.manifest.meta_usize("qnet", "num_actions")?;
        let train_batch = engine.manifest.meta_usize("qnet", "train_batch")?;
        // Older manifests predate the batch-forward artifact; fall back
        // to the train width so chunking stays well-defined.
        let fwd_lanes = engine.manifest.meta_usize("qnet", "fwd_batch").unwrap_or(train_batch);
        let params = engine.run("qnet_init", &[scalar_i32(seed)])?;
        let target = engine.run("qnet_init", &[scalar_i32(seed)])?;
        Ok(QNetSession {
            engine: Some(engine),
            host: None,
            params,
            target,
            state_dim,
            num_actions,
            train_batch,
            train_steps: 0,
            target_sync_every: 16,
            fwd_inputs: None,
            batch_inputs: None,
            fwd_lanes,
            batch_scratch: vec![0.0; fwd_lanes * state_dim],
            batch_out: vec![0.0; fwd_lanes * num_actions],
            batch_fwds: 0,
            batch_rows: 0,
            batch_pad_rows: 0,
            faults_to_inject: 0,
        })
    }

    /// Pure-host session: a seeded He-initialized MLP with the compiled
    /// artifacts' 36 → 64 → 64 → 11 geometry, runnable in stub builds
    /// with no PJRT client — this is what the decision benches and the
    /// stub-build equivalence tests execute.  Not bitwise-pinned to the
    /// compiled graphs; the host backend is its own reference (its
    /// per-row and batched forwards are pinned to *each other*).
    pub fn new_host(seed: i32) -> QNetSession<'static> {
        let mut rng = Rng::new((seed as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ 0x5eed);
        let params = host_init(&mut rng);
        let target = params.clone();
        QNetSession {
            engine: None,
            host: Some(HostNet {
                params,
                target,
                h1: vec![0.0; HOST_FWD_LANES * HOST_HIDDEN],
                h2: vec![0.0; HOST_FWD_LANES * HOST_HIDDEN],
            }),
            params: Vec::new(),
            target: Vec::new(),
            state_dim: HOST_STATE_DIM,
            num_actions: HOST_NUM_ACTIONS,
            train_batch: HOST_FWD_LANES,
            train_steps: 0,
            target_sync_every: 16,
            fwd_inputs: None,
            batch_inputs: None,
            fwd_lanes: HOST_FWD_LANES,
            batch_scratch: vec![0.0; HOST_FWD_LANES * HOST_STATE_DIM],
            batch_out: vec![0.0; HOST_FWD_LANES * HOST_NUM_ACTIONS],
            batch_fwds: 0,
            batch_rows: 0,
            batch_pad_rows: 0,
            faults_to_inject: 0,
        }
    }

    /// Fixed lane width of the batched forward (chunk + pad unit).
    pub fn fwd_lanes(&self) -> usize {
        self.fwd_lanes
    }

    /// `(batch_fwds, batch_rows, batch_pad_rows)` since construction:
    /// chunks issued, real rows scored, pad rows wasted on ragged final
    /// chunks.
    pub fn batch_stats(&self) -> (usize, usize, usize) {
        (self.batch_fwds, self.batch_rows, self.batch_pad_rows)
    }

    /// Arm the fault-injection hook: the next `n` forward calls (single
    /// rows or batch chunks) fail with an error instead of executing.
    pub fn inject_fwd_faults(&mut self, n: usize) {
        self.faults_to_inject += n;
    }

    fn take_fault(&mut self) -> Result<()> {
        if self.faults_to_inject > 0 {
            self.faults_to_inject -= 1;
            bail!("injected qnet forward fault");
        }
        Ok(())
    }

    /// Q-values for one state, written into `out` (`len == num_actions`)
    /// — the per-decision request path and the in-tree reference the
    /// batched forward is pinned against.  On the PJRT backend the
    /// parameter literals are cloned once per parameter *update*, not
    /// per call: steady-state forwards reuse the cached input vector and
    /// overwrite its state slot — in place under the host stub (zero
    /// allocations per decision), as one rebuilt device literal per call
    /// under vendored PJRT.
    pub fn fwd_into(&mut self, state: &[f32], out: &mut [f32]) -> Result<()> {
        if state.len() != self.state_dim {
            bail!("state dim {} != {}", state.len(), self.state_dim);
        }
        if out.len() != self.num_actions {
            bail!("q-out dim {} != {}", out.len(), self.num_actions);
        }
        self.take_fault()?;
        if let Some(net) = self.host.as_mut() {
            net.fwd_row(state, out);
            return Ok(());
        }
        if self.fwd_inputs.is_none() {
            let mut inputs = clone_literals(&self.params)?;
            inputs.push(lit_f32(&[1, self.state_dim], state)?);
            self.fwd_inputs = Some(inputs);
        } else {
            let inputs = self.fwd_inputs.as_mut().expect("cached fwd inputs");
            let slot = inputs.last_mut().expect("state slot");
            refill_state(slot, &[1, self.state_dim], state)?;
        }
        let inputs = self.fwd_inputs.as_ref().expect("cached fwd inputs");
        let engine = self.engine.as_deref_mut().expect("pjrt session has an engine");
        let result = engine.run("qnet_fwd", inputs)?;
        read_q_row(&result[0], out)
    }

    /// Q-values for `rows` states (row-major `rows × state_dim`), written
    /// row-for-row into `out` (`rows × num_actions`) — the batched
    /// decision path.  Work is issued in fixed-lane chunks of
    /// [`QNetSession::fwd_lanes`] rows; the final ragged chunk is
    /// zero-padded up to the lane width (exactly what a fixed-shape
    /// compiled artifact forces) and the pad rows' outputs are
    /// discarded.  Outputs are bitwise identical to `rows` calls of
    /// [`QNetSession::fwd_into`].  Per issued chunk: `batch_fwds` + 1,
    /// `batch_rows` + real rows, `batch_pad_rows` + padding.
    pub fn fwd_batch_into(&mut self, states: &[f32], rows: usize, out: &mut [f32]) -> Result<()> {
        let need_in = rows * self.state_dim;
        if states.len() < need_in {
            bail!("batch states have {} elems, {} rows need {}", states.len(), rows, need_in);
        }
        let need_out = rows * self.num_actions;
        if out.len() < need_out {
            bail!("batch q-out has {} elems, {} rows need {}", out.len(), rows, need_out);
        }
        let mut done = 0;
        while done < rows {
            let chunk = self.fwd_lanes.min(rows - done);
            self.fwd_chunk(
                &states[done * self.state_dim..(done + chunk) * self.state_dim],
                chunk,
                &mut out[done * self.num_actions..(done + chunk) * self.num_actions],
            )?;
            done += chunk;
        }
        Ok(())
    }

    /// One fixed-lane chunk (`1 ≤ rows ≤ fwd_lanes`): stage into the
    /// padded lane-size scratch, run the whole lane, copy the real rows
    /// out.
    fn fwd_chunk(&mut self, states: &[f32], rows: usize, out: &mut [f32]) -> Result<()> {
        let lanes = self.fwd_lanes;
        debug_assert!(rows >= 1 && rows <= lanes);
        self.take_fault()?;
        let used = rows * self.state_dim;
        self.batch_scratch[..used].copy_from_slice(states);
        self.batch_scratch[used..].fill(0.0);
        if self.host.is_some() {
            let net = self.host.as_mut().expect("host net");
            // The full lane runs — pad rows included — mirroring the
            // fixed-shape artifact; pad outputs land in the discarded
            // tail of `batch_out`.
            mlp_panel(
                &net.params,
                &self.batch_scratch,
                lanes,
                &mut net.h1,
                &mut net.h2,
                &mut self.batch_out,
            );
        } else {
            if self.batch_inputs.is_none() {
                let mut inputs = clone_literals(&self.params)?;
                inputs.push(lit_f32(&[lanes, self.state_dim], &self.batch_scratch)?);
                self.batch_inputs = Some(inputs);
            } else {
                let inputs = self.batch_inputs.as_mut().expect("cached batch inputs");
                let slot = inputs.last_mut().expect("batch states slot");
                refill_batch_states(slot, &[lanes, self.state_dim], states, rows, self.state_dim)?;
            }
            let inputs = self.batch_inputs.as_ref().expect("cached batch inputs");
            let engine = self.engine.as_deref_mut().expect("pjrt session has an engine");
            let result = engine.run("qnet_fwd_batch", inputs)?;
            read_q_row(&result[0], &mut self.batch_out)?;
        }
        out.copy_from_slice(&self.batch_out[..rows * self.num_actions]);
        self.batch_fwds += 1;
        self.batch_rows += rows;
        self.batch_pad_rows += lanes - rows;
        Ok(())
    }

    /// Allocating convenience wrapper over [`QNetSession::fwd_into`].
    pub fn fwd(&mut self, state: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0; self.num_actions];
        self.fwd_into(state, &mut out)?;
        Ok(out)
    }

    /// One TD step; returns the loss.  Syncs the target network
    /// periodically.
    pub fn train(&mut self, batch: &TdBatch, lr: f32, gamma: f32) -> Result<f32> {
        let b = self.train_batch;
        if batch.actions.len() != b {
            bail!("batch size {} != artifact batch {}", batch.actions.len(), b);
        }
        if self.host.is_some() {
            let loss = self.host.as_mut().expect("host net").train_step(batch, b, lr, gamma);
            self.train_steps += 1;
            if self.train_steps % self.target_sync_every == 0 {
                let net = self.host.as_mut().expect("host net");
                net.target = net.params.clone();
            }
            return Ok(loss);
        }
        let mut inputs = clone_literals(&self.params)?;
        inputs.extend(clone_literals(&self.target)?);
        inputs.push(lit_f32(&[b, self.state_dim], &batch.states)?);
        inputs.push(lit_i32(&[b], &batch.actions)?);
        inputs.push(lit_f32(&[b], &batch.rewards)?);
        inputs.push(lit_f32(&[b, self.state_dim], &batch.next_states)?);
        inputs.push(lit_f32(&[b], &batch.dones)?);
        inputs.push(scalar_f32(lr));
        inputs.push(scalar_f32(gamma));
        let engine = self.engine.as_deref_mut().expect("pjrt session has an engine");
        let mut out = engine.run("qnet_train", &inputs)?;
        let loss = to_scalar_f32(&out.pop().expect("loss"))?;
        self.params = out;
        // The cached forward inputs embed the old parameters.
        self.fwd_inputs = None;
        self.batch_inputs = None;
        self.train_steps += 1;
        if self.train_steps % self.target_sync_every == 0 {
            self.target = clone_literals(&self.params)?;
        }
        Ok(loss)
    }
}

/// Literals are not `Clone`; round-trip through host bytes.
pub fn clone_literals(lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    lits.iter()
        .map(|l| {
            let shape = l.shape()?;
            match &shape {
                xla::Shape::Array(a) => {
                    let dims: Vec<usize> = a.dims().iter().map(|&d| d as usize).collect();
                    match a.element_type() {
                        xla::ElementType::F32 => lit_f32(&dims, &l.to_vec::<f32>()?),
                        xla::ElementType::S32 => lit_i32(&dims, &l.to_vec::<i32>()?),
                        other => bail!("clone_literals: unsupported element type {other:?}"),
                    }
                }
                _ => bail!("clone_literals: non-array literal"),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::test_engine_owned;

    #[test]
    fn fwd_scores_and_train_reduces_loss() {
        let Some(mut eng) = test_engine_owned() else { return };

        let mut q = QNetSession::new(&mut eng, 3).unwrap();
        let s = vec![0.25f32; q.state_dim];
        let q0 = q.fwd(&s).unwrap();
        assert_eq!(q0.len(), q.num_actions);

        // Fixed terminal batch: loss must fall over repeated steps.
        let b = q.train_batch;
        let batch = TdBatch {
            states: vec![0.1; b * q.state_dim],
            actions: (0..b as i32).map(|i| i % q.num_actions as i32).collect(),
            rewards: vec![1.0; b],
            next_states: vec![0.1; b * q.state_dim],
            dones: vec![1.0; b],
        };
        let first = q.train(&batch, 0.05, 0.95).unwrap();
        let mut last = first;
        for _ in 0..25 {
            last = q.train(&batch, 0.05, 0.95).unwrap();
        }
        assert!(last < 0.6 * first, "first={first} last={last}");

        // Training must change the policy's scores.
        let q1 = q.fwd(&s).unwrap();
        assert_ne!(q0, q1);
    }

    #[test]
    fn bad_state_dim_rejected() {
        let Some(mut eng) = test_engine_owned() else { return };

        let mut q = QNetSession::new(&mut eng, 0).unwrap();
        assert!(q.fwd(&[0.0; 3]).is_err());
    }

    /// The tentpole pin: batched forwards must replay the per-row
    /// reference bitwise, row for row — including ragged final chunks
    /// whose lane is zero-padded (rows 31/33/70 cross and straddle the
    /// 32-lane boundary).
    #[test]
    fn host_batch_forward_is_bitwise_row_for_row() {
        let mut s = QNetSession::new_host(7);
        let mut rng = Rng::new(99);
        for &rows in &[1usize, 5, 31, 32, 33, 70] {
            let states: Vec<f32> =
                (0..rows * s.state_dim).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
            let mut batch = vec![0.0f32; rows * s.num_actions];
            s.fwd_batch_into(&states, rows, &mut batch).unwrap();
            let mut row = vec![0.0f32; s.num_actions];
            for r in 0..rows {
                s.fwd_into(&states[r * s.state_dim..(r + 1) * s.state_dim], &mut row).unwrap();
                for j in 0..s.num_actions {
                    assert_eq!(
                        row[j].to_bits(),
                        batch[r * s.num_actions + j].to_bits(),
                        "rows={rows} row={r} q={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn host_batch_counters_track_chunks_rows_and_padding() {
        let mut s = QNetSession::new_host(3);
        assert_eq!(s.fwd_lanes(), HOST_FWD_LANES);
        let rows = HOST_FWD_LANES + 1;
        let states = vec![0.1f32; rows * s.state_dim];
        let mut out = vec![0.0f32; rows * s.num_actions];
        s.fwd_batch_into(&states, rows, &mut out).unwrap();
        // 33 rows = one full lane + one 1-row chunk padded by 31.
        assert_eq!(s.batch_stats(), (2, rows, HOST_FWD_LANES - 1));
        let full = HOST_FWD_LANES * s.state_dim;
        let full_out = HOST_FWD_LANES * s.num_actions;
        s.fwd_batch_into(&states[..full], HOST_FWD_LANES, &mut out[..full_out]).unwrap();
        assert_eq!(s.batch_stats(), (3, rows + HOST_FWD_LANES, HOST_FWD_LANES - 1));
        // The per-row reference path never touches the batch counters.
        let mut row = vec![0.0f32; s.num_actions];
        s.fwd_into(&states[..s.state_dim], &mut row).unwrap();
        assert_eq!(s.batch_stats(), (3, rows + HOST_FWD_LANES, HOST_FWD_LANES - 1));
    }

    #[test]
    fn host_train_reduces_loss_and_changes_scores() {
        let mut q = QNetSession::new_host(5);
        let s = vec![0.25f32; q.state_dim];
        let q0 = q.fwd(&s).unwrap();
        let b = q.train_batch;
        let batch = TdBatch {
            states: vec![0.1; b * q.state_dim],
            actions: (0..b as i32).map(|i| i % q.num_actions as i32).collect(),
            rewards: vec![1.0; b],
            next_states: vec![0.1; b * q.state_dim],
            dones: vec![1.0; b],
        };
        let first = q.train(&batch, 0.05, 0.95).unwrap();
        let mut last = first;
        for _ in 0..25 {
            last = q.train(&batch, 0.05, 0.95).unwrap();
        }
        assert!(last < 0.6 * first, "first={first} last={last}");
        let q1 = q.fwd(&s).unwrap();
        assert_ne!(q0, q1);
        // Training invalidates nothing on the host path: batched and
        // per-row forwards stay bitwise identical on the new weights.
        let mut batch_q = vec![0.0f32; q.num_actions];
        q.fwd_batch_into(&s, 1, &mut batch_q).unwrap();
        let row_q = q.fwd(&s).unwrap();
        assert_eq!(
            batch_q.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            row_q.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn injected_faults_fail_forwards_then_clear() {
        let mut s = QNetSession::new_host(1);
        s.inject_fwd_faults(2);
        let states = vec![0.0f32; s.state_dim];
        let mut out = vec![0.0f32; s.num_actions];
        assert!(s.fwd_into(&states, &mut out).is_err());
        assert!(s.fwd_batch_into(&states, 1, &mut out).is_err());
        assert!(s.fwd_into(&states, &mut out).is_ok(), "faults are one-shot");
        // A failed chunk is not counted as an issued batch forward.
        assert_eq!(s.batch_stats(), (0, 0, 0));
        s.fwd_batch_into(&states, 1, &mut out).unwrap();
        assert_eq!(s.batch_stats(), (1, 1, HOST_FWD_LANES - 1));
    }
}
