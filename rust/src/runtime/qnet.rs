//! Q-network session over the `qnet_*` artifacts.
//!
//! Parameters live in Rust as literals; every call is a pure PJRT
//! execution.  This is the function approximator behind
//! [`DqnPolicy`](crate::rl::dqn::DqnPolicy): `fwd` scores a single
//! decision state (B=1 artifact), `train` runs one TD mini-batch step
//! against a target-network copy.

use crate::bail;
use crate::util::error::Result;

use super::{lit_f32, lit_i32, scalar_f32, scalar_i32, to_scalar_f32, Engine};

#[cfg(not(pjrt_vendored))]
use super::pjrt_stub as xla;

/// Owned Q-network parameters + target-network copy.
pub struct QNetSession<'e> {
    engine: &'e mut Engine,
    pub params: Vec<xla::Literal>,
    pub target: Vec<xla::Literal>,
    pub state_dim: usize,
    pub num_actions: usize,
    pub train_batch: usize,
    train_steps: usize,
    /// Sync the target network every this many train steps.
    pub target_sync_every: usize,
    /// Cached `qnet_fwd` input vector: the cloned parameter literals
    /// plus one reusable state slot at the end.  Rebuilt lazily after
    /// every parameter update; on the steady-state decision path each
    /// forward only overwrites the state slot in place.
    fwd_inputs: Option<Vec<xla::Literal>>,
}

/// One TD training batch (row-major, `len == batch`).
pub struct TdBatch {
    pub states: Vec<f32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub next_states: Vec<f32>,
    pub dones: Vec<f32>,
}

impl TdBatch {
    /// Pre-sized scratch for `batch` rows of `state_dim` features —
    /// reused across train steps via [`TdBatch::clear`].
    pub fn with_capacity(batch: usize, state_dim: usize) -> TdBatch {
        TdBatch {
            states: Vec::with_capacity(batch * state_dim),
            actions: Vec::with_capacity(batch),
            rewards: Vec::with_capacity(batch),
            next_states: Vec::with_capacity(batch * state_dim),
            dones: Vec::with_capacity(batch),
        }
    }

    /// Empty every column, keeping the allocations.
    pub fn clear(&mut self) {
        self.states.clear();
        self.actions.clear();
        self.rewards.clear();
        self.next_states.clear();
        self.dones.clear();
    }
}

/// Overwrite the cached state slot with a fresh state (host stub: an
/// in-place copy; vendored PJRT: rebuild the device literal).
#[cfg(not(pjrt_vendored))]
fn refill_state(slot: &mut xla::Literal, _dims: &[usize], state: &[f32]) -> Result<()> {
    slot.copy_from_f32(state)
}

#[cfg(pjrt_vendored)]
fn refill_state(slot: &mut xla::Literal, dims: &[usize], state: &[f32]) -> Result<()> {
    *slot = lit_f32(dims, state)?;
    Ok(())
}

/// Read the Q-value row into a caller buffer (host stub: no allocation).
#[cfg(not(pjrt_vendored))]
fn read_q_row(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_to_f32(out)
}

#[cfg(pjrt_vendored)]
fn read_q_row(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    let v = lit.to_vec::<f32>()?;
    if v.len() != out.len() {
        crate::bail!("q row has {} elems, sink has {}", v.len(), out.len());
    }
    out.copy_from_slice(&v);
    Ok(())
}

impl<'e> QNetSession<'e> {
    /// Initialize from the `qnet_init` artifact with the given seed.
    pub fn new(engine: &'e mut Engine, seed: i32) -> Result<QNetSession<'e>> {
        let state_dim = engine.manifest.meta_usize("qnet", "state_dim")?;
        let num_actions = engine.manifest.meta_usize("qnet", "num_actions")?;
        let train_batch = engine.manifest.meta_usize("qnet", "train_batch")?;
        let params = engine.run("qnet_init", &[scalar_i32(seed)])?;
        let target = engine.run("qnet_init", &[scalar_i32(seed)])?;
        Ok(QNetSession {
            engine,
            params,
            target,
            state_dim,
            num_actions,
            train_batch,
            train_steps: 0,
            target_sync_every: 16,
            fwd_inputs: None,
        })
    }

    /// Q-values for one state, written into `out` (`len == num_actions`)
    /// — the per-decision request path.  The parameter literals are
    /// cloned once per parameter *update*, not per call: steady-state
    /// forwards reuse the cached input vector and overwrite its state
    /// slot — in place under the host stub (zero allocations per
    /// decision), as one rebuilt device literal per call under vendored
    /// PJRT.
    pub fn fwd_into(&mut self, state: &[f32], out: &mut [f32]) -> Result<()> {
        if state.len() != self.state_dim {
            bail!("state dim {} != {}", state.len(), self.state_dim);
        }
        if out.len() != self.num_actions {
            bail!("q-out dim {} != {}", out.len(), self.num_actions);
        }
        if self.fwd_inputs.is_none() {
            let mut inputs = clone_literals(&self.params)?;
            inputs.push(lit_f32(&[1, self.state_dim], state)?);
            self.fwd_inputs = Some(inputs);
        } else {
            let inputs = self.fwd_inputs.as_mut().expect("cached fwd inputs");
            let slot = inputs.last_mut().expect("state slot");
            refill_state(slot, &[1, self.state_dim], state)?;
        }
        let inputs = self.fwd_inputs.as_ref().expect("cached fwd inputs");
        let result = self.engine.run("qnet_fwd", inputs)?;
        read_q_row(&result[0], out)
    }

    /// Allocating convenience wrapper over [`QNetSession::fwd_into`].
    pub fn fwd(&mut self, state: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0; self.num_actions];
        self.fwd_into(state, &mut out)?;
        Ok(out)
    }

    /// One TD step; returns the loss.  Syncs the target network
    /// periodically.
    pub fn train(&mut self, batch: &TdBatch, lr: f32, gamma: f32) -> Result<f32> {
        let b = self.train_batch;
        if batch.actions.len() != b {
            bail!("batch size {} != artifact batch {}", batch.actions.len(), b);
        }
        let mut inputs = clone_literals(&self.params)?;
        inputs.extend(clone_literals(&self.target)?);
        inputs.push(lit_f32(&[b, self.state_dim], &batch.states)?);
        inputs.push(lit_i32(&[b], &batch.actions)?);
        inputs.push(lit_f32(&[b], &batch.rewards)?);
        inputs.push(lit_f32(&[b, self.state_dim], &batch.next_states)?);
        inputs.push(lit_f32(&[b], &batch.dones)?);
        inputs.push(scalar_f32(lr));
        inputs.push(scalar_f32(gamma));
        let mut out = self.engine.run("qnet_train", &inputs)?;
        let loss = to_scalar_f32(&out.pop().expect("loss"))?;
        self.params = out;
        // The cached forward inputs embed the old parameters.
        self.fwd_inputs = None;
        self.train_steps += 1;
        if self.train_steps % self.target_sync_every == 0 {
            self.target = clone_literals(&self.params)?;
        }
        Ok(loss)
    }
}

/// Literals are not `Clone`; round-trip through host bytes.
pub fn clone_literals(lits: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    lits.iter()
        .map(|l| {
            let shape = l.shape()?;
            match &shape {
                xla::Shape::Array(a) => {
                    let dims: Vec<usize> = a.dims().iter().map(|&d| d as usize).collect();
                    match a.element_type() {
                        xla::ElementType::F32 => lit_f32(&dims, &l.to_vec::<f32>()?),
                        xla::ElementType::S32 => lit_i32(&dims, &l.to_vec::<i32>()?),
                        other => bail!("clone_literals: unsupported element type {other:?}"),
                    }
                }
                _ => bail!("clone_literals: non-array literal"),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::test_engine_owned;

    #[test]
    fn fwd_scores_and_train_reduces_loss() {
        let Some(mut eng) = test_engine_owned() else { return };
        
        let mut q = QNetSession::new(&mut eng, 3).unwrap();
        let s = vec![0.25f32; q.state_dim];
        let q0 = q.fwd(&s).unwrap();
        assert_eq!(q0.len(), q.num_actions);

        // Fixed terminal batch: loss must fall over repeated steps.
        let b = q.train_batch;
        let batch = TdBatch {
            states: vec![0.1; b * q.state_dim],
            actions: (0..b as i32).map(|i| i % q.num_actions as i32).collect(),
            rewards: vec![1.0; b],
            next_states: vec![0.1; b * q.state_dim],
            dones: vec![1.0; b],
        };
        let first = q.train(&batch, 0.05, 0.95).unwrap();
        let mut last = first;
        for _ in 0..25 {
            last = q.train(&batch, 0.05, 0.95).unwrap();
        }
        assert!(last < 0.6 * first, "first={first} last={last}");

        // Training must change the policy's scores.
        let q1 = q.fwd(&s).unwrap();
        assert_ne!(q0, q1);
    }

    #[test]
    fn bad_state_dim_rejected() {
        let Some(mut eng) = test_engine_owned() else { return };
        
        let mut q = QNetSession::new(&mut eng, 0).unwrap();
        assert!(q.fwd(&[0.0; 3]).is_err());
    }
}
