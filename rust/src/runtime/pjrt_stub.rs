//! Host-side stand-in for the vendored `xla` crate (used when the `pjrt`
//! feature is off, which is the default in this offline build).
//!
//! Literals are fully functional on the host (create / reshape / read
//! back), so literal-level code and tests work without PJRT.  Anything
//! that would actually touch a PJRT client — compiling or executing an
//! HLO artifact — returns a descriptive error instead.  Enabling the
//! `pjrt` feature switches `runtime` back onto the real crate (which must
//! then be vendored into `[dependencies]`).

use crate::util::error::{Error, Result};

fn unavailable(what: &str) -> Error {
    Error::msg(format!(
        "{what} unavailable: built without the `pjrt` feature (vendor the xla crate and enable it)"
    ))
}

/// Element types (the artifacts only use F32/S32; the remaining variants
/// mirror the real crate so `match` arms over them stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[allow(dead_code)]
pub enum ElementType {
    F32,
    S32,
    F64,
    S64,
    U32,
    Pred,
}

/// Array payload of a literal (public because [`NativeType`] mentions it).
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

/// A host tensor: shape plus typed data.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl PartialEq for Data {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Data::F32(a), Data::F32(b)) => a == b,
            (Data::S32(a), Data::S32(b)) => a == b,
            _ => false,
        }
    }
}

/// Scalar types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::S32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::S32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![v]) }
    }

    fn elems(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.elems() {
            return Err(Error::msg(format!(
                "reshape: {:?} wants {} elems, literal has {}",
                dims,
                want,
                self.elems()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Read the data back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error::msg("to_vec: element type mismatch"))
    }

    pub fn shape(&self) -> Result<Shape> {
        let ty = match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::S32(_) => ElementType::S32,
        };
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone(), ty }))
    }

    /// Decompose a tuple literal; the host stub never produces tuples
    /// (they only come back from PJRT execution).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literals"))
    }

    /// Overwrite an F32 literal's payload in place (shape unchanged) —
    /// the buffer-reuse hook for per-decision hot paths (stub-only; the
    /// vendored crate rebuilds the literal instead).
    pub fn copy_from_f32(&mut self, data: &[f32]) -> Result<()> {
        match &mut self.data {
            Data::F32(v) if v.len() == data.len() => {
                v.copy_from_slice(data);
                Ok(())
            }
            Data::F32(v) => Err(Error::msg(format!(
                "copy_from_f32: literal has {} elems, source has {}",
                v.len(),
                data.len()
            ))),
            Data::S32(_) => Err(Error::msg("copy_from_f32: element type mismatch")),
        }
    }

    /// Vectorized row refill for fixed-lane batch literals: overwrite the
    /// first `rows` rows of `row_len` elements from `data` in one
    /// `copy_from_slice`, then zero the remaining pad rows.  The batched
    /// Q-net forward refills its `[lanes, state_dim]` states slot through
    /// this instead of `rows` single-row copies.
    pub fn copy_rows_from_f32(&mut self, data: &[f32], rows: usize, row_len: usize) -> Result<()> {
        let used = rows * row_len;
        if data.len() < used {
            return Err(Error::msg(format!(
                "copy_rows_from_f32: {} rows of {} need {} elems, source has {}",
                rows,
                row_len,
                used,
                data.len()
            )));
        }
        match &mut self.data {
            Data::F32(v) if v.len() >= used => {
                v[..used].copy_from_slice(&data[..used]);
                v[used..].fill(0.0);
                Ok(())
            }
            Data::F32(v) => Err(Error::msg(format!(
                "copy_rows_from_f32: literal has {} elems, {} rows of {} need {}",
                v.len(),
                rows,
                row_len,
                used
            ))),
            Data::S32(_) => Err(Error::msg("copy_rows_from_f32: element type mismatch")),
        }
    }

    /// Read an F32 literal's payload into a caller buffer without
    /// allocating (the output half of the buffer-reuse hook).
    pub fn copy_to_f32(&self, out: &mut [f32]) -> Result<()> {
        match &self.data {
            Data::F32(v) if v.len() == out.len() => {
                out.copy_from_slice(v);
                Ok(())
            }
            Data::F32(v) => Err(Error::msg(format!(
                "copy_to_f32: literal has {} elems, sink has {}",
                v.len(),
                out.len()
            ))),
            Data::S32(_) => Err(Error::msg("copy_to_f32: element type mismatch")),
        }
    }
}

/// Array shape metadata.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// Shape of a literal.
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    /// Produced only by PJRT execution, never by the host stub.
    #[allow(dead_code)]
    Tuple(Vec<Shape>),
}

/// PJRT client stub: construction fails with a clear message.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PJRT compilation"))
    }
}

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HLO text parsing"))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PJRT buffers"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        match l.shape().unwrap() {
            Shape::Array(a) => {
                assert_eq!(a.dims(), &[2, 2]);
                assert_eq!(a.element_type(), ElementType::F32);
            }
            _ => panic!("expected array shape"),
        }
        assert!(l.to_vec::<i32>().is_err(), "type mismatch rejected");
    }

    #[test]
    fn scalar_and_bad_reshape() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(Literal::vec1(&[1.0f32]).reshape(&[2]).is_err());
    }

    #[test]
    fn in_place_copy_roundtrip_and_mismatches() {
        let mut l = Literal::vec1(&[0.0f32; 4]).reshape(&[2, 2]).unwrap();
        l.copy_from_f32(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut out = [0.0f32; 4];
        l.copy_to_f32(&mut out).unwrap();
        assert_eq!(out, [1.0, 2.0, 3.0, 4.0]);
        // Shape stays intact after the in-place overwrite.
        match l.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 2]),
            _ => panic!("expected array shape"),
        }
        // Length and type mismatches are rejected.
        assert!(l.copy_from_f32(&[1.0; 3]).is_err());
        assert!(l.copy_to_f32(&mut [0.0; 5]).is_err());
        let mut i = Literal::vec1(&[1i32, 2]);
        assert!(i.copy_from_f32(&[1.0, 2.0]).is_err());
        assert!(i.copy_to_f32(&mut [0.0; 2]).is_err());
    }

    #[test]
    fn row_batch_refill_pads_with_zeros() {
        let mut l = Literal::vec1(&[9.0f32; 8]).reshape(&[4, 2]).unwrap();
        l.copy_rows_from_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0, 0.0]);
        // Full refill leaves no pad tail; short source is rejected.
        l.copy_rows_from_f32(&[7.0; 8], 4, 2).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![7.0; 8]);
        assert!(l.copy_rows_from_f32(&[1.0; 3], 2, 2).is_err());
        assert!(l.copy_rows_from_f32(&[1.0; 16], 5, 2).is_err());
        let mut i = Literal::vec1(&[1i32, 2]);
        assert!(i.copy_rows_from_f32(&[1.0, 2.0], 1, 2).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }
}
