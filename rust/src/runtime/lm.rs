//! Transformer-LM training session over the `lm_*` artifacts.
//!
//! This is the *DL training job* of the end-to-end example: worker nodes
//! call [`LmSession::grad`] on their data shard, the parameter server
//! averages the gradients ([`average_grads`]) and applies them with
//! [`LmSession::update`] — the JAX analog of the paper's TensorFlow
//! parameter-server strategy, with every FLOP flowing through the
//! AOT-compiled Pallas kernels.

use crate::bail;
use crate::util::error::Result;

use super::qnet::clone_literals;
use super::{lit_i32, scalar_f32, scalar_i32, to_scalar_f32, Engine};

#[cfg(not(pjrt_vendored))]
use super::pjrt_stub as xla;

/// Hyper-parameters mirrored from `manifest.meta.lm`.
#[derive(Debug, Clone, Copy)]
pub struct LmMeta {
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub n_params: usize,
    pub param_count: usize,
}

/// Owned LM parameters + the engine executing the artifacts.
pub struct LmSession<'e> {
    engine: &'e mut Engine,
    pub params: Vec<xla::Literal>,
    pub meta: LmMeta,
}

impl<'e> LmSession<'e> {
    pub fn new(engine: &'e mut Engine, seed: i32) -> Result<LmSession<'e>> {
        let meta = LmMeta {
            vocab: engine.manifest.meta_usize("lm", "vocab")?,
            seq: engine.manifest.meta_usize("lm", "seq")?,
            batch: engine.manifest.meta_usize("lm", "batch")?,
            n_params: engine.manifest.artifacts["lm_init"].outputs.len(),
            param_count: engine.manifest.meta_usize("lm", "param_count")?,
        };
        let params = engine.run("lm_init", &[scalar_i32(seed)])?;
        Ok(LmSession { engine, params, meta })
    }

    fn tokens_literal(&self, tokens: &[i32]) -> Result<xla::Literal> {
        let want = self.meta.batch * (self.meta.seq + 1);
        if tokens.len() != want {
            bail!("tokens len {} != batch*(seq+1) = {}", tokens.len(), want);
        }
        lit_i32(&[self.meta.batch, self.meta.seq + 1], tokens)
    }

    /// Per-worker gradient computation: returns (grads, loss).
    pub fn grad(&mut self, tokens: &[i32]) -> Result<(Vec<xla::Literal>, f32)> {
        let mut inputs = clone_literals(&self.params)?;
        inputs.push(self.tokens_literal(tokens)?);
        let mut out = self.engine.run("lm_grad", &inputs)?;
        let loss = to_scalar_f32(&out.pop().expect("loss"))?;
        Ok((out, loss))
    }

    /// Gradients as host vectors (for parameter-server averaging).
    pub fn grad_host(&mut self, tokens: &[i32]) -> Result<(Vec<Vec<f32>>, f32)> {
        let (grads, loss) = self.grad(tokens)?;
        let host = grads.iter().map(|g| Ok(g.to_vec::<f32>()?)).collect::<Result<Vec<_>>>()?;
        Ok((host, loss))
    }

    /// Apply (averaged) gradients with learning rate `lr`.
    pub fn update(&mut self, grads: &[xla::Literal], lr: f32) -> Result<()> {
        if grads.len() != self.meta.n_params {
            bail!("grads len {} != n_params {}", grads.len(), self.meta.n_params);
        }
        let mut inputs = clone_literals(&self.params)?;
        inputs.extend(clone_literals(grads)?);
        inputs.push(scalar_f32(lr));
        self.params = self.engine.run("lm_update", &inputs)?;
        Ok(())
    }

    /// Apply host-vector gradients (the PS path).
    pub fn update_host(&mut self, grads: &[Vec<f32>], lr: f32) -> Result<()> {
        let specs = &self.engine.manifest.artifacts["lm_update"].inputs;
        let lits = grads
            .iter()
            .enumerate()
            .map(|(i, g)| {
                let spec = &specs[self.meta.n_params + i];
                super::lit_f32(&spec.shape, g)
            })
            .collect::<Result<Vec<_>>>()?;
        self.update(&lits, lr)
    }

    /// Forward-only evaluation loss.
    pub fn eval(&mut self, tokens: &[i32]) -> Result<f32> {
        let mut inputs = clone_literals(&self.params)?;
        inputs.push(self.tokens_literal(tokens)?);
        let out = self.engine.run("lm_eval", &inputs)?;
        to_scalar_f32(&out[0])
    }

    /// Snapshot parameters to host vectors (for broadcasting to workers).
    pub fn params_host(&self) -> Result<Vec<Vec<f32>>> {
        self.params.iter().map(|p| Ok(p.to_vec::<f32>()?)).collect()
    }

    /// Load parameters from host vectors (worker receiving a broadcast).
    pub fn set_params_host(&mut self, host: &[Vec<f32>]) -> Result<()> {
        let specs = &self.engine.manifest.artifacts["lm_init"].outputs;
        if host.len() != specs.len() {
            bail!("param count mismatch");
        }
        self.params = host
            .iter()
            .zip(specs)
            .map(|(v, s)| super::lit_f32(&s.shape, v))
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }
}

/// Average per-worker gradient sets element-wise (parameter server).
pub fn average_grads(worker_grads: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    assert!(!worker_grads.is_empty());
    let n = worker_grads.len() as f32;
    let mut avg = worker_grads[0].clone();
    for wg in &worker_grads[1..] {
        for (a, g) in avg.iter_mut().zip(wg) {
            for (x, y) in a.iter_mut().zip(g) {
                *x += *y;
            }
        }
    }
    for a in &mut avg {
        for x in a {
            *x /= n;
        }
    }
    avg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::test_engine_owned;
    use crate::util::Rng;

    fn predictable_tokens(meta: &LmMeta, rng: &mut Rng) -> Vec<i32> {
        // Cyclic sequence: trivially learnable.
        let start = rng.below(7) as i32;
        (0..meta.batch * (meta.seq + 1)).map(|i| (start + i as i32) % 7).collect()
    }

    #[test]
    fn init_grad_update_eval_cycle_learns() {
        let Some(mut eng) = test_engine_owned() else { return };
        
        let mut lm = LmSession::new(&mut eng, 0).unwrap();
        let mut rng = Rng::new(1);
        let toks = predictable_tokens(&lm.meta, &mut rng);
        let initial = lm.eval(&toks).unwrap();
        // Near-uniform at init.
        assert!((initial - (lm.meta.vocab as f32).ln()).abs() < 1.0, "init loss {initial}");
        let mut last = initial;
        for _ in 0..8 {
            let (grads, loss) = lm.grad(&toks).unwrap();
            lm.update(&grads, 0.5).unwrap();
            last = loss;
        }
        assert!(last < 0.7 * initial, "initial={initial} last={last}");
    }

    #[test]
    fn average_grads_is_elementwise_mean() {
        let a = vec![vec![1.0f32, 3.0], vec![2.0]];
        let b = vec![vec![3.0f32, 5.0], vec![4.0]];
        let avg = average_grads(&[a, b]);
        assert_eq!(avg, vec![vec![2.0, 4.0], vec![3.0]]);
    }

    #[test]
    fn params_host_roundtrip() {
        let Some(mut eng) = test_engine_owned() else { return };
        
        let mut lm = LmSession::new(&mut eng, 5).unwrap();
        let host = lm.params_host().unwrap();
        let total: usize = host.iter().map(|v| v.len()).sum();
        assert_eq!(total, lm.meta.param_count);
        let mut rng = Rng::new(2);
        let toks = predictable_tokens(&lm.meta, &mut rng);
        let before = lm.eval(&toks).unwrap();
        lm.set_params_host(&host).unwrap();
        let after = lm.eval(&toks).unwrap();
        assert!((before - after).abs() < 1e-6);
    }

    #[test]
    fn bad_tokens_len_rejected() {
        let Some(mut eng) = test_engine_owned() else { return };
        
        let mut lm = LmSession::new(&mut eng, 0).unwrap();
        assert!(lm.eval(&[1, 2, 3]).is_err());
    }
}
