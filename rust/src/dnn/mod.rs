//! DNN model graphs and per-layer resource demands.
//!
//! In the paper, a DL training job is a DNN whose layers (grouped into
//! *levels* that can run in parallel) are the schedulable tasks; the
//! cluster head (or each agent) must know the "resource demands of all
//! the layers".  The paper profiles demands with the TensorFlow benchmark
//! tool; here [`profile`] computes them analytically from layer dimensions
//! (FLOPs, parameter + activation memory, output transfer size), which
//! plays the same role: a per-layer `(cpu, mem, out_bytes)` demand vector.
//!
//! [`models`] builds the paper's three evaluation models (VGG-16,
//! GoogleNet/Inception, a 2-layer LSTM RNN) plus the transformer LM that
//! the end-to-end example actually trains through PJRT.

pub mod models;
pub mod profile;

use crate::cluster::Resources;

/// What kind of computation a layer performs (drives profiling).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution: spatial size, channels in/out, kernel size.
    Conv { hw: usize, cin: usize, cout: usize, k: usize },
    /// Max/avg pooling.
    Pool { hw: usize, c: usize },
    /// Fully connected.
    Dense { din: usize, dout: usize },
    /// LSTM over a sequence.
    Lstm { din: usize, hidden: usize, steps: usize },
    /// Token/positional embedding lookup.
    Embed { vocab: usize, dim: usize, seq: usize },
    /// Multi-head self-attention block.
    Attention { seq: usize, dim: usize, heads: usize },
    /// Branch concatenation (inception merge) — negligible compute.
    Concat { hw: usize, c: usize },
}

/// One schedulable task: a layer (or fused group) of the DNN.
#[derive(Debug, Clone)]
pub struct Layer {
    pub id: usize,
    pub name: String,
    pub kind: LayerKind,
    /// GFLOPs per training iteration (fwd+bwd, batch included).
    pub flops_g: f64,
    /// Resident memory demand in MB (weights + activations + gradients).
    pub mem_mb: f64,
    /// Activation output size in MB per iteration (transfer to next level).
    pub out_mb: f64,
    /// Pipeline level (layers in the same level may run in parallel).
    pub level: usize,
    /// Precomputed demand vector (hot path: consulted for every pricing
    /// and shielding decision).
    demand: Resources,
}

impl Layer {
    pub fn new(
        id: usize,
        name: String,
        kind: LayerKind,
        flops_g: f64,
        mem_mb: f64,
        out_mb: f64,
        level: usize,
    ) -> Layer {
        let demand = Resources {
            cpu: profile::cpu_demand(flops_g),
            mem: mem_mb,
            bw: profile::bw_demand(out_mb),
        };
        Layer { id, name, kind, flops_g, mem_mb, out_mb, level, demand }
    }

    /// The demand vector used for utilization math (Eq. 1) and the
    /// shield's resource-demand weight (Eq. 3).  CPU demand is the
    /// host-ratio share this layer would need to sustain the reference
    /// iteration rate; bandwidth demand is the egress rate at that rate.
    pub fn demand(&self) -> Resources {
        self.demand
    }

    /// Resource-demand weight ω(l) = Π_k b_k(l)/C_k(d) (paper Eq. 3).
    pub fn demand_weight(&self, caps: &Resources) -> f64 {
        let d = self.demand();
        (d.cpu / caps.cpu) * (d.mem / caps.mem) * (d.bw / caps.bw)
    }
}

/// Which evaluation model (paper §V-A: three ML models).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Vgg16,
    GoogleNet,
    Rnn,
    /// The transformer LM the end-to-end example trains for real.
    TransformerLm,
}

impl ModelKind {
    pub const PAPER_MODELS: [ModelKind; 3] = [ModelKind::Vgg16, ModelKind::GoogleNet, ModelKind::Rnn];

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Vgg16 => "vgg16",
            ModelKind::GoogleNet => "googlenet",
            ModelKind::Rnn => "rnn",
            ModelKind::TransformerLm => "transformer_lm",
        }
    }

    pub fn parse(s: &str) -> Option<ModelKind> {
        match s {
            "vgg16" | "vgg" => Some(ModelKind::Vgg16),
            "googlenet" | "inception" => Some(ModelKind::GoogleNet),
            "rnn" | "lstm" => Some(ModelKind::Rnn),
            "transformer_lm" | "transformer" | "lm" => Some(ModelKind::TransformerLm),
            _ => None,
        }
    }

    pub fn build(&self) -> ModelGraph {
        match self {
            ModelKind::Vgg16 => models::vgg16(),
            ModelKind::GoogleNet => models::googlenet(),
            ModelKind::Rnn => models::rnn(),
            ModelKind::TransformerLm => models::transformer_lm(),
        }
    }
}

/// A DNN as a DAG of layers grouped into topological levels.
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    pub layers: Vec<Layer>,
    /// Data-flow edges between layer ids (producer, consumer).
    pub edges: Vec<(usize, usize)>,
    /// Layer ids per level, in level order.
    pub levels: Vec<Vec<usize>>,
}

impl ModelGraph {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total model size in MB (for parameter-synchronization transfers).
    pub fn param_mb(&self) -> f64 {
        self.layers.iter().map(|l| profile::weight_mb(&l.kind)).sum()
    }

    /// Total GFLOPs per training iteration.
    pub fn total_flops_g(&self) -> f64 {
        self.layers.iter().map(|l| l.flops_g).sum()
    }

    /// Consumers of layer `id`.
    pub fn successors(&self, id: usize) -> Vec<usize> {
        self.edges.iter().filter(|(a, _)| *a == id).map(|(_, b)| *b).collect()
    }

    /// Validate structural invariants (used by tests and on construction).
    pub fn check(&self) -> Result<(), String> {
        // ids are dense and match indices
        for (i, l) in self.layers.iter().enumerate() {
            if l.id != i {
                return Err(format!("layer {i} has id {}", l.id));
            }
        }
        // levels partition the ids
        let mut seen = vec![false; self.layers.len()];
        for lvl in &self.levels {
            for &id in lvl {
                if id >= self.layers.len() || seen[id] {
                    return Err(format!("bad level entry {id}"));
                }
                seen[id] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("levels do not cover all layers".into());
        }
        // edges go strictly forward in level order
        for &(a, b) in &self.edges {
            if self.layers[a].level >= self.layers[b].level {
                return Err(format!("edge {a}->{b} not level-increasing"));
            }
        }
        // layer.level matches its index in `levels`
        for (li, lvl) in self.levels.iter().enumerate() {
            for &id in lvl {
                if self.layers[id].level != li {
                    return Err(format!("layer {id} level mismatch"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_structurally_valid() {
        for kind in [ModelKind::Vgg16, ModelKind::GoogleNet, ModelKind::Rnn, ModelKind::TransformerLm] {
            let g = kind.build();
            g.check().unwrap_or_else(|e| panic!("{}: {e}", g.name));
            assert!(g.n_layers() >= 5, "{} too small", g.name);
            assert!(g.total_flops_g() > 0.0);
            assert!(g.param_mb() > 0.0);
        }
    }

    #[test]
    fn demands_are_positive_and_bounded() {
        for kind in ModelKind::PAPER_MODELS {
            for l in &kind.build().layers {
                let d = l.demand();
                assert!(d.cpu > 0.0 && d.cpu <= 1.0, "{}: cpu {}", l.name, d.cpu);
                assert!(d.mem > 0.0);
                assert!(d.bw >= 0.0);
            }
        }
    }

    #[test]
    fn demand_weight_monotone_in_demand() {
        let caps = Resources::new(1.0, 2048.0, 100.0);
        let g = ModelKind::Vgg16.build();
        // The giant fc1 layer (411 MB of weights) must out-weigh the small
        // final classifier fc3.
        let fc1 = g.layers.iter().find(|l| l.name == "fc1").unwrap();
        let fc3 = g.layers.iter().find(|l| l.name == "fc3").unwrap();
        assert!(fc1.demand_weight(&caps) > fc3.demand_weight(&caps));
    }

    #[test]
    fn model_kind_parse_roundtrip() {
        for kind in [ModelKind::Vgg16, ModelKind::GoogleNet, ModelKind::Rnn, ModelKind::TransformerLm] {
            assert_eq!(ModelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::parse("nope"), None);
    }

    #[test]
    fn googlenet_has_parallel_levels() {
        let g = ModelKind::GoogleNet.build();
        assert!(
            g.levels.iter().any(|l| l.len() >= 3),
            "inception branches should occupy one level"
        );
    }

    #[test]
    fn vgg_is_sequential() {
        let g = ModelKind::Vgg16.build();
        assert!(g.levels.iter().all(|l| l.len() == 1));
    }

    #[test]
    fn vgg16_total_sizes_realistic() {
        let g = ModelKind::Vgg16.build();
        // VGG-16 has ~138M params ≈ 528 MB fp32.
        assert!((400.0..700.0).contains(&g.param_mb()), "param_mb={}", g.param_mb());
    }
}
