//! Builders for the paper's evaluation models.
//!
//! §V-A: "We run three ML models: GoogleNet Inception, VGG-16, and RNN.
//! We use the MNIST dataset to run the first two models and the Air
//! Quality dataset for the RNN model."  MNIST images are upscaled to
//! 224×224 for the CNNs (their canonical input); the RNN consumes the
//! Air-Quality sensor stream (5 features, hourly windows).
//!
//! Each builder emits a [`ModelGraph`] whose layers carry analytic
//! per-iteration demands from [`profile`], at the paper's training batch
//! size of 32.

use super::profile;
use super::{Layer, LayerKind, ModelGraph};

/// Training batch size used for profiling.  Edge devices train with small
/// batches (the Keras MNIST reference uses 128 on a workstation; on
/// 1–4 GB devices a per-replica batch of 8 is what fits next to the
/// activations of 224×224 CNNs).
pub const BATCH: usize = 8;

struct Builder {
    name: String,
    layers: Vec<Layer>,
    edges: Vec<(usize, usize)>,
    levels: Vec<Vec<usize>>,
}

impl Builder {
    fn new(name: &str) -> Builder {
        Builder { name: name.into(), layers: Vec::new(), edges: Vec::new(), levels: Vec::new() }
    }

    /// Append a layer at a new level, linked from `preds` (or the previous
    /// level's layers when `preds` is empty and a previous level exists).
    fn push(&mut self, name: &str, kind: LayerKind, preds: &[usize]) -> usize {
        let id = self.layers.len();
        let level = self.levels.len();
        let (flops_g, mem_mb, out_mb) = profile::profile(&kind, BATCH);
        self.layers.push(Layer::new(id, name.into(), kind, flops_g, mem_mb, out_mb, level));
        self.levels.push(vec![id]);
        let preds: Vec<usize> = if preds.is_empty() && level > 0 {
            self.levels[level - 1].clone()
        } else {
            preds.to_vec()
        };
        for p in preds {
            self.edges.push((p, id));
        }
        id
    }

    /// Append several layers sharing one level (inception branches),
    /// all linked from `preds`.
    fn push_parallel(&mut self, items: Vec<(String, LayerKind)>, preds: &[usize]) -> Vec<usize> {
        let level = self.levels.len();
        let mut ids = Vec::new();
        for (name, kind) in items {
            let id = self.layers.len();
            let (flops_g, mem_mb, out_mb) = profile::profile(&kind, BATCH);
            self.layers.push(Layer::new(id, name, kind, flops_g, mem_mb, out_mb, level));
            for &p in preds {
                self.edges.push((p, id));
            }
            ids.push(id);
        }
        self.levels.push(ids.clone());
        ids
    }

    fn finish(self) -> ModelGraph {
        let g = ModelGraph { name: self.name, layers: self.layers, edges: self.edges, levels: self.levels };
        g.check().expect("builder produced invalid graph");
        g
    }
}

/// VGG-16: 13 conv layers (fused with ReLU), 5 pools, 3 FC — strictly
/// sequential, dominated by fc1 (25088→4096, ~411 MB of weights).
pub fn vgg16() -> ModelGraph {
    let mut b = Builder::new("vgg16");
    let cfg: &[(usize, usize, usize, usize)] = &[
        // (hw, cin, cout, convs-in-block)
        (224, 3, 64, 2),
        (112, 64, 128, 2),
        (56, 128, 256, 3),
        (28, 256, 512, 3),
        (14, 512, 512, 3),
    ];
    for (bi, &(hw, cin, cout, n)) in cfg.iter().enumerate() {
        for ci in 0..n {
            let cin = if ci == 0 { cin } else { cout };
            b.push(
                &format!("conv{}_{}", bi + 1, ci + 1),
                LayerKind::Conv { hw, cin, cout, k: 3 },
                &[],
            );
        }
        b.push(&format!("pool{}", bi + 1), LayerKind::Pool { hw, c: cout }, &[]);
    }
    b.push("fc1", LayerKind::Dense { din: 7 * 7 * 512, dout: 4096 }, &[]);
    b.push("fc2", LayerKind::Dense { din: 4096, dout: 4096 }, &[]);
    b.push("fc3", LayerKind::Dense { din: 4096, dout: 1000 }, &[]);
    b.finish()
}

/// GoogleNet (Inception v1): conv stem, 9 inception modules (each one
/// level of 4 parallel branch tasks plus a concat), avg-pool classifier.
pub fn googlenet() -> ModelGraph {
    let mut b = Builder::new("googlenet");
    b.push("conv1", LayerKind::Conv { hw: 112, cin: 3, cout: 64, k: 7 }, &[]);
    b.push("pool1", LayerKind::Pool { hw: 112, c: 64 }, &[]);
    b.push("conv2", LayerKind::Conv { hw: 56, cin: 64, cout: 192, k: 3 }, &[]);
    b.push("pool2", LayerKind::Pool { hw: 56, c: 192 }, &[]);

    // (name, hw, cin, branch channels: 1x1, 3x3, 5x5, pool-proj)
    let modules: &[(&str, usize, usize, [usize; 4])] = &[
        ("3a", 28, 192, [64, 128, 32, 32]),
        ("3b", 28, 256, [128, 192, 96, 64]),
        ("4a", 14, 480, [192, 208, 48, 64]),
        ("4b", 14, 512, [160, 224, 64, 64]),
        ("4c", 14, 512, [128, 256, 64, 64]),
        ("4d", 14, 512, [112, 288, 64, 64]),
        ("4e", 14, 528, [256, 320, 128, 128]),
        ("5a", 7, 832, [256, 320, 128, 128]),
        ("5b", 7, 832, [384, 384, 128, 128]),
    ];
    for &(mname, hw, cin, ch) in modules {
        let preds = b.levels.last().unwrap().clone();
        let branches = vec![
            (format!("inc{mname}_1x1"), LayerKind::Conv { hw, cin, cout: ch[0], k: 1 }),
            (format!("inc{mname}_3x3"), LayerKind::Conv { hw, cin, cout: ch[1], k: 3 }),
            (format!("inc{mname}_5x5"), LayerKind::Conv { hw, cin, cout: ch[2], k: 5 }),
            (format!("inc{mname}_pool"), LayerKind::Conv { hw, cin, cout: ch[3], k: 1 }),
        ];
        b.push_parallel(branches, &preds);
        let c: usize = ch.iter().sum();
        b.push(&format!("inc{mname}_concat"), LayerKind::Concat { hw, c }, &[]);
    }
    b.push("avgpool", LayerKind::Pool { hw: 7, c: 1024 }, &[]);
    b.push("fc", LayerKind::Dense { din: 1024, dout: 1000 }, &[]);
    b.finish()
}

/// The RNN of the paper's §V-A: LSTM sequence model on the Air-Quality
/// dataset (5 metal-oxide sensor channels, hourly windows of 24 steps,
/// AQI regression head), per the cited Keras LSTM tutorial shape.
pub fn rnn() -> ModelGraph {
    let mut b = Builder::new("rnn");
    b.push("embed", LayerKind::Embed { vocab: 256, dim: 32, seq: 24 }, &[]);
    b.push("lstm1", LayerKind::Lstm { din: 32, hidden: 128, steps: 24 }, &[]);
    b.push("lstm2", LayerKind::Lstm { din: 128, hidden: 128, steps: 24 }, &[]);
    b.push("dense1", LayerKind::Dense { din: 128, dout: 64 }, &[]);
    b.push("dense2", LayerKind::Dense { din: 64, dout: 1 }, &[]);
    b.finish()
}

/// The transformer LM trained for real by `examples/edge_cluster_train`
/// (mirrors python/compile/model.py LmConfig defaults: vocab 512, seq 64,
/// d_model 128, 2 layers, 4 heads).
pub fn transformer_lm() -> ModelGraph {
    let mut b = Builder::new("transformer_lm");
    let (d, seq, heads, ff) = (128usize, 64usize, 4usize, 512usize);
    b.push("embed", LayerKind::Embed { vocab: 512, dim: d, seq }, &[]);
    for li in 0..2 {
        b.push(&format!("attn{li}"), LayerKind::Attention { seq, dim: d, heads }, &[]);
        b.push(&format!("ff{li}_up"), LayerKind::Dense { din: d, dout: ff }, &[]);
        b.push(&format!("ff{li}_down"), LayerKind::Dense { din: ff, dout: d }, &[]);
    }
    b.push("head", LayerKind::Dense { din: d, dout: 512 }, &[]);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_layer_count() {
        let g = vgg16();
        // 13 convs + 5 pools + 3 fc = 21 tasks.
        assert_eq!(g.n_layers(), 21);
        assert_eq!(g.levels.len(), 21);
    }

    #[test]
    fn googlenet_structure() {
        let g = googlenet();
        // stem(4) + 9 * (4 branches + concat) + avgpool + fc
        assert_eq!(g.n_layers(), 4 + 9 * 5 + 2);
        let parallel_levels = g.levels.iter().filter(|l| l.len() == 4).count();
        assert_eq!(parallel_levels, 9);
    }

    #[test]
    fn rnn_is_small_and_sequential() {
        let g = rnn();
        assert_eq!(g.n_layers(), 5);
        assert!(g.param_mb() < 10.0, "rnn should be tiny: {}", g.param_mb());
    }

    #[test]
    fn vgg_flops_realistic() {
        // VGG-16 fwd ≈ 31 GFLOPs/image (15.5 GMACs) → x3 bwd x8 batch
        // ≈ 744 GFLOPs/iter.
        let g = vgg16();
        let total = g.total_flops_g();
        assert!((400.0..1200.0).contains(&total), "total={total}");
    }

    #[test]
    fn googlenet_flops_much_smaller_than_vgg() {
        assert!(googlenet().total_flops_g() < 0.3 * vgg16().total_flops_g());
    }

    #[test]
    fn inception_branches_share_preds() {
        let g = googlenet();
        // Every 4-wide level's members must have identical predecessor sets.
        for lvl in g.levels.iter().filter(|l| l.len() == 4) {
            let preds_of = |id: usize| {
                let mut p: Vec<usize> =
                    g.edges.iter().filter(|(_, b)| *b == id).map(|(a, _)| *a).collect();
                p.sort_unstable();
                p
            };
            let first = preds_of(lvl[0]);
            for &id in &lvl[1..] {
                assert_eq!(preds_of(id), first);
            }
        }
    }

    #[test]
    fn edges_reference_valid_layers() {
        for g in [vgg16(), googlenet(), rnn(), transformer_lm()] {
            for &(a, b) in &g.edges {
                assert!(a < g.n_layers() && b < g.n_layers());
            }
        }
    }
}
