//! Analytic layer profiler.
//!
//! Stands in for the paper's TensorFlow-benchmark-tool profiling step:
//! given a [`LayerKind`] and the training batch size it
//! produces the per-iteration demands the schedulers consume —
//! GFLOPs (fwd+bwd), resident memory (weights + activations + gradients),
//! and activation-output transfer size.
//!
//! Constants are calibrated to edge-class devices: a reference host core
//! (CPU host-ratio 1.0) sustains [`GFLOPS_PER_HOST`] GFLOP/s, the target
//! scheduling rate is one iteration per [`TARGET_ITER_SECS`].

use super::LayerKind;

/// GFLOP/s a full reference core sustains on DNN kernels.
pub const GFLOPS_PER_HOST: f64 = 8.0;
/// Nominal iteration period used to convert per-iteration work into
/// demand *rates* (CPU host-ratio, Mbps).  Calibrated so a cluster of
/// five Table-I edges can host its three concurrent DL jobs just under
/// the α threshold when scheduled well — the regime the paper evaluates
/// (good schedules avoid overload, bad ones collide).
pub const TARGET_ITER_SECS: f64 = 240.0;
/// Bytes per fp32 scalar.
const BYTES_F32: f64 = 4.0;
/// Backward pass costs ~2x the forward FLOPs (standard rule of thumb).
const BWD_FACTOR: f64 = 3.0;

/// Forward GFLOPs for one sample through the layer.
pub fn fwd_gflops(kind: &LayerKind) -> f64 {
    let flops = match kind {
        LayerKind::Conv { hw, cin, cout, k } => {
            2.0 * (hw * hw) as f64 * (*cin as f64) * (*cout as f64) * (k * k) as f64
        }
        LayerKind::Pool { hw, c } => (hw * hw * c) as f64 * 4.0,
        LayerKind::Dense { din, dout } => 2.0 * (*din as f64) * (*dout as f64),
        LayerKind::Lstm { din, hidden, steps } => {
            // 4 gates, input + recurrent matmuls, per step.
            (*steps as f64) * 2.0 * 4.0 * ((din + hidden) * hidden) as f64
        }
        LayerKind::Embed { dim, seq, .. } => (seq * dim) as f64,
        LayerKind::Attention { seq, dim, .. } => {
            // qkv + out projections + 2 * (seq x seq x dim) score/context.
            2.0 * 4.0 * (dim * dim * seq) as f64 + 2.0 * 2.0 * (seq * seq * dim) as f64
        }
        LayerKind::Concat { hw, c } => (hw * hw * c) as f64,
    };
    flops / 1e9
}

/// Parameter memory in MB.
pub fn weight_mb(kind: &LayerKind) -> f64 {
    let params = match kind {
        LayerKind::Conv { cin, cout, k, .. } => (cin * cout * k * k + cout) as f64,
        LayerKind::Pool { .. } | LayerKind::Concat { .. } => 0.0,
        LayerKind::Dense { din, dout } => (din * dout + dout) as f64,
        LayerKind::Lstm { din, hidden, .. } => (4 * ((din + hidden) * hidden + hidden)) as f64,
        LayerKind::Embed { vocab, dim, .. } => (vocab * dim) as f64,
        LayerKind::Attention { dim, .. } => (4 * dim * dim) as f64,
    };
    params * BYTES_F32 / 1e6
}

/// Activation output size in MB for one sample.
pub fn out_mb(kind: &LayerKind) -> f64 {
    let elems = match kind {
        LayerKind::Conv { hw, cout, .. } => (hw * hw * cout) as f64,
        LayerKind::Pool { hw, c } => ((hw / 2).max(1).pow(2) * c) as f64,
        LayerKind::Dense { dout, .. } => *dout as f64,
        LayerKind::Lstm { hidden, steps, .. } => (hidden * steps) as f64,
        LayerKind::Embed { dim, seq, .. } => (seq * dim) as f64,
        LayerKind::Attention { seq, dim, .. } => (seq * dim) as f64,
        LayerKind::Concat { hw, c } => (hw * hw * c) as f64,
    };
    elems * BYTES_F32 / 1e6
}

/// Full per-iteration profile for a layer at the given batch size:
/// `(flops_g, mem_mb, out_mb)`.
pub fn profile(kind: &LayerKind, batch: usize) -> (f64, f64, f64) {
    let b = batch as f64;
    let flops_g = fwd_gflops(kind) * b * BWD_FACTOR;
    // Resident set: weights + in/out activations.  Gradients are pushed
    // to the parameter server as they are produced (PS strategy), so they
    // do not stay resident.
    let act_mb = out_mb(kind) * b;
    let mem_mb = weight_mb(kind) + 2.0 * act_mb;
    (flops_g, mem_mb.max(0.1), out_mb(kind) * b)
}

/// CPU host-ratio demand to run `flops_g` GFLOPs within the target
/// iteration period, clamped to one full host core.
pub fn cpu_demand(flops_g: f64) -> f64 {
    (flops_g / (GFLOPS_PER_HOST * TARGET_ITER_SECS)).clamp(0.005, 1.0)
}

/// Bandwidth demand (Mbps) to ship `out_mb` per iteration.
pub fn bw_demand(out_mb: f64) -> f64 {
    out_mb * 8.0 / TARGET_ITER_SECS
}

/// Compute seconds for `flops_g` GFLOPs on `cpu_share` host-ratio worth
/// of CPU (the simulator's core speed law).
pub fn compute_secs(flops_g: f64, cpu_share: f64) -> f64 {
    if flops_g <= 0.0 {
        return 0.0;
    }
    flops_g / (GFLOPS_PER_HOST * cpu_share.max(1e-6))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_flops_formula() {
        // 2 * HW^2 * cin * cout * k^2
        let k = LayerKind::Conv { hw: 10, cin: 3, cout: 8, k: 3 };
        let expect = 2.0 * 100.0 * 3.0 * 8.0 * 9.0 / 1e9;
        assert!((fwd_gflops(&k) - expect).abs() < 1e-12);
    }

    #[test]
    fn dense_weight_memory() {
        let k = LayerKind::Dense { din: 1000, dout: 500 };
        let expect = (1000.0 * 500.0 + 500.0) * 4.0 / 1e6;
        assert!((weight_mb(&k) - expect).abs() < 1e-9);
    }

    #[test]
    fn vgg_fc1_is_heavy() {
        // 25088 -> 4096: ~102.8M params ≈ 411 MB.
        let k = LayerKind::Dense { din: 25088, dout: 4096 };
        assert!((weight_mb(&k) - 411.0).abs() < 5.0);
    }

    #[test]
    fn profile_scales_with_batch() {
        let k = LayerKind::Conv { hw: 28, cin: 32, cout: 64, k: 3 };
        let (f1, m1, o1) = profile(&k, 1);
        let (f32_, _m32, o32) = profile(&k, 32);
        assert!((f32_ / f1 - 32.0).abs() < 1e-9);
        assert!((o32 / o1 - 32.0).abs() < 1e-9);
        assert!(m1 > 0.0);
    }

    #[test]
    fn cpu_demand_clamped() {
        assert_eq!(cpu_demand(0.0), 0.005);
        assert_eq!(cpu_demand(1e9), 1.0);
        let mid = cpu_demand(GFLOPS_PER_HOST * TARGET_ITER_SECS * 0.5);
        assert!((mid - 0.5).abs() < 1e-12);
    }

    #[test]
    fn compute_secs_inverse_in_share() {
        let t1 = compute_secs(80.0, 1.0);
        let t2 = compute_secs(80.0, 0.5);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        assert_eq!(compute_secs(0.0, 1.0), 0.0);
    }

    #[test]
    fn lstm_flops_scale_with_steps() {
        let a = LayerKind::Lstm { din: 5, hidden: 64, steps: 10 };
        let b = LayerKind::Lstm { din: 5, hidden: 64, steps: 20 };
        assert!((fwd_gflops(&b) / fwd_gflops(&a) - 2.0).abs() < 1e-9);
    }
}
