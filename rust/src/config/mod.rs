//! Experiment configuration: a single struct covering every knob of the
//! paper's evaluation, plus a TOML-subset file parser so deployments can
//! version their setups (`srole run --config exp.toml`).
//!
//! The parser supports the subset needed for flat experiment configs:
//! `key = value` lines with string / number / boolean values, `#`
//! comments, and `[section]` headers that prefix keys (`section.key`).

use std::collections::BTreeMap;

use crate::cluster::profiles::{ResourceProfile, CONTAINER_PROFILE, REAL_EDGE_PROFILE};
use crate::dnn::ModelKind;
use crate::net::mobility::{self, MobilityModel};
use crate::obs::TraceMode;
use crate::rl::RewardParams;
use crate::workload::serving::{RateShape, ServingSpec};
use crate::workload::ArrivalProcess;

/// Which testbed profile (Table I row group) to emulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    Container,
    RealEdge,
}

impl Profile {
    pub fn resource_profile(&self) -> &'static ResourceProfile {
        match self {
            Profile::Container => &CONTAINER_PROFILE,
            Profile::RealEdge => &REAL_EDGE_PROFILE,
        }
    }

    pub fn parse(s: &str) -> Option<Profile> {
        match s {
            "container" | "emulation" => Some(Profile::Container),
            "real_edge" | "real" | "realdevice" => Some(Profile::RealEdge),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Profile::Container => "container",
            Profile::RealEdge => "real_edge",
        }
    }
}

/// Full experiment configuration (defaults = paper §V-A).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Total edge nodes (25 containers / 10 Pis in the paper).
    pub n_edges: usize,
    /// Edges per cluster ("each cluster has 5 edge nodes").
    pub cluster_size: usize,
    pub profile: Profile,
    pub model: ModelKind,
    /// Workload fraction (1.0 = six PageRank jobs per cluster).
    pub workload: f64,
    /// DL jobs per cluster.
    pub jobs_per_cluster: usize,
    /// Training iterations per job.
    pub iterations: usize,
    pub reward: RewardParams,
    /// Sub-clusters per cluster for SROLE-D.
    pub subclusters: usize,
    /// Rounds between agent state-view refreshes.
    pub refresh_rounds: usize,
    /// Offline pre-training episodes before the measured run.
    pub pretrain_episodes: usize,
    /// Experiment repetitions (the paper repeats 5x).
    pub repetitions: usize,
    /// Tabular policy learning rate / exploration.
    pub lr: f64,
    pub epsilon: f64,
    /// Mean node-failure events per 1000 simulated seconds across the
    /// deployment (0 = static membership, the paper's setup).
    pub failure_rate: f64,
    /// Seconds a failed node stays down before rejoining (0 = failed
    /// nodes never come back).
    pub rejoin_secs: f64,
    /// DL-job arrival process (batched waves, Poisson stream, or trace).
    pub arrival: ArrivalProcess,
    /// Node motion model (static geography, random waypoint, or a
    /// deterministic trace patrol).
    pub mobility: MobilityModel,
    /// Seconds between mobility ticks (position advances and topology /
    /// shield-region refreshes happen at this granularity).
    pub mobility_tick_secs: f64,
    /// Correlated-failure blast radius in meters: a scheduled node
    /// failure also takes down every alive node within this distance of
    /// the seed's current position (0 = independent failures).
    pub blast_radius_m: f64,
    /// Force the event-driven driver even for static configurations —
    /// used by sweeps that compare churn rates against a 0-failure
    /// baseline, so every cell runs the same driver and only the churn
    /// axis varies.
    pub event_driven: bool,
    /// Geographic spread of each cluster in meters (0 = the profile's
    /// default).  `figures scale` overrides this to keep node *density*
    /// constant as single-cluster deployments grow toward 10k nodes —
    /// the profile's 10 m disc would otherwise make the adjacency (and
    /// every O(n·k) structure keyed on it) a complete graph.
    pub cluster_spread_m: f64,
    /// Run on the dense materialized link matrices instead of the sparse
    /// on-demand pricing model.  The dense store is the in-tree
    /// equivalence reference: it prices links through the identical
    /// function, consumes no extra RNG, and must reproduce sparse runs
    /// byte-identically (pinned by harness tests).  O(n²) memory — never
    /// enable it at scale.
    pub dense_links: bool,
    /// Worker threads for the region-sharded tick engine.  `0` keeps the
    /// legacy single-stream dynamic driver; `>= 1` routes dynamic runs to
    /// `coordinator::shard`, where `1` runs every region lane inline
    /// (the serial reference) and `N` spreads lanes over `N` OS threads.
    /// Results are byte-identical for every value `>= 1`.
    pub shards: usize,
    /// Evaluate each wave round's greedy Q-net forwards as one batched
    /// matmul instead of one forward per agent.  The per-agent path
    /// stays as the in-tree equivalence reference: batched runs must
    /// reproduce it byte-identically (pinned by harness tests), so this
    /// knob only trades wall-clock, never results.
    pub batch_decisions: bool,
    /// Model the *latency* benefit of batching too: charge one amortized
    /// batch evaluation per marl wave round instead of per-candidate
    /// policy-eval costs.  Off by default so modeled `decision_secs`
    /// keeps the paper's legacy per-candidate accounting.
    pub batched_eval_cost: bool,
    /// Super-shield group fanout for the hierarchical shield tree
    /// (`shield::tree`): regional cluster shields are grouped under at
    /// most `tree_fanout` clusters per group (grid-seeded over cluster
    /// centroids), and the sharded driver buckets cross-region events by
    /// group and handles the groups concurrently.  `0` (the default)
    /// disables the tree — the flat serial driver is the pinned
    /// reference.  `RunMetrics` is byte-identical for every value
    /// (pinned by harness tests) as long as `cross_cluster` stays off.
    pub tree_fanout: usize,
    /// Opt-in cross-cluster placement: reschedule fallbacks may target
    /// an alive boundary-pair neighbor in an adjacent cluster, shielded
    /// through the tree group's visible sets.  Off by default because it
    /// changes placements (and therefore results); requires
    /// `tree_fanout >= 1` and the global-state driver (`shards = 0`).
    pub cross_cluster: bool,
    /// Observability mode (`off | profile | full`, see `obs`).  `off`
    /// (the default) arms nothing — the per-decision loop keeps its
    /// uninstrumented cost.  Tracing only *reads* state and draws no
    /// RNG, so `RunMetrics` is byte-identical across modes (pinned by
    /// harness tests).
    pub trace: TraceMode,
    /// Run the open-loop inference-serving workload instead of training
    /// waves (`workload = "serving"` in TOML, or `serving = true`).
    /// DL training jobs are suppressed; `workload::serving` generates a
    /// request stream that both event drivers route through the
    /// admission gate + shielded per-request placement path.
    pub serving: bool,
    /// Mean serving request rate per cluster, requests/second.
    pub request_rate: f64,
    /// Serving rate envelope (`const | diurnal | bursty`).
    pub rate_shape: RateShape,
    /// Serving latency objective in seconds; a served request whose
    /// end-to-end latency (queue + decision + transfer + service)
    /// exceeds it counts as one SLO violation.
    pub slo_secs: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 1,
            n_edges: 25,
            cluster_size: 5,
            profile: Profile::Container,
            model: ModelKind::Vgg16,
            workload: 1.0,
            jobs_per_cluster: 3,
            iterations: 50,
            reward: RewardParams::default(),
            subclusters: 2,
            refresh_rounds: 3,
            pretrain_episodes: 300,
            repetitions: 5,
            lr: 0.15,
            epsilon: 0.1,
            failure_rate: 0.0,
            rejoin_secs: 0.0,
            arrival: ArrivalProcess::default(),
            mobility: MobilityModel::Static,
            mobility_tick_secs: mobility::DEFAULT_TICK_SECS,
            blast_radius_m: 0.0,
            event_driven: false,
            cluster_spread_m: 0.0,
            dense_links: false,
            shards: 0,
            batch_decisions: true,
            batched_eval_cost: false,
            tree_fanout: 0,
            cross_cluster: false,
            trace: TraceMode::Off,
            serving: false,
            request_rate: 0.5,
            rate_shape: RateShape::Constant,
            slo_secs: 5.0,
        }
    }
}

impl ExperimentConfig {
    /// Paper's real-device testbed: 10 Raspberry Pis, one cluster.
    pub fn real_device() -> Self {
        ExperimentConfig {
            n_edges: 10,
            cluster_size: 10,
            profile: Profile::RealEdge,
            subclusters: 2,
            ..Default::default()
        }
    }

    /// Load overrides from a TOML-subset string.
    pub fn from_toml(text: &str) -> Result<Self, String> {
        let kv = parse_toml_subset(text)?;
        let mut cfg = ExperimentConfig::default();
        for (key, val) in &kv {
            cfg.apply(key, val)?;
        }
        Ok(cfg)
    }

    /// Apply one `key = value` override.
    pub fn apply(&mut self, key: &str, val: &str) -> Result<(), String> {
        let parse_f64 = |v: &str| v.parse::<f64>().map_err(|_| format!("bad number {v} for {key}"));
        let parse_usize =
            |v: &str| v.parse::<usize>().map_err(|_| format!("bad integer {v} for {key}"));
        match key {
            "seed" => self.seed = val.parse().map_err(|_| format!("bad seed {val}"))?,
            "n_edges" | "edges" => self.n_edges = parse_usize(val)?,
            "cluster_size" => self.cluster_size = parse_usize(val)?,
            "profile" => {
                self.profile = Profile::parse(val).ok_or(format!("unknown profile {val}"))?
            }
            "model" => self.model = ModelKind::parse(val).ok_or(format!("unknown model {val}"))?,
            // `workload` keeps its historical numeric meaning (the
            // PageRank load fraction) and additionally selects the
            // workload *kind*: `training` (the default) or `serving`.
            "workload" => match val {
                "training" => self.serving = false,
                "serving" => self.serving = true,
                num => self.workload = parse_f64(num)?,
            },
            "serving" => {
                self.serving = match val {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    other => return Err(format!("bad boolean {other} for serving")),
                }
            }
            "request_rate" => self.request_rate = parse_f64(val)?,
            "rate_shape" => {
                self.rate_shape =
                    RateShape::parse(val).ok_or(format!("unknown rate shape {val}"))?
            }
            "slo_secs" | "slo" => self.slo_secs = parse_f64(val)?,
            "jobs_per_cluster" => self.jobs_per_cluster = parse_usize(val)?,
            "iterations" => self.iterations = parse_usize(val)?,
            "reward.alpha" | "alpha" => self.reward.alpha = parse_f64(val)?,
            "reward.rho" | "rho" => self.reward.rho = parse_f64(val)?,
            "reward.gamma" | "gamma" => self.reward.gamma = parse_f64(val)?,
            "reward.kappa" | "kappa" => self.reward.kappa = parse_f64(val)?,
            "subclusters" => self.subclusters = parse_usize(val)?,
            "refresh_rounds" => self.refresh_rounds = parse_usize(val)?,
            "pretrain_episodes" => self.pretrain_episodes = parse_usize(val)?,
            "repetitions" => self.repetitions = parse_usize(val)?,
            "lr" => self.lr = parse_f64(val)?,
            "epsilon" => self.epsilon = parse_f64(val)?,
            "failure_rate" => self.failure_rate = parse_f64(val)?,
            "rejoin_secs" => self.rejoin_secs = parse_f64(val)?,
            "arrival" => {
                self.arrival = match val {
                    "batched" => ArrivalProcess::default(),
                    "poisson" => ArrivalProcess::Poisson { rate: 0.05 },
                    other => return Err(format!("unknown arrival process {other}")),
                }
            }
            "arrival_rate" => self.arrival = ArrivalProcess::Poisson { rate: parse_f64(val)? },
            "mobility" => {
                self.mobility = match val {
                    "static" | "none" => MobilityModel::Static,
                    "rwp" | "random_waypoint" | "waypoint" => MobilityModel::RandomWaypoint {
                        speed_mps: mobility::DEFAULT_SPEED_MPS,
                        pause_secs: mobility::DEFAULT_PAUSE_SECS,
                    },
                    "trace" => MobilityModel::default_trace(),
                    other => return Err(format!("unknown mobility model {other}")),
                }
            }
            // Speed / pause refine the model; setting them on a static
            // config upgrades it to random waypoint (BTreeMap ordering
            // guarantees "mobility" applies before "mobility_*" keys
            // when both appear in one file).
            "mobility_speed" => {
                let v = parse_f64(val)?;
                self.mobility = match self.mobility.clone() {
                    MobilityModel::RandomWaypoint { pause_secs, .. } => {
                        MobilityModel::RandomWaypoint { speed_mps: v, pause_secs }
                    }
                    MobilityModel::Trace { offsets, .. } => {
                        MobilityModel::Trace { offsets, speed_mps: v }
                    }
                    MobilityModel::Static => MobilityModel::RandomWaypoint {
                        speed_mps: v,
                        pause_secs: mobility::DEFAULT_PAUSE_SECS,
                    },
                };
            }
            "mobility_pause" => {
                let v = parse_f64(val)?;
                self.mobility = match self.mobility.clone() {
                    MobilityModel::RandomWaypoint { speed_mps, .. } => {
                        MobilityModel::RandomWaypoint { speed_mps, pause_secs: v }
                    }
                    MobilityModel::Trace { .. } => {
                        return Err("trace mobility has no pause".into())
                    }
                    MobilityModel::Static => MobilityModel::RandomWaypoint {
                        speed_mps: mobility::DEFAULT_SPEED_MPS,
                        pause_secs: v,
                    },
                };
            }
            "mobility_tick_secs" => self.mobility_tick_secs = parse_f64(val)?,
            "blast_radius_m" | "blast_radius" => self.blast_radius_m = parse_f64(val)?,
            "cluster_spread_m" | "spread" => self.cluster_spread_m = parse_f64(val)?,
            "dense_links" => {
                self.dense_links = match val {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    other => return Err(format!("bad boolean {other} for dense_links")),
                }
            }
            "shards" => self.shards = parse_usize(val)?,
            "tree_fanout" => self.tree_fanout = parse_usize(val)?,
            "cross_cluster" => {
                self.cross_cluster = match val {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    other => return Err(format!("bad boolean {other} for cross_cluster")),
                }
            }
            "batch_decisions" => {
                self.batch_decisions = match val {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    other => return Err(format!("bad boolean {other} for batch_decisions")),
                }
            }
            "batched_eval_cost" => {
                self.batched_eval_cost = match val {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    other => return Err(format!("bad boolean {other} for batched_eval_cost")),
                }
            }
            "trace" => {
                self.trace =
                    TraceMode::parse(val).ok_or(format!("unknown trace mode {val} for trace"))?
            }
            other => return Err(format!("unknown config key {other}")),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_edges == 0 || self.cluster_size == 0 {
            return Err("n_edges and cluster_size must be positive".into());
        }
        if self.cluster_size > self.n_edges {
            return Err("cluster_size exceeds n_edges".into());
        }
        if !(0.0..=1.0).contains(&self.workload) {
            return Err("workload must be in [0, 1]".into());
        }
        if !(0.0..=1.0).contains(&self.reward.alpha) {
            return Err("alpha must be in [0, 1]".into());
        }
        if self.subclusters == 0 {
            return Err("subclusters must be positive".into());
        }
        if self.failure_rate < 0.0 || self.rejoin_secs < 0.0 {
            return Err("failure_rate and rejoin_secs must be non-negative".into());
        }
        if self.blast_radius_m < 0.0 {
            return Err("blast_radius_m must be non-negative".into());
        }
        if self.cluster_spread_m.is_nan() || self.cluster_spread_m < 0.0 {
            return Err("cluster_spread_m must be non-negative".into());
        }
        if self.cross_cluster {
            if self.tree_fanout == 0 {
                return Err("cross_cluster requires tree_fanout >= 1 (the shield tree carries the boundary-pair visible sets)".into());
            }
            if self.shards > 0 {
                return Err("cross_cluster requires the global-state driver (shards = 0): lane resource windows cannot host foreign-cluster layers".into());
            }
        }
        if self.mobility_tick_secs.is_nan() || self.mobility_tick_secs <= 0.0 {
            return Err("mobility_tick_secs must be positive".into());
        }
        if !self.request_rate.is_finite() || self.request_rate < 0.0 {
            return Err("request_rate must be a finite non-negative rate".into());
        }
        if !self.slo_secs.is_finite() || self.slo_secs < 0.0 {
            return Err("slo_secs must be a finite non-negative latency objective".into());
        }
        match &self.mobility {
            MobilityModel::Static => {}
            MobilityModel::RandomWaypoint { speed_mps, pause_secs } => {
                if *speed_mps < 0.0 || *pause_secs < 0.0 {
                    return Err("mobility speed and pause must be non-negative".into());
                }
            }
            MobilityModel::Trace { speed_mps, .. } => {
                if *speed_mps < 0.0 {
                    return Err("mobility speed must be non-negative".into());
                }
            }
        }
        match &self.arrival {
            ArrivalProcess::Poisson { rate } if *rate <= 0.0 => {
                return Err("poisson arrival rate must be positive".into());
            }
            ArrivalProcess::Batched { window } if *window < 0.0 => {
                return Err("batched arrival window must be non-negative".into());
            }
            _ => {}
        }
        Ok(())
    }

    /// Whether this configuration runs on the dynamic event-driven driver
    /// (node churn, node mobility, an online arrival process, or an
    /// explicit opt-in) instead of the static pre-batched wave path.
    pub fn dynamic(&self) -> bool {
        self.event_driven
            || self.serving
            || self.shards > 0
            || self.failure_rate > 0.0
            || self.mobility.enabled()
            || !matches!(self.arrival, ArrivalProcess::Batched { .. })
    }

    /// Serving-workload knobs bundled for `workload::serving`.
    pub fn serving_spec(&self) -> ServingSpec {
        ServingSpec { shape: self.rate_shape, rate: self.request_rate, slo_secs: self.slo_secs }
    }
}

/// Parse the TOML subset: sections, key=value, comments, quoted strings.
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let name = stripped.strip_suffix(']').ok_or(format!("line {}: bad section", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or(format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        let mut val = val.trim().to_string();
        if (val.starts_with('"') && val.ends_with('"') && val.len() >= 2)
            || (val.starts_with('\'') && val.ends_with('\'') && val.len() >= 2)
        {
            val = val[1..val.len() - 1].to_string();
        }
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.insert(full_key, val);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = ExperimentConfig::default();
        assert_eq!(c.n_edges, 25);
        assert_eq!(c.cluster_size, 5);
        assert_eq!(c.jobs_per_cluster, 3);
        assert_eq!(c.iterations, 50);
        assert_eq!(c.repetitions, 5);
        assert_eq!(c.reward.alpha, 0.9);
        c.validate().unwrap();
    }

    #[test]
    fn real_device_testbed() {
        let c = ExperimentConfig::real_device();
        assert_eq!(c.n_edges, 10);
        assert_eq!(c.cluster_size, 10);
        assert_eq!(c.profile, Profile::RealEdge);
        c.validate().unwrap();
    }

    #[test]
    fn toml_subset_parses() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            # experiment
            seed = 7
            model = "googlenet"
            workload = 0.8
            [reward]
            kappa = 200
            alpha = 0.95
            "#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.model, ModelKind::GoogleNet);
        assert_eq!(cfg.workload, 0.8);
        assert_eq!(cfg.reward.kappa, 200.0);
        assert_eq!(cfg.reward.alpha, 0.95);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(ExperimentConfig::from_toml("bogus = 1").is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = ExperimentConfig::default();
        c.workload = 1.5;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.cluster_size = 100;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.subclusters = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn churn_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            failure_rate = 1.5
            rejoin_secs = 120
            arrival_rate = 0.02
            "#,
        )
        .unwrap();
        assert_eq!(cfg.failure_rate, 1.5);
        assert_eq!(cfg.rejoin_secs, 120.0);
        assert_eq!(cfg.arrival, ArrivalProcess::Poisson { rate: 0.02 });
        assert!(cfg.dynamic());
        cfg.validate().unwrap();

        assert!(!ExperimentConfig::default().dynamic());
        let mut bad = ExperimentConfig::default();
        bad.failure_rate = -1.0;
        assert!(bad.validate().is_err());
        let mut bad = ExperimentConfig::default();
        bad.arrival = ArrivalProcess::Poisson { rate: 0.0 };
        assert!(bad.validate().is_err());
        assert!(ExperimentConfig::from_toml("arrival = \"lognormal\"").is_err());
    }

    #[test]
    fn mobility_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            mobility = "rwp"
            mobility_speed = 2.5
            mobility_pause = 15
            mobility_tick_secs = 5
            blast_radius_m = 12
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.mobility,
            MobilityModel::RandomWaypoint { speed_mps: 2.5, pause_secs: 15.0 }
        );
        assert_eq!(cfg.mobility_tick_secs, 5.0);
        assert_eq!(cfg.blast_radius_m, 12.0);
        assert!(cfg.dynamic(), "mobility routes through the event driver");
        cfg.validate().unwrap();

        // Speed alone upgrades a static config to random waypoint.
        let cfg = ExperimentConfig::from_toml("mobility_speed = 1.5").unwrap();
        assert!(matches!(
            cfg.mobility,
            MobilityModel::RandomWaypoint { speed_mps, .. } if speed_mps == 1.5
        ));
        // Trace parses; pause on a trace is rejected.
        let cfg = ExperimentConfig::from_toml("mobility = \"trace\"").unwrap();
        assert!(matches!(cfg.mobility, MobilityModel::Trace { .. }));
        assert!(cfg.dynamic());
        assert!(ExperimentConfig::from_toml("mobility = \"trace\"\nmobility_pause = 5").is_err());
        assert!(ExperimentConfig::from_toml("mobility = \"teleport\"").is_err());

        // Static stays on the wave path; bad values are rejected.
        assert!(!ExperimentConfig::default().dynamic());
        let mut bad = ExperimentConfig::default();
        bad.mobility = MobilityModel::RandomWaypoint { speed_mps: -1.0, pause_secs: 0.0 };
        assert!(bad.validate().is_err());
        let mut bad = ExperimentConfig::default();
        bad.mobility_tick_secs = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = ExperimentConfig::default();
        bad.blast_radius_m = -3.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn link_model_and_spread_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            dense_links = true
            cluster_spread_m = 40
            "#,
        )
        .unwrap();
        assert!(cfg.dense_links);
        assert_eq!(cfg.cluster_spread_m, 40.0);
        cfg.validate().unwrap();

        let cfg = ExperimentConfig::from_toml("dense_links = false\nspread = 0").unwrap();
        assert!(!cfg.dense_links);
        assert_eq!(cfg.cluster_spread_m, 0.0);
        cfg.validate().unwrap();

        assert!(ExperimentConfig::from_toml("dense_links = \"maybe\"").is_err());
        let mut bad = ExperimentConfig::default();
        bad.cluster_spread_m = -1.0;
        assert!(bad.validate().is_err());
        // The defaults stay on the sparse model with the profile spread.
        let d = ExperimentConfig::default();
        assert!(!d.dense_links);
        assert_eq!(d.cluster_spread_m, 0.0);
    }

    #[test]
    fn shards_key_parses_and_routes_dynamic() {
        let cfg = ExperimentConfig::from_toml("shards = 4").unwrap();
        assert_eq!(cfg.shards, 4);
        assert!(cfg.dynamic(), "shards > 0 must route through the event engines");
        cfg.validate().unwrap();

        let d = ExperimentConfig::default();
        assert_eq!(d.shards, 0, "default stays on the legacy single-stream driver");
        assert!(!d.dynamic());
        assert!(ExperimentConfig::from_toml("shards = -1").is_err());
    }

    #[test]
    fn tree_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml("tree_fanout = 8").unwrap();
        assert_eq!(cfg.tree_fanout, 8);
        assert!(
            !cfg.dynamic(),
            "the tree knob alone must not flip the engine: fanout is byte-identical"
        );
        cfg.validate().unwrap();

        let d = ExperimentConfig::default();
        assert_eq!(d.tree_fanout, 0, "default stays on the flat serial-driver reference");
        assert!(!d.cross_cluster, "cross-cluster placement is opt-in");

        let xc = ExperimentConfig::from_toml("tree_fanout = 2\ncross_cluster = true").unwrap();
        assert!(xc.cross_cluster);
        xc.validate().unwrap();

        // cross_cluster without a tree (or with lane-sliced state) is rejected.
        let bad = ExperimentConfig::from_toml("cross_cluster = true").unwrap();
        assert!(bad.validate().is_err());
        let bad = ExperimentConfig::from_toml(
            "cross_cluster = true\ntree_fanout = 2\nshards = 4",
        )
        .unwrap();
        assert!(bad.validate().is_err());
        assert!(ExperimentConfig::from_toml("cross_cluster = maybe").is_err());
    }

    #[test]
    fn decision_path_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            batch_decisions = false
            batched_eval_cost = true
            "#,
        )
        .unwrap();
        assert!(!cfg.batch_decisions);
        assert!(cfg.batched_eval_cost);
        cfg.validate().unwrap();

        // Batched decisions are the default; the cost knob is opt-in so
        // modeled latency keeps the legacy per-candidate accounting.
        let d = ExperimentConfig::default();
        assert!(d.batch_decisions);
        assert!(!d.batched_eval_cost);
        assert!(ExperimentConfig::from_toml("batch_decisions = \"maybe\"").is_err());
        assert!(ExperimentConfig::from_toml("batched_eval_cost = \"2\"").is_err());
    }

    #[test]
    fn trace_key_parses_and_defaults_off() {
        let cfg = ExperimentConfig::from_toml("trace = \"profile\"").unwrap();
        assert_eq!(cfg.trace, TraceMode::Profile);
        cfg.validate().unwrap();
        let cfg = ExperimentConfig::from_toml("trace = \"full\"").unwrap();
        assert_eq!(cfg.trace, TraceMode::Full);
        // Tracing is observation-only: it must never flip a config onto
        // a different driver.
        assert!(!cfg.dynamic());

        let d = ExperimentConfig::default();
        assert_eq!(d.trace, TraceMode::Off, "tracing must be off by default");
        assert!(ExperimentConfig::from_toml("trace = \"verbose\"").is_err());
    }

    #[test]
    fn serving_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            workload = "serving"
            request_rate = 2.5
            rate_shape = "diurnal"
            slo_secs = 1.5
            "#,
        )
        .unwrap();
        assert!(cfg.serving);
        assert_eq!(cfg.request_rate, 2.5);
        assert_eq!(cfg.rate_shape, RateShape::Diurnal);
        assert_eq!(cfg.slo_secs, 1.5);
        assert!(cfg.dynamic(), "serving must route through the event drivers");
        cfg.validate().unwrap();

        // The numeric meaning of `workload` is unchanged, and
        // `workload = "training"` switches back off.
        let cfg = ExperimentConfig::from_toml("workload = 0.8").unwrap();
        assert!(!cfg.serving);
        assert_eq!(cfg.workload, 0.8);
        let cfg =
            ExperimentConfig::from_toml("serving = true\nworkload = \"training\"").unwrap();
        assert!(!cfg.serving, "workload = training must override serving = true");

        let d = ExperimentConfig::default();
        assert!(!d.serving, "training is the default workload");
        assert_eq!(d.rate_shape, RateShape::Constant);
        assert!(!d.dynamic());

        // SLO of 0 is a legal (degenerate) objective; negatives and
        // non-finite rates are not.
        let mut zero = ExperimentConfig::default();
        zero.serving = true;
        zero.slo_secs = 0.0;
        zero.validate().unwrap();
        let mut bad = ExperimentConfig::default();
        bad.request_rate = -1.0;
        assert!(bad.validate().is_err());
        let mut bad = ExperimentConfig::default();
        bad.request_rate = f64::INFINITY;
        assert!(bad.validate().is_err());
        let mut bad = ExperimentConfig::default();
        bad.slo_secs = -0.5;
        assert!(bad.validate().is_err());
        assert!(ExperimentConfig::from_toml("rate_shape = \"sawtooth\"").is_err());
        assert!(ExperimentConfig::from_toml("serving = \"maybe\"").is_err());
    }

    #[test]
    fn profile_parse() {
        assert_eq!(Profile::parse("container"), Some(Profile::Container));
        assert_eq!(Profile::parse("real"), Some(Profile::RealEdge));
        assert_eq!(Profile::parse("x"), None);
    }
}
