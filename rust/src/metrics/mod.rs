//! Metric collection and summarization for the paper's four evaluation
//! metrics (§V-C): job completion time, tasks per device, resource
//! utilization, computation-time overhead — plus action collisions.

use crate::util::json::{obj, Json};
use crate::util::stats::{mean_of, Pcts, Summary};

/// Raw metrics of one experiment run (one method × one configuration ×
/// one seed).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Per-job training time (the paper's JCT).
    pub jct: Vec<f64>,
    /// Per-job total decision latency (scheduling + shielding + queue).
    pub decision_secs: Vec<f64>,
    /// Per-job scheduling-only time (Fig 7 blue bar).
    pub sched_secs: Vec<f64>,
    /// Per-job shielding-only time (Fig 7 orange bar).
    pub shield_secs: Vec<f64>,
    /// Action collisions (scheduling-time, pre-correction) + runtime
    /// overload onsets (Fig 8 metric).
    pub collisions: usize,
    /// Nodes entering actual overload during execution (kept separate
    /// from the paper's action-collision count).
    pub runtime_overloads: usize,
    pub shield_corrections: usize,
    pub memory_violations: usize,
    /// Node failure events delivered by the event core (dynamic runs).
    pub node_failures: usize,
    /// Secondary failures triggered by a seed failure's geographic blast
    /// radius (correlated churn; counted on top of `node_failures`).
    pub correlated_failures: usize,
    /// Layers stranded by failures and re-placed by the reschedule
    /// handler.
    pub rescheduled_layers: usize,
    /// Node position updates delivered by mobility ticks (a node moving
    /// during one tick counts once).
    pub mobility_moves: usize,
    /// Shield-region handoffs: a moving node crossed a sub-cluster
    /// boundary and migrated between sub-shields (SROLE-D only).
    pub region_handoffs: usize,
    /// Layers migrated because mobility carried their host out of the
    /// owning agent's transmission range.
    pub migrated_layers: usize,
    /// Q-net forward errors absorbed by the DQN policy's
    /// greedy-by-utilization fallback (0 for tabular policies).  A
    /// non-zero count flags a degraded decision path that previously
    /// hid behind silent all-zero Q values.
    pub qnet_fwd_errors: usize,
    /// Batched Q-net forward chunks issued by the batched decision path
    /// (one fixed-lane matmul each; 0 when the per-agent reference path
    /// or a tabular policy runs).
    pub qnet_batch_fwds: usize,
    /// Real agent rows scored through those batched chunks.
    pub qnet_batch_rows: usize,
    /// Zero-padding rows added to fill each chunk to the lane size
    /// (computed and discarded; a measure of ragged-batch waste).
    pub qnet_batch_pad_rows: usize,
    /// Cross-cluster shield checks escalated past a super-shield group
    /// to the tree root because the boundary pair crossed a group
    /// boundary (`shield::tree`; 0 unless `cross_cluster` is on).
    pub shield_tree_escalations: usize,
    /// Layers placed on an alive boundary-pair neighbor in an adjacent
    /// cluster (`cross_cluster` opt-in; 0 when the knob is off).
    pub cross_cluster_placements: usize,
    /// Per-request end-to-end serving latency (queue + decision +
    /// transfer + service), pushed in cluster order at run end so both
    /// event drivers emit the identical vector (serving workload only).
    pub request_latency: Vec<f64>,
    /// Requests admitted, placed, and completed.
    pub requests_served: usize,
    /// Requests refused by the admission gate (every candidate host
    /// over the α view-overload threshold at decision time).
    pub requests_rejected: usize,
    /// Admitted requests lost in flight (host failed mid-service).
    pub requests_failed: usize,
    /// Served requests whose end-to-end latency exceeded the SLO.
    pub slo_violations: usize,
    /// Per-(node, sample) task counts.
    pub tasks_per_device: Vec<f64>,
    /// Per-(node, sample) utilization per resource.
    pub util_cpu: Vec<f64>,
    pub util_mem: Vec<f64>,
    pub util_bw: Vec<f64>,
    pub makespan: f64,
}

impl RunMetrics {
    pub fn jct_summary(&self) -> Summary {
        Summary::of(&self.jct)
    }

    pub fn tasks_summary(&self) -> Option<Summary> {
        if self.tasks_per_device.is_empty() {
            None
        } else {
            Some(Summary::of(&self.tasks_per_device))
        }
    }

    pub fn util_summary(&self, kind: &str) -> Option<Summary> {
        let v = match kind {
            "cpu" => &self.util_cpu,
            "mem" => &self.util_mem,
            "bw" => &self.util_bw,
            _ => panic!("unknown resource {kind}"),
        };
        if v.is_empty() {
            None
        } else {
            Some(Summary::of(v))
        }
    }

    pub fn mean_sched_secs(&self) -> f64 {
        mean_of(&self.sched_secs)
    }

    pub fn mean_shield_secs(&self) -> f64 {
        mean_of(&self.shield_secs)
    }

    /// Mean full decision latency (queue + scheduling + shielding) —
    /// the paper's "time from when a job is initiated to when the task
    /// assignment schedule is made".
    pub fn mean_decision_secs(&self) -> f64 {
        mean_of(&self.decision_secs)
    }

    /// Combined per-job decision overhead (Fig 7 total bar height):
    /// decision latency, split by the figures into a scheduling part
    /// (`mean_decision_secs - mean_shield_secs`, which for centralized RL
    /// includes queueing at the head) and the shielding part.
    pub fn mean_overhead_secs(&self) -> f64 {
        self.mean_decision_secs()
    }

    /// Request-latency percentiles of the serving workload (`None` when
    /// no request completed — training runs, or full rejection).
    pub fn request_summary(&self) -> Option<Pcts> {
        Pcts::of(&self.request_latency)
    }

    /// Serialize for `--json` output.
    pub fn to_json(&self) -> Json {
        let arr = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        obj(vec![
            ("jct", arr(&self.jct)),
            ("decision_secs", arr(&self.decision_secs)),
            ("sched_secs", arr(&self.sched_secs)),
            ("shield_secs", arr(&self.shield_secs)),
            ("collisions", Json::Num(self.collisions as f64)),
            ("runtime_overloads", Json::Num(self.runtime_overloads as f64)),
            ("shield_corrections", Json::Num(self.shield_corrections as f64)),
            ("memory_violations", Json::Num(self.memory_violations as f64)),
            ("node_failures", Json::Num(self.node_failures as f64)),
            ("correlated_failures", Json::Num(self.correlated_failures as f64)),
            ("rescheduled_layers", Json::Num(self.rescheduled_layers as f64)),
            ("mobility_moves", Json::Num(self.mobility_moves as f64)),
            ("region_handoffs", Json::Num(self.region_handoffs as f64)),
            ("migrated_layers", Json::Num(self.migrated_layers as f64)),
            ("qnet_fwd_errors", Json::Num(self.qnet_fwd_errors as f64)),
            ("qnet_batch_fwds", Json::Num(self.qnet_batch_fwds as f64)),
            ("qnet_batch_rows", Json::Num(self.qnet_batch_rows as f64)),
            ("qnet_batch_pad_rows", Json::Num(self.qnet_batch_pad_rows as f64)),
            ("shield_tree_escalations", Json::Num(self.shield_tree_escalations as f64)),
            ("cross_cluster_placements", Json::Num(self.cross_cluster_placements as f64)),
            ("request_latency", arr(&self.request_latency)),
            ("requests_served", Json::Num(self.requests_served as f64)),
            ("requests_rejected", Json::Num(self.requests_rejected as f64)),
            ("requests_failed", Json::Num(self.requests_failed as f64)),
            ("slo_violations", Json::Num(self.slo_violations as f64)),
            ("tasks_per_device", arr(&self.tasks_per_device)),
            ("util_cpu", arr(&self.util_cpu)),
            ("util_mem", arr(&self.util_mem)),
            ("util_bw", arr(&self.util_bw)),
            ("makespan", Json::Num(self.makespan)),
        ])
    }

    /// Merge another run (repetition) into a pooled sample.
    pub fn absorb(&mut self, other: &RunMetrics) {
        self.jct.extend_from_slice(&other.jct);
        self.decision_secs.extend_from_slice(&other.decision_secs);
        self.sched_secs.extend_from_slice(&other.sched_secs);
        self.shield_secs.extend_from_slice(&other.shield_secs);
        self.collisions += other.collisions;
        self.runtime_overloads += other.runtime_overloads;
        self.shield_corrections += other.shield_corrections;
        self.memory_violations += other.memory_violations;
        self.node_failures += other.node_failures;
        self.correlated_failures += other.correlated_failures;
        self.rescheduled_layers += other.rescheduled_layers;
        self.mobility_moves += other.mobility_moves;
        self.region_handoffs += other.region_handoffs;
        self.migrated_layers += other.migrated_layers;
        self.qnet_fwd_errors += other.qnet_fwd_errors;
        self.qnet_batch_fwds += other.qnet_batch_fwds;
        self.qnet_batch_rows += other.qnet_batch_rows;
        self.qnet_batch_pad_rows += other.qnet_batch_pad_rows;
        self.shield_tree_escalations += other.shield_tree_escalations;
        self.cross_cluster_placements += other.cross_cluster_placements;
        self.request_latency.extend_from_slice(&other.request_latency);
        self.requests_served += other.requests_served;
        self.requests_rejected += other.requests_rejected;
        self.requests_failed += other.requests_failed;
        self.slo_violations += other.slo_violations;
        self.tasks_per_device.extend_from_slice(&other.tasks_per_device);
        self.util_cpu.extend_from_slice(&other.util_cpu);
        self.util_mem.extend_from_slice(&other.util_mem);
        self.util_bw.extend_from_slice(&other.util_bw);
        self.makespan = self.makespan.max(other.makespan);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunMetrics {
        RunMetrics {
            jct: vec![100.0, 200.0, 300.0],
            decision_secs: vec![1.0, 2.0, 3.0],
            sched_secs: vec![0.5, 0.5, 0.5],
            shield_secs: vec![0.1, 0.1, 0.1],
            collisions: 4,
            runtime_overloads: 0,
            shield_corrections: 2,
            memory_violations: 1,
            node_failures: 1,
            correlated_failures: 1,
            rescheduled_layers: 2,
            mobility_moves: 4,
            region_handoffs: 2,
            migrated_layers: 1,
            qnet_fwd_errors: 3,
            qnet_batch_fwds: 5,
            qnet_batch_rows: 40,
            qnet_batch_pad_rows: 3,
            shield_tree_escalations: 2,
            cross_cluster_placements: 1,
            request_latency: vec![0.5, 1.5, 6.0],
            requests_served: 3,
            requests_rejected: 1,
            requests_failed: 1,
            slo_violations: 1,
            tasks_per_device: vec![2.0, 3.0, 5.0],
            util_cpu: vec![0.5, 0.6],
            util_mem: vec![0.4, 0.5],
            util_bw: vec![0.1, 0.2],
            makespan: 1234.0,
        }
    }

    #[test]
    fn summaries() {
        let m = sample();
        assert_eq!(m.jct_summary().median, 200.0);
        assert_eq!(m.tasks_summary().unwrap().median, 3.0);
        assert_eq!(m.util_summary("cpu").unwrap().n, 2);
        assert!((m.mean_decision_secs() - 2.0).abs() < 1e-12);
        assert!((m.mean_overhead_secs() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn request_summary_reports_percentiles() {
        let m = sample();
        let p = m.request_summary().unwrap();
        assert_eq!(p.n, 3);
        assert_eq!(p.p50, 1.5);
        assert!(p.p999 > p.p50 && p.p999 <= 6.0);
        assert!(RunMetrics::default().request_summary().is_none());
    }

    #[test]
    fn absorb_pools_samples() {
        let mut a = sample();
        let b = sample();
        a.absorb(&b);
        assert_eq!(a.jct.len(), 6);
        assert_eq!(a.request_latency.len(), 6);
        assert_eq!(a.requests_served, 6);
        assert_eq!(a.requests_rejected, 2);
        assert_eq!(a.requests_failed, 2);
        assert_eq!(a.slo_violations, 2);
        assert_eq!(a.collisions, 8);
        assert_eq!(a.region_handoffs, 4);
        assert_eq!(a.correlated_failures, 2);
        assert_eq!(a.migrated_layers, 2);
        assert_eq!(a.mobility_moves, 8);
        assert_eq!(a.qnet_fwd_errors, 6);
        assert_eq!(a.qnet_batch_fwds, 10);
        assert_eq!(a.qnet_batch_rows, 80);
        assert_eq!(a.qnet_batch_pad_rows, 6);
        assert_eq!(a.shield_tree_escalations, 4);
        assert_eq!(a.cross_cluster_placements, 2);
        assert_eq!(a.makespan, 1234.0);
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        let j = m.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("collisions").unwrap().as_usize(), Some(4));
        assert_eq!(parsed.get("qnet_fwd_errors").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("qnet_batch_fwds").unwrap().as_usize(), Some(5));
        assert_eq!(parsed.get("qnet_batch_rows").unwrap().as_usize(), Some(40));
        assert_eq!(parsed.get("qnet_batch_pad_rows").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("jct").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    #[should_panic]
    fn unknown_resource_panics() {
        sample().util_summary("gpu");
    }

    /// Fill every field with nonzero random values so a field whose
    /// `absorb` arm is missing cannot hide behind a zero default.
    fn randomized(rng: &mut crate::util::Rng) -> RunMetrics {
        fn v(rng: &mut crate::util::Rng) -> Vec<f64> {
            (0..rng.below(5) + 1).map(|_| rng.range_f64(0.1, 100.0)).collect()
        }
        fn c(rng: &mut crate::util::Rng) -> usize {
            rng.below(10) + 1
        }
        RunMetrics {
            jct: v(rng),
            decision_secs: v(rng),
            sched_secs: v(rng),
            shield_secs: v(rng),
            collisions: c(rng),
            runtime_overloads: c(rng),
            shield_corrections: c(rng),
            memory_violations: c(rng),
            node_failures: c(rng),
            correlated_failures: c(rng),
            rescheduled_layers: c(rng),
            mobility_moves: c(rng),
            region_handoffs: c(rng),
            migrated_layers: c(rng),
            qnet_fwd_errors: c(rng),
            qnet_batch_fwds: c(rng),
            qnet_batch_rows: c(rng),
            qnet_batch_pad_rows: c(rng),
            shield_tree_escalations: c(rng),
            cross_cluster_placements: c(rng),
            request_latency: v(rng),
            requests_served: c(rng),
            requests_rejected: c(rng),
            requests_failed: c(rng),
            slo_violations: c(rng),
            tasks_per_device: v(rng),
            util_cpu: v(rng),
            util_mem: v(rng),
            util_bw: v(rng),
            makespan: rng.range_f64(1.0, 1e4),
        }
    }

    /// Property: absorbing two randomized runs must extend every array
    /// field and sum every counter (max for `makespan`).  Driven by the
    /// `to_json` key set, so adding a field to the struct + serializer
    /// while forgetting its `absorb` arm fails here instead of silently
    /// dropping repetitions.
    #[test]
    fn absorb_covers_every_field() {
        let mut rng = crate::util::Rng::new(0xab50b);
        for _ in 0..16 {
            let a = randomized(&mut rng);
            let b = randomized(&mut rng);
            let mut merged = a.clone();
            merged.absorb(&b);
            let (Json::Obj(ma), Json::Obj(mb), Json::Obj(mm)) =
                (a.to_json(), b.to_json(), merged.to_json())
            else {
                panic!("to_json must serialize to an object");
            };
            assert_eq!(ma.len(), mm.len(), "absorb must not add or drop fields");
            for (key, va) in &ma {
                let (vb, vm) = (&mb[key], &mm[key]);
                match (va, vb, vm) {
                    (Json::Arr(x), Json::Arr(y), Json::Arr(z)) => {
                        assert_eq!(z.len(), x.len() + y.len(), "{key} must pool samples");
                        assert_eq!(&z[..x.len()], &x[..], "{key} must keep self's samples");
                        assert_eq!(&z[x.len()..], &y[..], "{key} must append other's");
                    }
                    (Json::Num(x), Json::Num(y), Json::Num(z)) => {
                        if key == "makespan" {
                            assert_eq!(*z, x.max(*y), "makespan must merge by max");
                        } else {
                            assert!((z - (x + y)).abs() < 1e-9, "counter {key} must sum");
                        }
                    }
                    _ => panic!("unexpected shapes for field {key}"),
                }
            }
        }
    }
}
