//! Workload generation: DL training jobs plus PageRank-like background
//! jobs.
//!
//! §V-A: "we trained one DNN model in each cluster and add several other
//! non-ML jobs (PageRank) from the HiBench benchmark to vary available
//! resources on the edges. ... We run x = 2,3,...,6 PageRank jobs in each
//! cluster throughout the whole training period to control the workload.
//! Workload of 100% means there are 6 PageRank jobs running
//! simultaneously."  Three DL jobs of the same model run per cluster,
//! initiated by randomly chosen edge nodes.

pub mod serving;

use crate::cluster::{Deployment, NodeId, Resources};
use crate::dnn::ModelKind;
use crate::util::Rng;

/// Workload level as a fraction (1.0 = 100% = 6 PageRank jobs/cluster).
pub const PAGERANK_AT_FULL: usize = 6;

/// Map the paper's workload percentage to PageRank jobs per cluster.
///
/// §V-A runs x = 2..6 jobs for the 60 %..100 % levels — one job per
/// 10 % step, i.e. `x = (w − 40 %) / 10 %`.  Off-level workloads map to
/// the *nearest* level; exact midpoints (e.g. 75 %) resolve **down**
/// (a half-level cannot spawn half a PageRank job, and under-provisioning
/// keeps the sweep monotone without ever overshooting a paper level).
/// Clamped to `[0, 6]`; levels at or below 45 % spawn no background jobs.
pub fn pagerank_jobs_for_workload(workload: f64) -> usize {
    // Nearest integer level with ties-down: ceil(x − 1/2).
    let level = 10.0 * workload - 4.0;
    (level - 0.5).ceil().clamp(0.0, PAGERANK_AT_FULL as f64) as usize
}

/// How DL jobs arrive over simulated time.
///
/// The paper's evaluation releases each cluster's jobs near-simultaneously
/// ([`ArrivalProcess::Batched`]); the dynamic event core also supports an
/// online Poisson stream and trace replay, turning the pre-generated wave
/// setup into an arrival *process* the scheduler reacts to event by event.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// All of a cluster's jobs arrive independently within `window`
    /// seconds of t = 0 (the paper's concurrent-wave setup).
    Batched { window: f64 },
    /// Poisson stream: inter-arrival gaps drawn from Exp(`rate`), per
    /// cluster, `rate` in arrivals per second.
    Poisson { rate: f64 },
    /// Trace replay: the i-th job of every cluster arrives at the i-th
    /// offset (seconds).  Jobs beyond the trace reuse its last entry.
    Trace(Vec<f64>),
}

impl Default for ArrivalProcess {
    fn default() -> Self {
        ArrivalProcess::Batched { window: 5.0 }
    }
}

impl ArrivalProcess {
    /// Short tag for scenario labels (`b`, `p0.05`, `t4`).  Rates print
    /// un-rounded so distinct sweep cells never share a label.
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Batched { .. } => "b".to_string(),
            ArrivalProcess::Poisson { rate } => format!("p{rate}"),
            ArrivalProcess::Trace(offsets) => format!("t{}", offsets.len()),
        }
    }
}

/// A background (non-ML) job occupying resources on one node.  Modeled on
/// HiBench PageRank: an iterative graph kernel with a steady CPU/memory
/// footprint and periodic shuffle traffic.
#[derive(Debug, Clone)]
pub struct BackgroundJob {
    pub id: usize,
    pub node: NodeId,
    pub demand: Resources,
    /// Active interval [start, end) in simulation seconds.
    pub start: f64,
    pub end: f64,
}

impl BackgroundJob {
    pub fn active_at(&self, t: f64) -> bool {
        t >= self.start && t < self.end
    }
}

/// One DL training job: a model replica trained within one cluster,
/// initiated by a member edge node (the MARL agent that schedules it).
#[derive(Debug, Clone)]
pub struct DlJob {
    pub id: usize,
    pub cluster: usize,
    pub owner: NodeId,
    pub model: ModelKind,
    pub arrival: f64,
    pub iterations: usize,
}

/// The full generated workload for one experiment run.
#[derive(Debug, Clone)]
pub struct Workload {
    pub dl_jobs: Vec<DlJob>,
    pub background: Vec<BackgroundJob>,
}

/// Generation knobs (defaults follow §V-A).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub model: ModelKind,
    /// DL jobs per cluster ("three DL training jobs of the same type").
    pub jobs_per_cluster: usize,
    /// Training iterations per job ("50 iterations").
    pub iterations: usize,
    /// Workload fraction (1.0 = 6 PageRank jobs per cluster).
    pub workload: f64,
    /// How the cluster's jobs arrive: batched (the paper's concurrent
    /// waves — concurrent decision-making is what makes action collisions
    /// possible), Poisson, or trace replay.
    pub arrival: ArrivalProcess,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            model: ModelKind::Vgg16,
            jobs_per_cluster: 3,
            iterations: 50,
            workload: 1.0,
            arrival: ArrivalProcess::default(),
        }
    }
}

impl Workload {
    pub fn generate(rng: &mut Rng, dep: &Deployment, spec: &WorkloadSpec, horizon: f64) -> Workload {
        let mut dl_jobs = Vec::new();
        let mut background = Vec::new();
        let mut job_id = 0;
        let mut bg_id = 0;
        for (ci, cluster) in dep.clusters.iter().enumerate() {
            // DL jobs: random owners, arrivals drawn from the process.
            let mut poisson_t = 0.0f64;
            for j in 0..spec.jobs_per_cluster {
                let owner = *rng.choose(&cluster.members);
                let arrival = match &spec.arrival {
                    ArrivalProcess::Batched { window } => rng.range_f64(0.0, *window),
                    ArrivalProcess::Poisson { rate } => {
                        poisson_t += rng.exp(rate.max(1e-9));
                        poisson_t
                    }
                    ArrivalProcess::Trace(offsets) => {
                        let last = offsets.last().copied().unwrap_or(0.0);
                        offsets.get(j).copied().unwrap_or(last)
                    }
                };
                dl_jobs.push(DlJob {
                    id: job_id,
                    cluster: ci,
                    owner,
                    model: spec.model,
                    arrival,
                    iterations: spec.iterations,
                });
                job_id += 1;
            }
            // PageRank background jobs: run "throughout the whole training
            // period" — active across the horizon, re-spawning with churn
            // so contention varies over time.
            let n_bg = pagerank_jobs_for_workload(spec.workload);
            for _ in 0..n_bg {
                let mut t = 0.0;
                while t < horizon {
                    let node = *rng.choose(&cluster.members);
                    // HiBench PageRank footprint: moderate CPU, a few
                    // hundred MB, bursty shuffle bandwidth.
                    let demand = Resources {
                        cpu: rng.range_f64(0.10, 0.30),
                        mem: rng.range_f64(96.0, 256.0),
                        bw: rng.range_f64(2.0, 10.0),
                    };
                    let dur = rng.range_f64(0.2, 0.5) * horizon.max(60.0);
                    background.push(BackgroundJob {
                        id: bg_id,
                        node,
                        demand,
                        start: t,
                        end: (t + dur).min(horizon),
                    });
                    bg_id += 1;
                    t += dur;
                }
            }
        }
        Workload { dl_jobs, background }
    }

    /// Total background demand resident on `node` at time `t`.
    pub fn background_demand_at(&self, node: NodeId, t: f64) -> Resources {
        let mut total = Resources::default();
        for j in self.background.iter().filter(|j| j.node == node && j.active_at(t)) {
            total = total.add(&j.demand);
        }
        total
    }

    /// Number of background tasks resident on `node` at `t`.
    pub fn background_count_at(&self, node: NodeId, t: f64) -> usize {
        self.background.iter().filter(|j| j.node == node && j.active_at(t)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, CONTAINER_PROFILE};

    fn dep() -> Deployment {
        let mut rng = Rng::new(5);
        Deployment::generate(&mut rng, 25, 5, &CONTAINER_PROFILE)
    }

    #[test]
    fn workload_mapping_matches_paper() {
        assert_eq!(pagerank_jobs_for_workload(1.0), 6);
        assert_eq!(pagerank_jobs_for_workload(0.9), 5);
        assert_eq!(pagerank_jobs_for_workload(0.8), 4);
        assert_eq!(pagerank_jobs_for_workload(0.7), 3);
        assert_eq!(pagerank_jobs_for_workload(0.6), 2);
    }

    #[test]
    fn workload_mapping_full_range() {
        // Every 5 % step over 0–100 % (index i = workload / 5 %): nearest
        // §V-A level, exact midpoints (45 %, 55 %, ..., 75 %) resolving
        // down, clamped to [0, 6].  The 70 %/75 % boundary in particular
        // must not round a midpoint up past its level.
        let expected = [0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6];
        for (i, &jobs) in expected.iter().enumerate() {
            let w = i as f64 / 20.0;
            assert_eq!(pagerank_jobs_for_workload(w), jobs, "workload {w}");
        }
    }

    #[test]
    fn workload_mapping_is_monotone_and_clamped() {
        let mut prev = 0usize;
        for i in 0..=1000 {
            let w = i as f64 / 1000.0;
            let jobs = pagerank_jobs_for_workload(w);
            assert!(jobs >= prev, "mapping not monotone at {w}");
            assert!(jobs <= PAGERANK_AT_FULL);
            prev = jobs;
        }
        // Out-of-range inputs stay clamped rather than panicking.
        assert_eq!(pagerank_jobs_for_workload(-1.0), 0);
        assert_eq!(pagerank_jobs_for_workload(2.0), PAGERANK_AT_FULL);
    }

    #[test]
    fn three_jobs_per_cluster() {
        let mut rng = Rng::new(1);
        let d = dep();
        let w = Workload::generate(&mut rng, &d, &WorkloadSpec::default(), 1000.0);
        assert_eq!(w.dl_jobs.len(), 15);
        for ci in 0..5 {
            assert_eq!(w.dl_jobs.iter().filter(|j| j.cluster == ci).count(), 3);
        }
    }

    #[test]
    fn owners_belong_to_cluster() {
        let mut rng = Rng::new(2);
        let d = dep();
        let w = Workload::generate(&mut rng, &d, &WorkloadSpec::default(), 1000.0);
        for j in &w.dl_jobs {
            assert!(d.clusters[j.cluster].members.contains(&j.owner));
        }
    }

    #[test]
    fn background_respects_workload_level() {
        let mut rng = Rng::new(3);
        let d = dep();
        let mut spec = WorkloadSpec::default();
        spec.workload = 0.6;
        let w_low = Workload::generate(&mut rng, &d, &spec, 1000.0);
        spec.workload = 1.0;
        let mut rng = Rng::new(3);
        let w_high = Workload::generate(&mut rng, &d, &spec, 1000.0);
        let load = |w: &Workload| -> f64 {
            d.nodes.iter().map(|n| w.background_demand_at(n.id, 500.0).cpu).sum()
        };
        assert!(load(&w_high) > load(&w_low));
    }

    #[test]
    fn background_covers_horizon() {
        let mut rng = Rng::new(4);
        let d = dep();
        let w = Workload::generate(&mut rng, &d, &WorkloadSpec::default(), 2000.0);
        // At any sampled time, every cluster should have some active
        // background demand at 100% workload.
        for t in [10.0, 500.0, 1500.0, 1999.0] {
            for c in &d.clusters {
                let total: f64 = c.members.iter().map(|&m| w.background_demand_at(m, t).cpu).sum();
                assert!(total > 0.0, "no background at t={t}");
            }
        }
    }

    #[test]
    fn zero_workload_means_no_background() {
        let mut rng = Rng::new(6);
        let d = dep();
        let mut spec = WorkloadSpec::default();
        spec.workload = 0.4; // maps to 0 jobs
        let w = Workload::generate(&mut rng, &d, &spec, 1000.0);
        assert_eq!(pagerank_jobs_for_workload(0.4), 0);
        assert!(w.background.is_empty());
    }

    #[test]
    fn poisson_arrivals_are_increasing_per_cluster() {
        let mut rng = Rng::new(8);
        let d = dep();
        let spec = WorkloadSpec {
            arrival: ArrivalProcess::Poisson { rate: 0.05 },
            ..Default::default()
        };
        let w = Workload::generate(&mut rng, &d, &spec, 1000.0);
        for ci in 0..d.clusters.len() {
            let arrivals: Vec<f64> =
                w.dl_jobs.iter().filter(|j| j.cluster == ci).map(|j| j.arrival).collect();
            assert_eq!(arrivals.len(), 3);
            assert!(arrivals.windows(2).all(|p| p[1] > p[0]), "{arrivals:?}");
            assert!(arrivals[0] > 0.0);
        }
    }

    #[test]
    fn trace_arrivals_replay_offsets() {
        let mut rng = Rng::new(8);
        let d = dep();
        let spec = WorkloadSpec {
            jobs_per_cluster: 4,
            arrival: ArrivalProcess::Trace(vec![0.0, 30.0, 90.0]),
            ..Default::default()
        };
        let w = Workload::generate(&mut rng, &d, &spec, 1000.0);
        let arrivals: Vec<f64> =
            w.dl_jobs.iter().filter(|j| j.cluster == 0).map(|j| j.arrival).collect();
        // Jobs beyond the trace reuse its last offset.
        assert_eq!(arrivals, vec![0.0, 30.0, 90.0, 90.0]);
    }

    #[test]
    fn arrival_process_labels() {
        assert_eq!(ArrivalProcess::default().label(), "b");
        assert_eq!(ArrivalProcess::Poisson { rate: 0.1 }.label(), "p0.1");
        assert_eq!(ArrivalProcess::Poisson { rate: 0.004 }.label(), "p0.004");
        assert_eq!(ArrivalProcess::Trace(vec![1.0, 2.0]).label(), "t2");
    }

    #[test]
    fn deterministic_generation() {
        let d = dep();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = Workload::generate(&mut r1, &d, &WorkloadSpec::default(), 1000.0);
        let b = Workload::generate(&mut r2, &d, &WorkloadSpec::default(), 1000.0);
        assert_eq!(a.dl_jobs.len(), b.dl_jobs.len());
        for (x, y) in a.dl_jobs.iter().zip(&b.dl_jobs) {
            assert_eq!(x.owner, y.owner);
            assert_eq!(x.arrival, y.arrival);
        }
    }
}
