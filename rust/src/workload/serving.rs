//! Open-loop inference-request generation: the "millions of users"
//! serving workload (ROADMAP north star; Castellano et al.,
//! arXiv:2301.13618 framing).
//!
//! Training jobs are a *closed* set the drivers schedule once per wave;
//! serving is an *open loop*: requests keep arriving at a configured
//! rate whether or not the deployment keeps up, which is exactly the
//! regime where admission control and the shields' overload vetoes
//! matter.  The whole schedule is drawn up-front from one dedicated RNG
//! fork — both drivers (`coordinator::dynamic` and `coordinator::shard`)
//! replay the identical request table, which is what keeps serving
//! RunMetrics byte-identical across shard counts.
//!
//! Rate shapes are deterministic functions of simulated time; the
//! non-constant shapes are sampled by Lewis–Shedler thinning (draw a
//! Poisson stream at the peak rate, accept each point with probability
//! `rate(t) / peak`), so a shape's schedule is reproducible from the
//! seed alone.  [`ArrivalProcess::Trace`] bypasses the generator: the
//! trace offsets *are* the per-cluster request schedule (real-trace
//! replay through the same path the training arrivals already use).

use crate::cluster::{Deployment, NodeId, Resources};
use crate::util::Rng;
use crate::workload::ArrivalProcess;

/// Deterministic request-rate envelope over simulated time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RateShape {
    /// Flat `rate` requests/second per cluster.
    Constant,
    /// One sinusoidal "day" across the horizon: `rate · (1 + 0.8·sin)`,
    /// peaking at 1.8× and troughing at 0.2× the mean.
    Diurnal,
    /// Flat base with periodic correlated blasts: 8% of every quarter
    /// horizon runs at [`BURST_MULT`]× the base rate.
    Bursty,
}

/// Burst multiplier of [`RateShape::Bursty`] windows.
pub const BURST_MULT: f64 = 8.0;
/// Diurnal amplitude (fraction of the mean rate).
pub const DIURNAL_AMP: f64 = 0.8;
/// Fraction of each quarter-horizon a burst window covers.
const BURST_FRAC: f64 = 0.08;

impl RateShape {
    /// Instantaneous rate at simulated time `t` for mean rate `base`.
    pub fn rate_at(&self, base: f64, t: f64, horizon: f64) -> f64 {
        let h = horizon.max(1e-9);
        match self {
            RateShape::Constant => base,
            RateShape::Diurnal => {
                base * (1.0 + DIURNAL_AMP * (std::f64::consts::TAU * t / h).sin())
            }
            RateShape::Bursty => {
                if Self::in_burst(t, h) {
                    base * BURST_MULT
                } else {
                    base
                }
            }
        }
    }

    /// Whether `t` falls inside a correlated-blast window.
    pub fn in_burst(t: f64, horizon: f64) -> bool {
        let quarter = horizon.max(1e-9) / 4.0;
        (t / quarter).fract() < BURST_FRAC
    }

    /// Upper bound of `rate_at` over the horizon (the thinning envelope).
    pub fn peak(&self, base: f64) -> f64 {
        match self {
            RateShape::Constant => base,
            RateShape::Diurnal => base * (1.0 + DIURNAL_AMP),
            RateShape::Bursty => base * BURST_MULT,
        }
    }

    /// Short tag for scenario labels and the `rate_shape` config knob.
    pub fn label(&self) -> &'static str {
        match self {
            RateShape::Constant => "const",
            RateShape::Diurnal => "diurnal",
            RateShape::Bursty => "bursty",
        }
    }

    pub fn parse(s: &str) -> Option<RateShape> {
        match s.to_ascii_lowercase().as_str() {
            "const" | "constant" => Some(RateShape::Constant),
            "diurnal" => Some(RateShape::Diurnal),
            "bursty" | "burst" => Some(RateShape::Bursty),
            _ => None,
        }
    }
}

/// Serving-workload knobs (threaded from `ExperimentConfig`).
#[derive(Debug, Clone, Copy)]
pub struct ServingSpec {
    pub shape: RateShape,
    /// Mean request rate per cluster, requests/second.
    pub rate: f64,
    /// End-to-end latency objective; a served request whose total latency
    /// exceeds this counts as one SLO violation.
    pub slo_secs: f64,
}

impl Default for ServingSpec {
    fn default() -> Self {
        ServingSpec { shape: RateShape::Constant, rate: 0.5, slo_secs: 5.0 }
    }
}

/// One inference request: arrives at `arrival` on `origin`, needs one
/// model replica placed somewhere in its own cluster.  Requests are
/// cluster-local by construction — in the sharded engine they are
/// lane-local events, never barrier work.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub cluster: usize,
    pub origin: NodeId,
    pub arrival: f64,
    /// Estimated resident demand of serving this request.
    pub demand: Resources,
    /// Nominal service time on an uncontended host.
    pub service_secs: f64,
    /// Request + response payload priced over the origin→host link.
    pub mb: f64,
}

/// Draw the full request schedule: cluster-major, time-ascending within
/// each cluster, ids sequential in emission order.  `ArrivalProcess::
/// Trace` replays its offsets verbatim as each cluster's schedule (one
/// request per offset); every other arrival process uses the open-loop
/// `spec.shape` generator.  A non-positive rate yields an empty
/// schedule.
pub fn generate_requests(
    rng: &mut Rng,
    dep: &Deployment,
    spec: &ServingSpec,
    arrival: &ArrivalProcess,
    horizon: f64,
) -> Vec<Request> {
    let mut out = Vec::new();
    for (ci, cluster) in dep.clusters.iter().enumerate() {
        match arrival {
            ArrivalProcess::Trace(offsets) => {
                for &t in offsets {
                    if t < horizon {
                        push_request(rng, &mut out, ci, &cluster.members, t);
                    }
                }
            }
            _ => {
                let peak = spec.shape.peak(spec.rate);
                if peak <= 0.0 {
                    continue;
                }
                let mut t = 0.0f64;
                loop {
                    t += rng.exp(peak);
                    if t >= horizon {
                        break;
                    }
                    // Thinning: accept with probability rate(t)/peak.
                    // The uniform is drawn unconditionally so Constant
                    // (where it always accepts) stays on the same RNG
                    // stream as the shaped variants.
                    let u = rng.range_f64(0.0, 1.0);
                    if u * peak <= spec.shape.rate_at(spec.rate, t, horizon) {
                        push_request(rng, &mut out, ci, &cluster.members, t);
                    }
                }
            }
        }
    }
    out
}

/// Emit one request at `t` in cluster `ci` (origin, footprint, and
/// payload drawn from `rng`).  Inference footprints are small next to a
/// training layer: a model replica answering one query, not a pipeline
/// stage.
fn push_request(rng: &mut Rng, out: &mut Vec<Request>, ci: usize, members: &[NodeId], t: f64) {
    let origin = *rng.choose(members);
    let demand = Resources {
        cpu: rng.range_f64(0.05, 0.20),
        mem: rng.range_f64(32.0, 128.0),
        bw: rng.range_f64(1.0, 8.0),
    };
    let service_secs = rng.range_f64(0.05, 0.50);
    let mb = rng.range_f64(0.2, 2.0);
    out.push(Request {
        id: out.len(),
        cluster: ci,
        origin,
        arrival: t,
        demand,
        service_secs,
        mb,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Deployment, CONTAINER_PROFILE};

    fn dep() -> Deployment {
        let mut rng = Rng::new(5);
        Deployment::generate(&mut rng, 25, 5, &CONTAINER_PROFILE)
    }

    fn gen(shape: RateShape, rate: f64, seed: u64) -> Vec<Request> {
        let d = dep();
        let spec = ServingSpec { shape, rate, slo_secs: 5.0 };
        let mut rng = Rng::new(seed);
        generate_requests(&mut rng, &d, &spec, &ArrivalProcess::default(), 1000.0)
    }

    #[test]
    fn identical_seed_identical_schedule() {
        let a = gen(RateShape::Diurnal, 0.2, 42);
        let b = gen(RateShape::Diurnal, 0.2, 42);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.cluster, y.cluster);
            assert_eq!(x.origin, y.origin);
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.service_secs.to_bits(), y.service_secs.to_bits());
            assert_eq!(x.demand.cpu.to_bits(), y.demand.cpu.to_bits());
        }
    }

    #[test]
    fn zero_rate_generates_nothing() {
        assert!(gen(RateShape::Constant, 0.0, 1).is_empty());
        assert!(gen(RateShape::Bursty, 0.0, 1).is_empty());
    }

    #[test]
    fn requests_are_cluster_local_ordered_and_ided() {
        let reqs = gen(RateShape::Constant, 0.3, 7);
        let d = dep();
        assert!(!reqs.is_empty());
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i, "ids must be sequential in emission order");
            assert!(d.clusters[r.cluster].members.contains(&r.origin));
            assert!(r.arrival >= 0.0 && r.arrival < 1000.0);
            assert!(r.service_secs > 0.0 && r.mb > 0.0);
        }
        // Cluster-major, time-ascending within each cluster.
        for w in reqs.windows(2) {
            assert!(
                w[0].cluster < w[1].cluster
                    || (w[0].cluster == w[1].cluster && w[0].arrival < w[1].arrival)
            );
        }
    }

    #[test]
    fn trace_replays_offsets_per_cluster() {
        let d = dep();
        let spec = ServingSpec::default();
        let mut rng = Rng::new(3);
        let offsets = vec![1.0, 30.0, 90.0, 2000.0];
        let reqs = generate_requests(
            &mut rng,
            &d,
            &spec,
            &ArrivalProcess::Trace(offsets.clone()),
            1000.0,
        );
        // One request per in-horizon offset per cluster.
        assert_eq!(reqs.len(), 3 * d.clusters.len());
        for ci in 0..d.clusters.len() {
            let times: Vec<f64> =
                reqs.iter().filter(|r| r.cluster == ci).map(|r| r.arrival).collect();
            assert_eq!(times, vec![1.0, 30.0, 90.0]);
        }
    }

    #[test]
    fn diurnal_peak_half_outweighs_trough_half() {
        let reqs = gen(RateShape::Diurnal, 0.5, 11);
        // sin > 0 over the first half horizon: the peak half must carry
        // clearly more arrivals than the trough half.
        let first: usize = reqs.iter().filter(|r| r.arrival < 500.0).count();
        let second = reqs.len() - first;
        assert!(first > second + second / 2, "diurnal shape invisible: {first} vs {second}");
    }

    #[test]
    fn bursty_windows_are_denser_than_baseline() {
        let reqs = gen(RateShape::Bursty, 0.5, 13);
        let horizon = 1000.0;
        let in_burst =
            reqs.iter().filter(|r| RateShape::in_burst(r.arrival, horizon)).count() as f64;
        let outside = reqs.len() as f64 - in_burst;
        // Burst windows cover 8% of the horizon at 8x rate: per-second
        // density inside must far exceed outside.
        let dens_in = in_burst / (horizon * BURST_FRAC);
        let dens_out = outside / (horizon * (1.0 - BURST_FRAC));
        assert!(dens_in > 3.0 * dens_out, "burst density {dens_in} vs {dens_out}");
    }

    #[test]
    fn rate_shape_labels_and_parse_roundtrip() {
        for s in [RateShape::Constant, RateShape::Diurnal, RateShape::Bursty] {
            assert_eq!(RateShape::parse(s.label()), Some(s));
        }
        assert_eq!(RateShape::parse("nope"), None);
        assert_eq!(RateShape::Constant.peak(2.0), 2.0);
        assert!(RateShape::Diurnal.peak(2.0) > 2.0);
        assert_eq!(RateShape::Bursty.peak(2.0), 2.0 * BURST_MULT);
    }
}
