//! Node mobility: waypoint-style motion models evolving node positions
//! over simulated time, and the time-varying topology derived from them.
//!
//! The paper freezes geography; the ROADMAP's named follow-up — node
//! *mobility* (moving between shield regions while alive) — lives here:
//!
//! * [`MobilityModel`] — the motion law.  [`MobilityModel::RandomWaypoint`]
//!   is the classic model (pick a waypoint, travel at constant speed,
//!   pause, repeat), with waypoints drawn inside each node's *cluster
//!   roam disc* so nodes wander across sub-cluster (shield-region)
//!   boundaries without dissolving the cluster structure.
//!   [`MobilityModel::Trace`] is a deterministic patrol: every mobile
//!   node visits a fixed sequence of offsets relative to its home
//!   position — reproducible without consuming randomness.
//! * [`MobilityState`] — per-node motion bookkeeping advanced at event-
//!   queue granularity (`EventKind::MobilityTick`).  It owns a forked RNG
//!   stream, so enabling mobility never perturbs the scheduling RNG.
//! * [`DynamicTopology`] — couples the motion process to a [`Topology`]:
//!   whenever positions advance it calls
//!   [`Topology::advance_links`], so the adjacency cache refreshes and
//!   the moved nodes' link prices reprice incrementally — O(moved·k) on
//!   the sparse link model (versus the dense reference's O(moved·n) row
//!   rewrite).  Prices are always the distance-[`attenuation`]d pricing
//!   function of the *current* positions (see [`super::link`]), so
//!   neighbor sets, transfer times and the RL agents' candidate
//!   features all follow the motion.
//!
//! Adding a motion model is local: add the variant, give it a label, an
//! `enabled` rule and a waypoint rule (`MobilityState::pick_waypoint`) —
//! the advance loop, repricing and the event wiring are model-agnostic.

use super::{Pos, Topology};
use crate::util::Rng;

// The attenuation law lives with the pricing function now (`net::link`);
// re-exported here because mobility made it famous.
pub use super::link::{attenuation, EDGE_ATTENUATION};

/// Default mobility-tick period in simulated seconds.
pub const DEFAULT_TICK_SECS: f64 = 10.0;
/// Default random-waypoint speed (m/s) and pause (s).
pub const DEFAULT_SPEED_MPS: f64 = 1.0;
pub const DEFAULT_PAUSE_SECS: f64 = 30.0;
/// Roam disc: cluster radius is scaled by this factor (so waypoints
/// cross sub-cluster boundaries) with a minimum in meters.
const ROAM_FACTOR: f64 = 1.5;
const MIN_ROAM_M: f64 = 5.0;

/// How (and whether) nodes move.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum MobilityModel {
    /// Frozen geography (the paper's setup; the default).
    #[default]
    Static,
    /// Random waypoint inside the node's cluster roam disc: travel at
    /// `speed_mps`, pause `pause_secs` on arrival, repeat.
    RandomWaypoint { speed_mps: f64, pause_secs: f64 },
    /// Deterministic patrol: each node cycles through `offsets` (meters,
    /// relative to its home position) at `speed_mps`, no pauses.
    Trace { offsets: Vec<(f64, f64)>, speed_mps: f64 },
}

impl MobilityModel {
    /// A default square patrol for `Trace` configs (`mobility = "trace"`).
    pub fn default_trace() -> MobilityModel {
        MobilityModel::Trace {
            offsets: vec![(12.0, 0.0), (12.0, 12.0), (0.0, 12.0), (0.0, 0.0)],
            speed_mps: DEFAULT_SPEED_MPS,
        }
    }

    /// Whether this model actually moves anyone.
    pub fn enabled(&self) -> bool {
        match self {
            MobilityModel::Static => false,
            MobilityModel::RandomWaypoint { speed_mps, .. } => *speed_mps > 0.0,
            MobilityModel::Trace { offsets, speed_mps } => {
                *speed_mps > 0.0 && !offsets.is_empty()
            }
        }
    }

    /// Short tag for scenario labels (`static`, `w1p30`, `t4x1-9c2e`).
    /// Speeds and pauses print un-rounded, and trace patrols carry a
    /// fingerprint of their offset sequence, so distinct sweep cells
    /// never share a label.
    pub fn label(&self) -> String {
        match self {
            MobilityModel::Static => "static".to_string(),
            MobilityModel::RandomWaypoint { speed_mps, pause_secs } => {
                format!("w{speed_mps}p{pause_secs}")
            }
            MobilityModel::Trace { offsets, speed_mps } => {
                // FNV-1a over the offset bits: length alone is ambiguous
                // (two different patrols can share a waypoint count).
                let mut h: u64 = 0xcbf29ce484222325;
                for &(x, y) in offsets {
                    for b in
                        x.to_bits().to_le_bytes().into_iter().chain(y.to_bits().to_le_bytes())
                    {
                        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
                    }
                }
                format!("t{}x{speed_mps}-{:04x}", offsets.len(), h & 0xffff)
            }
        }
    }

    fn speed_and_pause(&self) -> (f64, f64) {
        match self {
            MobilityModel::Static => (0.0, 0.0),
            MobilityModel::RandomWaypoint { speed_mps, pause_secs } => (*speed_mps, *pause_secs),
            MobilityModel::Trace { speed_mps, .. } => (*speed_mps, 0.0),
        }
    }
}

/// Per-node motion bookkeeping.
#[derive(Debug, Clone)]
struct NodeMotion {
    target: Pos,
    /// Simulated time until which the node rests at its position.
    pause_until: f64,
    /// Next trace-waypoint index (trace model only).
    next_wp: usize,
}

/// The motion process over all nodes: advanced by the event core at
/// [`DEFAULT_TICK_SECS`]-style granularity, deterministic in its own
/// forked RNG stream.
#[derive(Debug, Clone)]
pub struct MobilityState {
    model: MobilityModel,
    rng: Rng,
    /// t = 0 position per node (trace offsets are relative to these).
    homes: Vec<Pos>,
    /// Roam-disc center / radius per node (its cluster's centroid).
    roam_center: Vec<Pos>,
    roam_radius: Vec<f64>,
    /// Empty when the model is disabled.
    motion: Vec<NodeMotion>,
}

impl MobilityState {
    /// Build the motion process.  `groups` are the geographic clusters
    /// (each a member list): they define the per-node roam discs.  Nodes
    /// in no group get a degenerate disc and never move.
    pub fn new(
        topo: &Topology,
        model: MobilityModel,
        groups: &[Vec<usize>],
        rng: Rng,
    ) -> MobilityState {
        let n = topo.n();
        let homes = topo.positions.clone();
        let mut roam_center = homes.clone();
        let mut roam_radius = vec![0.0; n];
        for g in groups {
            if g.is_empty() {
                continue;
            }
            let (mut cx, mut cy) = (0.0, 0.0);
            for &m in g {
                cx += homes[m].x;
                cy += homes[m].y;
            }
            let c = Pos { x: cx / g.len() as f64, y: cy / g.len() as f64 };
            let mut r: f64 = 0.0;
            for &m in g {
                r = r.max(c.dist(&homes[m]));
            }
            let r = (r * ROAM_FACTOR).max(MIN_ROAM_M);
            for &m in g {
                roam_center[m] = c;
                roam_radius[m] = r;
            }
        }
        let mut st =
            MobilityState { model, rng, homes, roam_center, roam_radius, motion: Vec::new() };
        if st.enabled() {
            st.motion = (0..n)
                .map(|_| NodeMotion {
                    target: Pos { x: 0.0, y: 0.0 },
                    pause_until: 0.0,
                    next_wp: 0,
                })
                .collect();
            // Initial waypoints, in node-id order (determinism).
            for i in 0..n {
                let wp = st.pick_waypoint(i);
                st.motion[i].target = wp;
            }
        }
        st
    }

    pub fn enabled(&self) -> bool {
        self.model.enabled()
    }

    pub fn model(&self) -> &MobilityModel {
        &self.model
    }

    /// Next waypoint of node `i` under the model.
    fn pick_waypoint(&mut self, i: usize) -> Pos {
        match &self.model {
            MobilityModel::Static => self.homes[i],
            MobilityModel::RandomWaypoint { .. } => {
                let ang = self.rng.range_f64(0.0, std::f64::consts::TAU);
                let r = self.roam_radius[i] * self.rng.f64().sqrt();
                Pos {
                    x: self.roam_center[i].x + r * ang.cos(),
                    y: self.roam_center[i].y + r * ang.sin(),
                }
            }
            MobilityModel::Trace { offsets, .. } => {
                if offsets.is_empty() {
                    return self.homes[i];
                }
                let k = self.motion[i].next_wp % offsets.len();
                self.motion[i].next_wp = (k + 1) % offsets.len();
                let (ox, oy) = offsets[k];
                Pos { x: self.homes[i].x + ox, y: self.homes[i].y + oy }
            }
        }
    }

    /// Advance the motion over the interval `[now - dt, now]`, mutating
    /// `positions` in place.  Returns the ids of nodes that moved,
    /// ascending.  The caller owns cache invalidation (adjacency,
    /// bandwidth repricing) — [`DynamicTopology::advance`] bundles it.
    pub fn advance(&mut self, now: f64, dt: f64, positions: &mut [Pos]) -> Vec<usize> {
        let (speed, pause) = self.model.speed_and_pause();
        if speed <= 0.0 || self.motion.is_empty() || dt <= 0.0 {
            return Vec::new();
        }
        let mut moved = Vec::new();
        for i in 0..positions.len() {
            let start = positions[i];
            let mut t = now - dt;
            while t < now - 1e-9 {
                if t < self.motion[i].pause_until {
                    t = self.motion[i].pause_until.min(now);
                    continue;
                }
                let p = positions[i];
                let target = self.motion[i].target;
                let dx = target.x - p.x;
                let dy = target.y - p.y;
                let dist = (dx * dx + dy * dy).sqrt();
                if dist <= 1e-9 {
                    // Arrived: rest, then head for the next waypoint.
                    self.motion[i].pause_until = t + pause;
                    let wp = self.pick_waypoint(i);
                    self.motion[i].target = wp;
                    if pause <= 0.0 && wp.dist(&p) <= 1e-9 {
                        // Degenerate zero-length leg (e.g. a one-point
                        // trace): nothing left to do this tick.
                        break;
                    }
                    continue;
                }
                let travel = speed * (now - t);
                if travel >= dist {
                    positions[i] = target;
                    t += dist / speed;
                } else {
                    let f = travel / dist;
                    positions[i] = Pos { x: p.x + dx * f, y: p.y + dy * f };
                    t = now;
                }
            }
            if start.dist(&positions[i]) > 1e-12 {
                moved.push(i);
            }
        }
        moved
    }
}

/// Time-varying topology: the motion process coupled to a [`Topology`]
/// whose position-derived state (link prices, adjacency cache) it keeps
/// consistent with the current positions.
///
/// Since the sparse link model, this type carries *no* link state of its
/// own: prices are always the pricing function of the current positions
/// (`net::link`), so "repricing" a mobility tick reduces to
/// [`Topology::advance_links`] — O(moved·k) cache invalidation on the
/// sparse model instead of the seed's O(moved·n) matrix rewrite.
#[derive(Debug, Clone)]
pub struct DynamicTopology {
    pub motion: MobilityState,
}

impl DynamicTopology {
    /// Couple `topo` to a motion process.  Construction mutates nothing
    /// — link prices already reflect the current positions (the sparse
    /// model prices on demand), so unlike the matrix era no initial
    /// repricing pass is needed.
    pub fn new(
        topo: &Topology,
        model: MobilityModel,
        groups: &[Vec<usize>],
        rng: Rng,
    ) -> DynamicTopology {
        let motion = MobilityState::new(topo, model, groups, rng);
        DynamicTopology { motion }
    }

    /// Advance the motion over `[now - dt, now]` and refresh every
    /// position-derived structure of `topo` (adjacency cache, moved
    /// nodes' link prices).  Returns the moved node ids, ascending.
    pub fn advance(&mut self, now: f64, dt: f64, topo: &mut Topology) -> Vec<usize> {
        let moved = self.motion.advance(now, dt, &mut topo.positions);
        if !moved.is_empty() {
            topo.advance_links(&moved);
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_topo(n: usize) -> Topology {
        let mut rng = Rng::new(5);
        Topology::generate_clustered(&mut rng, n, 5, 10.0, 30.0, &[100.0], 0.001)
    }

    fn groups(n: usize, cs: usize) -> Vec<Vec<usize>> {
        (0..n.div_ceil(cs)).map(|c| ((c * cs)..n.min((c + 1) * cs)).collect()).collect()
    }

    fn rwp(speed: f64, pause: f64) -> MobilityModel {
        MobilityModel::RandomWaypoint { speed_mps: speed, pause_secs: pause }
    }

    #[test]
    fn attenuation_bounds_and_shape() {
        assert_eq!(attenuation(0.0, 40.0), 1.0);
        assert_eq!(attenuation(20.0, 40.0), 1.0);
        assert_eq!(attenuation(40.0, 40.0), EDGE_ATTENUATION);
        assert_eq!(attenuation(400.0, 40.0), EDGE_ATTENUATION);
        let mid = attenuation(30.0, 40.0);
        assert!(mid < 1.0 && mid > EDGE_ATTENUATION);
        // Monotone non-increasing in distance.
        let mut prev = 1.0;
        for k in 0..50 {
            let a = attenuation(k as f64, 40.0);
            assert!(a <= prev + 1e-12);
            prev = a;
        }
        // Degenerate range never divides by zero.
        assert_eq!(attenuation(10.0, 0.0), 1.0);
    }

    #[test]
    fn static_model_never_moves() {
        let topo = grid_topo(10);
        let mut st = MobilityState::new(&topo, MobilityModel::Static, &groups(10, 5), Rng::new(1));
        let mut pos = topo.positions.clone();
        for tick in 1..10 {
            assert!(st.advance(tick as f64 * 10.0, 10.0, &mut pos).is_empty());
        }
        assert_eq!(pos, topo.positions);
        assert!(!st.enabled());
        // Zero speed is equally disabled.
        assert!(!rwp(0.0, 10.0).enabled());
    }

    #[test]
    fn random_waypoint_moves_and_is_deterministic() {
        let topo = grid_topo(10);
        let g = groups(10, 5);
        let run = || {
            let mut st = MobilityState::new(&topo, rwp(2.0, 0.0), &g, Rng::new(7));
            let mut pos = topo.positions.clone();
            let mut total_moved = 0usize;
            for tick in 1..=20 {
                let moved = st.advance(tick as f64 * 10.0, 10.0, &mut pos);
                assert!(moved.windows(2).all(|w| w[0] < w[1]), "moved list not ascending");
                total_moved += moved.len();
            }
            (pos, total_moved)
        };
        let (a, ma) = run();
        let (b, mb) = run();
        assert_eq!(a, b, "same seed must replay the same trajectory");
        assert_eq!(ma, mb);
        assert!(ma > 0, "nobody moved in 20 ticks at 2 m/s");
        assert_ne!(a, topo.positions);
    }

    #[test]
    fn waypoints_stay_in_cluster_roam_disc() {
        let topo = grid_topo(15);
        let g = groups(15, 5);
        let mut st = MobilityState::new(&topo, rwp(3.0, 0.0), &g, Rng::new(11));
        // Snapshot the discs before advancing (same-module test: private
        // fields are visible).
        let centers = st.roam_center.clone();
        let radii = st.roam_radius.clone();
        let mut pos = topo.positions.clone();
        for tick in 1..=50 {
            st.advance(tick as f64 * 10.0, 10.0, &mut pos);
            for i in 0..15 {
                assert!(
                    centers[i].dist(&pos[i]) <= radii[i] + 1e-6,
                    "node {i} escaped its roam disc at tick {tick}"
                );
            }
        }
    }

    #[test]
    fn displacement_bounded_by_speed() {
        let topo = grid_topo(10);
        let mut st = MobilityState::new(&topo, rwp(1.5, 0.0), &groups(10, 5), Rng::new(3));
        let mut pos = topo.positions.clone();
        for tick in 1..=10 {
            let before = pos.clone();
            st.advance(tick as f64 * 10.0, 10.0, &mut pos);
            for i in 0..10 {
                assert!(
                    before[i].dist(&pos[i]) <= 1.5 * 10.0 + 1e-6,
                    "node {i} outran its speed"
                );
            }
        }
    }

    #[test]
    fn pause_delays_departure() {
        let topo = grid_topo(5);
        // Huge pause: after reaching the first waypoint nodes freeze.
        let mut st = MobilityState::new(&topo, rwp(100.0, 1e9), &groups(5, 5), Rng::new(9));
        let mut pos = topo.positions.clone();
        st.advance(10.0, 10.0, &mut pos); // everyone reaches waypoint 1
        let settled = pos.clone();
        for tick in 2..=10 {
            st.advance(tick as f64 * 10.0, 10.0, &mut pos);
        }
        assert_eq!(pos, settled, "paused nodes must not move");
    }

    #[test]
    fn trace_model_patrols_deterministically() {
        // One node at home (0,0), square patrol, speed exactly one leg
        // per tick: the trajectory is the waypoint cycle itself.
        let topo = Topology::from_parts(
            vec![Pos { x: 0.0, y: 0.0 }],
            30.0,
            crate::net::LinkParams::uniform(1, 100.0, 0.0),
        );
        let model = MobilityModel::Trace {
            offsets: vec![(10.0, 0.0), (10.0, 10.0), (0.0, 10.0), (0.0, 0.0)],
            speed_mps: 1.0,
        };
        let mut st = MobilityState::new(&topo, model, &[vec![0]], Rng::new(1));
        let mut pos = topo.positions.clone();
        let expect = [
            Pos { x: 10.0, y: 0.0 },
            Pos { x: 10.0, y: 10.0 },
            Pos { x: 0.0, y: 10.0 },
            Pos { x: 0.0, y: 0.0 },
            Pos { x: 10.0, y: 0.0 },
        ];
        for (k, want) in expect.iter().enumerate() {
            let now = (k as f64 + 1.0) * 10.0;
            let moved = st.advance(now, 10.0, &mut pos);
            assert_eq!(moved, vec![0], "leg {k}");
            assert!(pos[0].dist(want) < 1e-9, "leg {k}: at {:?}, want {:?}", pos[0], want);
        }
    }

    #[test]
    fn dynamic_topology_repricing_follows_distance() {
        let mut topo = grid_topo(10);
        let g = groups(10, 5);
        let mut dt = DynamicTopology::new(&topo, rwp(3.0, 0.0), &g, Rng::new(21));
        for tick in 1..=30 {
            dt.advance(tick as f64 * 10.0, 10.0, &mut topo);
        }
        for i in 0..10 {
            for j in 0..10 {
                if i == j {
                    continue;
                }
                // Symmetric, bounded by the base rate, floored at the
                // edge attenuation, and exactly the attenuation law.
                let bw = topo.bandwidth(i, j);
                let base = topo.params.rate[i].min(topo.params.rate[j]);
                assert_eq!(bw, topo.bandwidth(j, i));
                assert!(bw <= base + 1e-9);
                assert!(bw >= base * EDGE_ATTENUATION - 1e-9);
                let att = attenuation(topo.positions[i].dist(&topo.positions[j]), topo.range);
                assert!((bw - base * att).abs() < 1e-9, "({i},{j})");
            }
            // Adjacency cache is in sync with the moved positions.
            assert_eq!(topo.neighbors(i), topo.neighbors_scan(i));
        }
    }

    #[test]
    fn sparse_prices_never_stale_across_100_ticks() {
        // The satellite regression: across ≥100 mobility ticks, the
        // sparse cache must never serve a stale price — every read
        // equals the pure pricing function of the *current* positions,
        // and the dense reference (advanced through the identical
        // motion) agrees bit-for-bit, transfer times included.
        let mut sparse = grid_topo(30);
        let mut dense = sparse.clone();
        dense.use_dense_links();
        assert!(dense.is_dense() && !sparse.is_dense());
        let g = groups(30, 5);
        let mut dyn_s = DynamicTopology::new(&sparse, rwp(3.0, 10.0), &g, Rng::new(0xca5e));
        let mut dyn_d = DynamicTopology::new(&dense, rwp(3.0, 10.0), &g, Rng::new(0xca5e));
        let mut qrng = Rng::new(0x9e11);
        let mut moved_total = 0usize;
        for tick in 1..=120 {
            let now = tick as f64 * 10.0;
            let ms = dyn_s.advance(now, 10.0, &mut sparse);
            let md = dyn_d.advance(now, 10.0, &mut dense);
            assert_eq!(ms, md, "tick {tick}: motion diverged");
            moved_total += ms.len();
            for _ in 0..40 {
                let i = qrng.below(30);
                let j = qrng.below(30);
                let want = if i == j {
                    (f64::INFINITY, 0.0)
                } else {
                    crate::net::link::price(&sparse.params, &sparse.positions, sparse.range, i, j)
                };
                assert_eq!(sparse.link_price(i, j), want, "tick {tick}: sparse stale ({i},{j})");
                assert_eq!(dense.link_price(i, j), want, "tick {tick}: dense stale ({i},{j})");
                assert_eq!(
                    sparse.transfer_secs(i, j, 12.5, 2),
                    dense.transfer_secs(i, j, 12.5, 2),
                    "tick {tick}: transfer diverged ({i},{j})"
                );
            }
        }
        assert!(moved_total > 0, "vacuous: nothing moved in 120 ticks");
    }

    #[test]
    fn grid_neighbor_queries_match_scan_under_random_waypoint_motion() {
        // The spatial grid backs adjacency rebuilds and radius queries on
        // the mobility tick path: across a random-waypoint trajectory,
        // both must stay pinned to the O(n²) scan references after every
        // advance.
        let mut topo = grid_topo(30);
        let g = groups(30, 5);
        let mut dyn_topo = DynamicTopology::new(&topo, rwp(3.0, 0.0), &g, Rng::new(0x6e1d));
        let mut qrng = Rng::new(0x717);
        let mut within = Vec::new();
        let mut moved_total = 0usize;
        for tick in 1..=40 {
            moved_total += dyn_topo.advance(tick as f64 * 10.0, 10.0, &mut topo).len();
            assert_eq!(topo.adjacency_scan(), {
                let mut lists = Vec::with_capacity(topo.n());
                for i in 0..topo.n() {
                    lists.push(topo.neighbors(i));
                }
                lists
            });
            for _ in 0..5 {
                let center = qrng.below(30);
                let r = [0.0, 8.0, 25.0, 200.0][qrng.below(4)];
                topo.nodes_within_into(center, r, &mut within);
                assert_eq!(within, topo.nodes_within_scan(center, r), "tick {tick} r {r}");
            }
        }
        assert!(moved_total > 0, "vacuous: nothing moved");
    }

    #[test]
    fn model_labels_are_distinct() {
        let cells = [
            MobilityModel::Static,
            rwp(0.5, 0.0),
            rwp(0.5, 30.0),
            rwp(2.0, 30.0),
            MobilityModel::default_trace(),
            // Same waypoint count and speed as default_trace, different
            // offsets: the patrol fingerprint must keep them apart.
            MobilityModel::Trace {
                offsets: vec![(5.0, 0.0), (5.0, 5.0), (0.0, 5.0), (0.0, 0.0)],
                speed_mps: DEFAULT_SPEED_MPS,
            },
            MobilityModel::Trace { offsets: vec![(5.0, 0.0)], speed_mps: 1.0 },
            MobilityModel::Trace { offsets: vec![(25.0, 0.0)], speed_mps: 1.0 },
        ];
        let mut labels: Vec<String> = cells.iter().map(|m| m.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), cells.len(), "{labels:?}");
    }
}
