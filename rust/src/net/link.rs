//! Sparse on-demand link pricing — the model that broke the 1000-node
//! ceiling.
//!
//! The seed carried *dense* pairwise `bw`/`latency` matrices: O(n²)
//! memory, O(n²) RNG draws at generation time, and an O(moved·n) matrix
//! rewrite on every mobility tick.  At 10 000 nodes that is 1.6 GB of
//! matrices before the first decision fires.  This module replaces the
//! matrices with a *pricing function*: every link quality is derived on
//! demand from
//!
//! * per-node **base rates** ([`LinkParams`]: one bandwidth rate and one
//!   latency-jitter factor per node — O(n) state, O(n) RNG draws), and
//! * the current node **positions**, through the same distance
//!   [`attenuation`] law `DynamicTopology` has always used for mobility.
//!
//! Two interchangeable backends implement the price store:
//!
//! * [`SparseLinks`] — the production model: a bounded per-node cache
//!   holding exactly the priced [`SpatialGrid`](super::SpatialGrid)
//!   adjacency rows, so only O(n·k) links are ever materialized.  Reads
//!   off the cached adjacency are an L1-resident binary search; reads of
//!   non-adjacent pairs compute the pure pricing function on the fly
//!   (no mutation — [`Topology`](super::Topology) stays `Sync`).
//!   Repricing after motion is O(moved·k): moved rows rebuild, reverse
//!   entries refresh in place, and per-node *epochs* lazily invalidate
//!   whatever cross entries remain.
//! * [`DenseLinks`] — the dense reference: full matrices materialized
//!   from the *same* pricing function.  It exists so the sparse fast
//!   path stays pinned to a bit-identical baseline (randomized
//!   equivalence tests in `net`, harness-level `RunMetrics` equivalence,
//!   and the `benches/hotpath.rs` sparse-vs-dense cells) — the same
//!   discipline as `shield::reference` and the `*_scan` topology
//!   baselines.
//!
//! Both backends price a pair `(i, j)` as
//!
//! ```text
//! base_bw(i,j)  = min(rate[i], rate[j])                 (bottleneck NIC)
//! base_lat(i,j) = latency_s · (jitter[i] + jitter[j])/2
//! bw(i,j)  = base_bw(i,j)  · attenuation(dist(i,j), range)
//! lat(i,j) = base_lat(i,j) / attenuation(dist(i,j), range)
//! ```
//!
//! which is symmetric by construction, and — because the dense matrices
//! are filled by calling the very same [`price`] function — sparse and
//! dense reads return bit-identical `f64`s.

use super::Pos;
use crate::util::Rng;

/// Bandwidth multiplier at exactly the transmission range; beyond the
/// range the link floors here (reachable but slow) instead of vanishing.
pub const EDGE_ATTENUATION: f64 = 0.25;

/// Distance attenuation of link quality: full bandwidth up to half the
/// transmission range, linear roll-off to [`EDGE_ATTENUATION`] at the
/// range, floored beyond it.  Latency scales inversely.
pub fn attenuation(dist: f64, range: f64) -> f64 {
    if range <= 0.0 {
        return 1.0;
    }
    let d = dist / range;
    if d <= 0.5 {
        1.0
    } else if d >= 1.0 {
        EDGE_ATTENUATION
    } else {
        1.0 - (1.0 - EDGE_ATTENUATION) * (d - 0.5) / 0.5
    }
}

/// Per-node link parameters: the O(n) state every pair price derives
/// from.  Replaces the seed's O(n²) base matrices.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkParams {
    /// Per-node base link rate in Mbps (sampled from the profile's
    /// `bw_choices`); a pair's base bandwidth is the min of its ends.
    pub rate: Vec<f64>,
    /// Per-node latency jitter factor in [0.5, 1.5); a pair's base
    /// latency is `latency_s` scaled by the mean of its ends.
    pub jitter: Vec<f64>,
    /// Base one-way control-message latency in seconds.
    pub latency_s: f64,
}

impl LinkParams {
    /// Sample per-node rates and jitters — 2n draws in node-id order
    /// (the dense seed drew O(n²); generation is now linear).
    pub fn generate(rng: &mut Rng, n: usize, bw_choices: &[f64], latency_s: f64) -> LinkParams {
        let rate = (0..n).map(|_| *rng.choose(bw_choices)).collect();
        let jitter = (0..n).map(|_| rng.range_f64(0.5, 1.5)).collect();
        LinkParams { rate, jitter, latency_s }
    }

    /// Uniform parameters (tests / hand-built topologies): every node
    /// gets the same `rate` and a jitter of exactly 1.0, so every pair
    /// prices to `rate · att` and `latency_s / att`.
    pub fn uniform(n: usize, rate: f64, latency_s: f64) -> LinkParams {
        LinkParams { rate: vec![rate; n], jitter: vec![1.0; n], latency_s }
    }

    pub fn n(&self) -> usize {
        self.rate.len()
    }
}

/// Pure pricing function: `(bandwidth Mbps, one-way latency s)` of the
/// link `(i, j)` at the current positions.  The single source of truth —
/// the sparse cache, the dense matrices and every on-the-fly read all
/// evaluate exactly this, so all paths agree bit-for-bit.
#[inline]
pub fn price(params: &LinkParams, positions: &[Pos], range: f64, i: usize, j: usize) -> (f64, f64) {
    if i == j {
        return (f64::INFINITY, 0.0);
    }
    let att = attenuation(positions[i].dist(&positions[j]), range);
    let bw = params.rate[i].min(params.rate[j]) * att;
    let lat = params.latency_s * 0.5 * (params.jitter[i] + params.jitter[j]) / att;
    (bw, lat)
}

/// One cached link price in a node's row.
#[derive(Debug, Clone, Copy)]
struct CacheEntry {
    peer: u32,
    /// `epoch[peer]` at pricing time: the entry self-invalidates when
    /// the peer moves (its epoch bumps) before this row is refreshed.
    peer_epoch: u32,
    bw: f64,
    lat: f64,
}

/// Sparse link store: per-node rows of priced links, bounded by (and
/// keyed on) the spatial-grid adjacency, so at most O(n·k) links are
/// ever materialized.
///
/// Invariant (inherited from the adjacency cache): whoever mutates
/// `positions` calls [`Topology::rebuild_adjacency`](super::Topology::rebuild_adjacency)
/// (full refresh) or [`Topology::reprice_moved`](super::Topology::reprice_moved)
/// (O(moved·k) incremental path) before reading prices.  Rows of nodes
/// that did not move stay valid; their entries pointing *at* movers are
/// caught by the epoch check and re-priced on the fly.
#[derive(Debug, Clone, Default)]
pub struct SparseLinks {
    /// Position epoch per node, bumped by [`SparseLinks::reprice_moved`].
    epoch: Vec<u32>,
    /// Per-node cached rows, ascending by peer id (binary-searchable).
    rows: Vec<Vec<CacheEntry>>,
}

impl SparseLinks {
    /// Rebuild every row from the adjacency lists — O(n·k).  Called by
    /// the generators and the full `rebuild_adjacency` hook.
    pub fn refresh_all(
        &mut self,
        params: &LinkParams,
        positions: &[Pos],
        range: f64,
        adjacency: &[Vec<usize>],
    ) {
        let n = positions.len();
        self.epoch.resize(n, 0);
        self.rows.resize_with(n, Vec::new);
        for i in 0..n {
            let mut row = std::mem::take(&mut self.rows[i]);
            row.clear();
            row.extend(adjacency[i].iter().map(|&j| {
                let (bw, lat) = price(params, positions, range, i, j);
                CacheEntry { peer: j as u32, peer_epoch: self.epoch[j], bw, lat }
            }));
            self.rows[i] = row;
        }
    }

    /// Incremental reprice after `moved` nodes changed position —
    /// O(moved·k): bump each mover's epoch (lazily invalidating every
    /// cross entry that points at it), rebuild the movers' own rows from
    /// the already-refreshed adjacency, and refresh reverse entries in
    /// place where they exist.
    pub fn reprice_moved(
        &mut self,
        params: &LinkParams,
        positions: &[Pos],
        range: f64,
        adjacency: &[Vec<usize>],
        moved: &[usize],
    ) {
        for &i in moved {
            self.epoch[i] = self.epoch[i].wrapping_add(1);
        }
        for &i in moved {
            let mut row = std::mem::take(&mut self.rows[i]);
            row.clear();
            for &j in &adjacency[i] {
                // Price each mover-neighbor pair once (the function is
                // symmetric): fill the mover's row and refresh the
                // reverse entry in place where one exists (binary
                // search, no insertion shifts).  Pairs with no reverse
                // entry fall back to the pure compute on read.
                let (bw, lat) = price(params, positions, range, i, j);
                row.push(CacheEntry { peer: j as u32, peer_epoch: self.epoch[j], bw, lat });
                if let Ok(pos) = self.rows[j].binary_search_by_key(&(i as u32), |e| e.peer) {
                    self.rows[j][pos] =
                        CacheEntry { peer: i as u32, peer_epoch: self.epoch[i], bw, lat };
                }
            }
            self.rows[i] = row;
        }
    }

    /// Price of link `(i, j)`: cached-row hit when the entry is present
    /// and its peer epoch is current, pure compute otherwise.  `&self` —
    /// misses never mutate, so concurrent scenario threads can read
    /// freely.
    #[inline]
    pub fn link(
        &self,
        params: &LinkParams,
        positions: &[Pos],
        range: f64,
        i: usize,
        j: usize,
    ) -> (f64, f64) {
        if i == j {
            return (f64::INFINITY, 0.0);
        }
        if let Ok(pos) = self.rows[i].binary_search_by_key(&(j as u32), |e| e.peer) {
            let e = self.rows[i][pos];
            if e.peer_epoch == self.epoch[j] {
                return (e.bw, e.lat);
            }
        }
        price(params, positions, range, i, j)
    }

    /// Total cached entries (diagnostics / the O(n·k) bound tests).
    pub fn cached_links(&self) -> usize {
        self.rows.iter().map(Vec::len).sum()
    }

    /// Fault injection (tests): overwrite — or insert — the cached
    /// bandwidth of `(i, j)` in `i`'s row with current epochs, so the
    /// poisoned value is what reads actually serve.
    pub fn poison_bw(&mut self, i: usize, j: usize, bw: f64, lat: f64) {
        let entry = CacheEntry { peer: j as u32, peer_epoch: self.epoch[j], bw, lat };
        match self.rows[i].binary_search_by_key(&(j as u32), |e| e.peer) {
            Ok(pos) => self.rows[i][pos] = entry,
            Err(pos) => self.rows[i].insert(pos, entry),
        }
    }
}

/// Dense reference store: full matrices materialized from [`price`].
/// O(n²) memory and O(moved·n) repricing — kept in-tree only as the
/// equivalence baseline the sparse model is pinned against.
#[derive(Debug, Clone, Default)]
pub struct DenseLinks {
    pub bw: Vec<Vec<f64>>,
    pub latency: Vec<Vec<f64>>,
}

impl DenseLinks {
    /// Materialize every pair — O(n²).
    pub fn refresh_all(&mut self, params: &LinkParams, positions: &[Pos], range: f64) {
        let n = positions.len();
        self.bw = vec![vec![0.0; n]; n];
        self.latency = vec![vec![0.0; n]; n];
        for i in 0..n {
            self.bw[i][i] = f64::INFINITY;
            for j in (i + 1)..n {
                let (bw, lat) = price(params, positions, range, i, j);
                self.bw[i][j] = bw;
                self.bw[j][i] = bw;
                self.latency[i][j] = lat;
                self.latency[j][i] = lat;
            }
        }
    }

    /// The seed's repricing shape: rewrite the full rows of every moved
    /// node — O(moved·n).
    pub fn reprice_moved(
        &mut self,
        params: &LinkParams,
        positions: &[Pos],
        range: f64,
        moved: &[usize],
    ) {
        let n = positions.len();
        for &i in moved {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let (bw, lat) = price(params, positions, range, i, j);
                self.bw[i][j] = bw;
                self.bw[j][i] = bw;
                self.latency[i][j] = lat;
                self.latency[j][i] = lat;
            }
        }
    }

    #[inline]
    pub fn link(&self, i: usize, j: usize) -> (f64, f64) {
        if i == j {
            return (f64::INFINITY, 0.0);
        }
        (self.bw[i][j], self.latency[i][j])
    }

    pub fn poison_bw(&mut self, i: usize, j: usize, bw: f64) {
        self.bw[i][j] = bw;
        self.bw[j][i] = bw;
    }
}

/// The link store behind a [`Topology`](super::Topology): sparse
/// on-demand pricing (production) or the dense materialized reference.
#[derive(Debug, Clone)]
pub enum LinkModel {
    Sparse(SparseLinks),
    Dense(DenseLinks),
}

impl LinkModel {
    pub fn is_dense(&self) -> bool {
        matches!(self, LinkModel::Dense(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize, seed: u64) -> (LinkParams, Vec<Pos>) {
        let mut rng = Rng::new(seed);
        let params = LinkParams::generate(&mut rng, n, &[50.0, 100.0, 500.0], 0.002);
        let positions = (0..n)
            .map(|_| Pos { x: rng.range_f64(0.0, 60.0), y: rng.range_f64(0.0, 60.0) })
            .collect();
        (params, positions)
    }

    fn adjacency(positions: &[Pos], range: f64) -> Vec<Vec<usize>> {
        (0..positions.len())
            .map(|i| {
                (0..positions.len())
                    .filter(|&j| j != i && positions[i].dist(&positions[j]) <= range)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn price_is_symmetric_and_bounded() {
        let (params, positions) = setup(20, 1);
        for i in 0..20 {
            for j in 0..20 {
                let (bw, lat) = price(&params, &positions, 30.0, i, j);
                let (bw2, lat2) = price(&params, &positions, 30.0, j, i);
                assert_eq!(bw, bw2, "({i},{j})");
                assert_eq!(lat, lat2);
                if i == j {
                    assert_eq!(bw, f64::INFINITY);
                    assert_eq!(lat, 0.0);
                } else {
                    let base = params.rate[i].min(params.rate[j]);
                    assert!(bw <= base + 1e-12);
                    assert!(bw >= base * EDGE_ATTENUATION - 1e-12);
                    assert!(lat > 0.0);
                }
            }
        }
    }

    #[test]
    fn sparse_and_dense_agree_bitwise() {
        let (params, positions) = setup(25, 7);
        let adj = adjacency(&positions, 30.0);
        let mut sparse = SparseLinks::default();
        sparse.refresh_all(&params, &positions, 30.0, &adj);
        let mut dense = DenseLinks::default();
        dense.refresh_all(&params, &positions, 30.0);
        for i in 0..25 {
            for j in 0..25 {
                assert_eq!(
                    sparse.link(&params, &positions, 30.0, i, j),
                    dense.link(i, j),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn reprice_moved_matches_full_refresh() {
        let (params, mut positions) = setup(30, 13);
        let mut rng = Rng::new(99);
        let mut sparse = SparseLinks::default();
        let mut dense = DenseLinks::default();
        let adj0 = adjacency(&positions, 25.0);
        sparse.refresh_all(&params, &positions, 25.0, &adj0);
        dense.refresh_all(&params, &positions, 25.0);
        for round in 0..20 {
            // Move a random subset, rebuild adjacency, reprice both
            // models incrementally, and pin every pair to a fresh
            // from-scratch pricing.
            let mut moved: Vec<usize> = (0..30).filter(|_| rng.chance(0.3)).collect();
            if moved.is_empty() {
                moved.push(rng.below(30));
            }
            for &i in &moved {
                positions[i] = Pos { x: rng.range_f64(0.0, 60.0), y: rng.range_f64(0.0, 60.0) };
            }
            let adj = adjacency(&positions, 25.0);
            sparse.reprice_moved(&params, &positions, 25.0, &adj, &moved);
            dense.reprice_moved(&params, &positions, 25.0, &moved);
            for i in 0..30 {
                for j in 0..30 {
                    let want = if i == j {
                        (f64::INFINITY, 0.0)
                    } else {
                        price(&params, &positions, 25.0, i, j)
                    };
                    assert_eq!(
                        sparse.link(&params, &positions, 25.0, i, j),
                        want,
                        "sparse stale at round {round} ({i},{j})"
                    );
                    assert_eq!(dense.link(i, j), want, "dense stale at round {round} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn cache_is_bounded_by_adjacency() {
        let (params, positions) = setup(40, 3);
        let adj = adjacency(&positions, 20.0);
        let mut sparse = SparseLinks::default();
        sparse.refresh_all(&params, &positions, 20.0, &adj);
        let degree_total: usize = adj.iter().map(Vec::len).sum();
        assert_eq!(sparse.cached_links(), degree_total);
        assert!(degree_total < 40 * 40, "adjacency itself must be sparse here");
    }

    #[test]
    fn uniform_params_price_flat() {
        let params = LinkParams::uniform(4, 200.0, 0.001);
        let positions = vec![Pos { x: 0.0, y: 0.0 }; 4];
        let (bw, lat) = price(&params, &positions, 30.0, 0, 3);
        assert_eq!(bw, 200.0);
        assert_eq!(lat, 0.001);
    }
}
