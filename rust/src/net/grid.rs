//! Uniform spatial-hash grid over node positions — the sub-quadratic
//! backend for every "who is near this point" query on the per-tick hot
//! path.
//!
//! [`Topology::rebuild_adjacency`](super::Topology::rebuild_adjacency)
//! used to be an O(n²) all-pairs distance scan per tick; binning the
//! positions into range-sized square cells makes each node's neighbor
//! query an O(k) walk over the 3×3 cells around it, so a full rebuild is
//! O(n·k).  The same structure answers the blast-radius victim queries
//! of `coordinator::dynamic` for arbitrary radii.  The scan
//! implementations stay in `net::mod` as references, pinned by
//! randomized equivalence tests (mirroring the `shield::reference`
//! pattern).
//!
//! Correctness does not depend on the cell size: a query for radius `r`
//! visits every cell whose index range covers `[center − r, center + r]`
//! (cell indexing is monotone in the coordinate and clamped at the grid
//! edge, so any point within `r` lands inside the visited range) and
//! re-checks the exact [`Pos::dist`] predicate the scan baseline uses.
//! The cell table is bounded at O(n) cells, so a single far-flung
//! outlier (a teleported test node) cannot blow up the allocation — it
//! just coarsens the effective cells.

use super::Pos;

/// Square-cell spatial hash in CSR layout.
#[derive(Debug, Clone)]
pub struct SpatialGrid {
    min_x: f64,
    min_y: f64,
    /// Cell side length in meters.
    cell: f64,
    nx: usize,
    ny: usize,
    /// CSR cell contents: the node ids of cell `c` are
    /// `items[starts[c]..starts[c + 1]]` (ascending: nodes are binned in
    /// id order).
    starts: Vec<usize>,
    items: Vec<usize>,
}

impl SpatialGrid {
    /// Bin `positions` into square cells of side `cell`.  Degenerate
    /// cell sizes (zero, negative, NaN, infinite) fall back to 1 m so
    /// construction never divides by zero.
    pub fn build(positions: &[Pos], cell: f64) -> SpatialGrid {
        let mut grid = SpatialGrid {
            min_x: 0.0,
            min_y: 0.0,
            cell: 1.0,
            nx: 1,
            ny: 1,
            starts: vec![0, 0],
            items: Vec::new(),
        };
        grid.rebuild(positions, cell);
        grid
    }

    /// Re-bin `positions` in place, reusing the CSR buffers — the
    /// steady-state mobility tick rebuilds the grid without allocating
    /// once the buffers have warmed up.  Semantics identical to
    /// [`SpatialGrid::build`].
    pub fn rebuild(&mut self, positions: &[Pos], cell: f64) {
        let cell = if cell.is_finite() && cell > 0.0 { cell } else { 1.0 };
        let n = positions.len();
        if n == 0 {
            self.min_x = 0.0;
            self.min_y = 0.0;
            self.cell = cell;
            self.nx = 1;
            self.ny = 1;
            self.starts.clear();
            self.starts.resize(2, 0);
            self.items.clear();
            return;
        }
        let mut min_x = f64::INFINITY;
        let mut min_y = f64::INFINITY;
        let mut max_x = f64::NEG_INFINITY;
        let mut max_y = f64::NEG_INFINITY;
        for p in positions {
            min_x = min_x.min(p.x);
            min_y = min_y.min(p.y);
            max_x = max_x.max(p.x);
            max_y = max_y.max(p.y);
        }
        // Bound the dense cell table at ≤ 4n + 64 cells (floor of the
        // square root per axis): outliers clamp into the edge cells
        // instead of inflating the table.
        let cap = (((4 * n + 64) as f64).sqrt() as usize).max(1);
        let span_cells = |span: f64| -> usize {
            let c = (span / cell).floor();
            if c.is_finite() && c >= 0.0 {
                // Clamp before the +1: a pathological span must not
                // overflow the cell count (`as usize` saturates).
                (c as usize).min(cap - 1) + 1
            } else {
                1
            }
        };
        self.min_x = min_x;
        self.min_y = min_y;
        self.cell = cell;
        self.nx = span_cells(max_x - min_x);
        self.ny = span_cells(max_y - min_y);
        let ncells = self.nx * self.ny;

        // Counting sort into CSR, with `starts` doubling as the fill
        // cursor (no temporary count/cursor vectors): count into
        // starts[c + 1], prefix-sum, fill advancing starts[c], then
        // shift starts back one slot.  Filling in node-id order keeps
        // each cell's id list ascending.
        self.starts.clear();
        self.starts.resize(ncells + 1, 0);
        self.items.clear();
        self.items.resize(n, 0);
        for p in positions {
            let c = self.cell_of(*p);
            self.starts[c + 1] += 1;
        }
        for c in 0..ncells {
            self.starts[c + 1] += self.starts[c];
        }
        for (id, p) in positions.iter().enumerate() {
            let c = self.cell_of(*p);
            let slot = self.starts[c];
            self.items[slot] = id;
            self.starts[c] += 1;
        }
        // Each starts[c] now holds cell c's END offset; shift right so
        // starts[c] is the start again (starts[ncells] already == n).
        for c in (1..ncells).rev() {
            self.starts[c] = self.starts[c - 1];
        }
        self.starts[0] = 0;
    }

    /// Total cells in the table (for tests / diagnostics).
    pub fn n_cells(&self) -> usize {
        self.nx * self.ny
    }

    /// The ids binned into cell `c` (ascending — nodes are filled in id
    /// order).  Empty slice for empty cells.
    pub fn cell_items(&self, c: usize) -> &[usize] {
        &self.items[self.starts[c]..self.starts[c + 1]]
    }

    /// Iterate the *non-empty* cells in cell-index order as
    /// `(cell_index, member ids)` — the seed enumeration the grid-backed
    /// sub-cluster partitioner merges into regions.
    pub fn cells(&self) -> impl Iterator<Item = (usize, &[usize])> + '_ {
        (0..self.n_cells()).filter_map(move |c| {
            let items = self.cell_items(c);
            if items.is_empty() {
                None
            } else {
                Some((c, items))
            }
        })
    }

    /// Clamped cell index along one axis (monotone non-decreasing in
    /// the coordinate — the property the query range relies on).
    #[inline]
    fn axis_cell(&self, coord: f64, min: f64, ncells: usize) -> usize {
        let i = (coord - min) / self.cell;
        if i.is_nan() || i <= 0.0 {
            // NaN and ≤ 0 both land in the first cell.
            return 0;
        }
        (i as usize).min(ncells - 1)
    }

    #[inline]
    fn cell_of(&self, p: Pos) -> usize {
        let cx = self.axis_cell(p.x, self.min_x, self.nx);
        self.axis_cell(p.y, self.min_y, self.ny) * self.nx + cx
    }

    /// Fill `out` with every node within `r` meters of `center` — the
    /// same `dist ≤ r` predicate as the scan baselines — excluding
    /// `exclude` (pass `usize::MAX` for none), ascending by id.
    /// Clears `out` first; no allocation once the buffer has warmed up.
    ///
    /// ```
    /// use srole::net::{Pos, SpatialGrid};
    ///
    /// let positions: Vec<Pos> = (0..20).map(|i| Pos { x: i as f64 * 3.0, y: 0.0 }).collect();
    /// let grid = SpatialGrid::build(&positions, 10.0);
    /// let mut out = Vec::new();
    /// grid.within_into(&positions, positions[0], 10.0, 0, &mut out);
    /// assert_eq!(out, vec![1, 2, 3]); // 3, 6, 9 m away; 12 m is out of range
    /// ```
    pub fn within_into(
        &self,
        positions: &[Pos],
        center: Pos,
        r: f64,
        exclude: usize,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        if r < 0.0 || self.items.is_empty() {
            return;
        }
        let cx0 = self.axis_cell(center.x - r, self.min_x, self.nx);
        let cx1 = self.axis_cell(center.x + r, self.min_x, self.nx);
        let cy0 = self.axis_cell(center.y - r, self.min_y, self.ny);
        let cy1 = self.axis_cell(center.y + r, self.min_y, self.ny);
        for cy in cy0..=cy1 {
            for cx in cx0..=cx1 {
                let c = cy * self.nx + cx;
                for &j in &self.items[self.starts[c]..self.starts[c + 1]] {
                    if j != exclude && positions[j].dist(&center) <= r {
                        out.push(j);
                    }
                }
            }
        }
        // Cells are visited in geometric order; callers expect the
        // scan baselines' ascending-id order.
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Brute-force baseline: the exact predicate the grid must replay.
    fn scan(positions: &[Pos], center: Pos, r: f64, exclude: usize) -> Vec<usize> {
        (0..positions.len())
            .filter(|&j| j != exclude && positions[j].dist(&center) <= r)
            .collect()
    }

    fn random_positions(rng: &mut Rng, n: usize, side: f64) -> Vec<Pos> {
        (0..n)
            .map(|_| Pos { x: rng.range_f64(0.0, side), y: rng.range_f64(0.0, side) })
            .collect()
    }

    #[test]
    fn prop_grid_queries_match_scan() {
        // Random layouts × random query radii (including r = 0, r larger
        // than the arena, and centers off any node): the grid must
        // return exactly the scan's id list.
        let mut rng = Rng::new(0x6121D);
        let mut out = Vec::new();
        for case in 0..30usize {
            let n = 1 + rng.below(120);
            let side = [10.0, 100.0, 1000.0][case % 3];
            let positions = random_positions(&mut rng, n, side);
            let cell = [0.5, 7.0, 40.0, side * 2.0][case % 4];
            let grid = SpatialGrid::build(&positions, cell);
            for _ in 0..20 {
                let center = if rng.chance(0.5) {
                    positions[rng.below(n)]
                } else {
                    Pos { x: rng.range_f64(-side, 2.0 * side), y: rng.range_f64(-side, 2.0 * side) }
                };
                let r = [0.0, 3.0, 25.0, side, 3.0 * side][rng.below(5)];
                let exclude = if rng.chance(0.3) { rng.below(n) } else { usize::MAX };
                grid.within_into(&positions, center, r, exclude, &mut out);
                assert_eq!(out, scan(&positions, center, r, exclude), "case {case}");
            }
        }
    }

    #[test]
    fn degenerate_inputs() {
        let mut out = vec![99];
        // Empty position set.
        let g = SpatialGrid::build(&[], 10.0);
        g.within_into(&[], Pos { x: 0.0, y: 0.0 }, 5.0, usize::MAX, &mut out);
        assert!(out.is_empty(), "within_into must clear stale contents");

        // All nodes coincident; zero and negative radii.
        let positions = vec![Pos { x: 3.0, y: 4.0 }; 5];
        let g = SpatialGrid::build(&positions, 10.0);
        g.within_into(&positions, positions[0], 0.0, usize::MAX, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3, 4], "coincident nodes are within r = 0");
        g.within_into(&positions, positions[0], 0.0, 2, &mut out);
        assert_eq!(out, vec![0, 1, 3, 4]);
        g.within_into(&positions, positions[0], -1.0, usize::MAX, &mut out);
        assert!(out.is_empty(), "negative radius matches nothing");

        // Degenerate cell sizes never divide by zero.
        for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
            let g = SpatialGrid::build(&positions, bad);
            g.within_into(&positions, positions[0], 1.0, usize::MAX, &mut out);
            assert_eq!(out.len(), 5, "cell={bad}");
        }
    }

    #[test]
    fn outlier_does_not_inflate_the_table() {
        // One node teleported 1e6 m away (the mobility tests do this):
        // the cell table must stay O(n), and queries must stay exact.
        let mut rng = Rng::new(7);
        let mut positions = random_positions(&mut rng, 50, 100.0);
        positions[0] = Pos { x: 1e6, y: 1e6 };
        let grid = SpatialGrid::build(&positions, 30.0);
        assert!(grid.n_cells() <= 4 * 50 + 64, "cells = {}", grid.n_cells());
        let mut out = Vec::new();
        for i in 0..positions.len() {
            grid.within_into(&positions, positions[i], 30.0, i, &mut out);
            assert_eq!(out, scan(&positions, positions[i], 30.0, i), "node {i}");
        }
    }

    #[test]
    fn in_place_rebuild_matches_fresh_build() {
        // Rebuilding over warm buffers (shrinking, growing, degenerate)
        // must leave exactly the state a fresh build produces.
        let mut rng = Rng::new(0x2eb);
        let mut grid = SpatialGrid::build(&[], 10.0);
        let mut out_a = Vec::new();
        let mut out_b = Vec::new();
        for (n, cell) in [(60usize, 12.0), (9, 30.0), (0, 5.0), (120, 3.0), (1, 7.0)] {
            let positions = random_positions(&mut rng, n, 200.0);
            grid.rebuild(&positions, cell);
            let fresh = SpatialGrid::build(&positions, cell);
            assert_eq!(grid.starts, fresh.starts, "n={n}");
            assert_eq!(grid.items, fresh.items, "n={n}");
            assert_eq!(grid.n_cells(), fresh.n_cells(), "n={n}");
            for i in 0..n {
                grid.within_into(&positions, positions[i], cell, i, &mut out_a);
                fresh.within_into(&positions, positions[i], cell, i, &mut out_b);
                assert_eq!(out_a, out_b, "n={n} node={i}");
                assert_eq!(out_a, scan(&positions, positions[i], cell, i));
            }
        }
    }

    #[test]
    fn cell_iteration_covers_every_node_once() {
        let mut rng = Rng::new(0xce11);
        let positions = random_positions(&mut rng, 80, 150.0);
        let grid = SpatialGrid::build(&positions, 20.0);
        let mut seen: Vec<usize> = Vec::new();
        for (c, items) in grid.cells() {
            assert!(!items.is_empty(), "cells() must skip empty cells");
            assert!(c < grid.n_cells());
            assert!(items.windows(2).all(|w| w[0] < w[1]), "cell ids ascend");
            assert_eq!(items, grid.cell_items(c));
            seen.extend_from_slice(items);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..80).collect::<Vec<_>>());
    }

    #[test]
    fn build_is_deterministic() {
        let mut rng = Rng::new(11);
        let positions = random_positions(&mut rng, 40, 60.0);
        let a = SpatialGrid::build(&positions, 15.0);
        let b = SpatialGrid::build(&positions, 15.0);
        assert_eq!(a.starts, b.starts);
        assert_eq!(a.items, b.items);
        // Every node is binned exactly once.
        let mut ids = a.items.clone();
        ids.sort_unstable();
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }
}
