//! Edge-network substrate: 2-D geography, transmission ranges, and the
//! pairwise bandwidth/latency model.
//!
//! The paper's testbeds shape bandwidth with `tcconfig` (containers) and
//! `wondershaper` (Raspberry Pis); here a [`Topology`] carries an explicit
//! symmetric bandwidth matrix plus node positions.  Geographic proximity
//! drives both cluster formation (§III) and the neighbor sets that bound
//! every MARL agent's action space ("edge nodes in its transmission
//! range", §I).

use crate::util::Rng;

/// 2-D position in meters (arbitrary plane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pos {
    pub x: f64,
    pub y: f64,
}

impl Pos {
    pub fn dist(&self, other: &Pos) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }
}

/// Network topology over `n` edge nodes.
#[derive(Debug, Clone)]
pub struct Topology {
    pub positions: Vec<Pos>,
    /// Transmission range in meters: nodes within range are neighbors.
    pub range: f64,
    /// Symmetric pairwise bandwidth in Mbps (`bw[i][j]`, `bw[i][i] = inf`).
    pub bw: Vec<Vec<f64>>,
    /// One-way latency in seconds for control messages.
    pub latency: Vec<Vec<f64>>,
}

impl Topology {
    pub fn n(&self) -> usize {
        self.positions.len()
    }

    /// All nodes within transmission range of `i` (excluding `i`).
    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        (0..self.n())
            .filter(|&j| j != i && self.positions[i].dist(&self.positions[j]) <= self.range)
            .collect()
    }

    pub fn bandwidth(&self, a: usize, b: usize) -> f64 {
        if a == b {
            f64::INFINITY
        } else {
            self.bw[a][b]
        }
    }

    pub fn latency(&self, a: usize, b: usize) -> f64 {
        if a == b {
            0.0
        } else {
            self.latency[a][b]
        }
    }

    /// Transfer time in seconds for `mb` megabytes between `a` and `b`,
    /// with `flows` concurrent flows sharing the link.
    pub fn transfer_secs(&self, a: usize, b: usize, mb: f64, flows: usize) -> f64 {
        if a == b || mb <= 0.0 {
            return 0.0;
        }
        let bw = self.bandwidth(a, b) / flows.max(1) as f64; // Mbps
        self.latency(a, b) + mb * 8.0 / bw
    }

    /// Generate a topology: positions uniform in a `side`×`side` square,
    /// bandwidth sampled uniformly from `bw_choices` per unordered pair.
    pub fn generate(
        rng: &mut Rng,
        n: usize,
        side: f64,
        range: f64,
        bw_choices: &[f64],
        latency_s: f64,
    ) -> Topology {
        let positions: Vec<Pos> =
            (0..n).map(|_| Pos { x: rng.range_f64(0.0, side), y: rng.range_f64(0.0, side) }).collect();
        let mut bw = vec![vec![0.0; n]; n];
        let mut latency = vec![vec![0.0; n]; n];
        for i in 0..n {
            bw[i][i] = f64::INFINITY;
            for j in (i + 1)..n {
                let b = *rng.choose(bw_choices);
                bw[i][j] = b;
                bw[j][i] = b;
                let l = latency_s * rng.range_f64(0.5, 1.5);
                latency[i][j] = l;
                latency[j][i] = l;
            }
        }
        Topology { positions, range, bw, latency }
    }

    /// Generate positions pre-grouped into geographic clusters of
    /// `cluster_size`: each cluster gets a well-separated center and its
    /// members are placed within `spread` of it.  This mirrors the paper's
    /// "clusters of edges are created according to geographical locations".
    pub fn generate_clustered(
        rng: &mut Rng,
        n: usize,
        cluster_size: usize,
        spread: f64,
        range: f64,
        bw_choices: &[f64],
        latency_s: f64,
    ) -> Topology {
        let n_clusters = n.div_ceil(cluster_size);
        let grid = (n_clusters as f64).sqrt().ceil() as usize;
        let cell = spread * 4.0;
        let mut positions = Vec::with_capacity(n);
        for c in 0..n_clusters {
            let cx = (c % grid) as f64 * cell + cell / 2.0;
            let cy = (c / grid) as f64 * cell + cell / 2.0;
            let members = ((c * cluster_size)..n.min((c + 1) * cluster_size)).count();
            for _ in 0..members {
                let ang = rng.range_f64(0.0, std::f64::consts::TAU);
                let r = spread * rng.f64().sqrt();
                positions.push(Pos { x: cx + r * ang.cos(), y: cy + r * ang.sin() });
            }
        }
        let mut topo = Topology::generate(rng, n, 1.0, range, bw_choices, latency_s);
        topo.positions = positions;
        topo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(n: usize) -> Topology {
        let mut rng = Rng::new(1);
        Topology::generate(&mut rng, n, 100.0, 40.0, &[50.0, 100.0], 0.002)
    }

    #[test]
    fn symmetric_bandwidth() {
        let t = topo(10);
        for i in 0..10 {
            for j in 0..10 {
                assert_eq!(t.bw[i][j], t.bw[j][i]);
            }
        }
    }

    #[test]
    fn neighbors_within_range_and_symmetric() {
        let t = topo(15);
        for i in 0..15 {
            for &j in &t.neighbors(i) {
                assert!(t.positions[i].dist(&t.positions[j]) <= t.range);
                assert!(t.neighbors(j).contains(&i));
            }
            assert!(!t.neighbors(i).contains(&i));
        }
    }

    #[test]
    fn transfer_time_scales_with_size_and_flows() {
        let t = topo(5);
        let t1 = t.transfer_secs(0, 1, 10.0, 1);
        let t2 = t.transfer_secs(0, 1, 20.0, 1);
        let t4 = t.transfer_secs(0, 1, 10.0, 2);
        assert!(t2 > t1);
        assert!(t4 > t1);
        assert_eq!(t.transfer_secs(3, 3, 10.0, 1), 0.0);
    }

    #[test]
    fn clustered_positions_are_grouped() {
        let mut rng = Rng::new(2);
        let t = Topology::generate_clustered(&mut rng, 25, 5, 10.0, 25.0, &[100.0], 0.001);
        assert_eq!(t.n(), 25);
        // Within-cluster distances are bounded by the spread diameter.
        for c in 0..5 {
            for i in 0..5 {
                for j in 0..5 {
                    let a = c * 5 + i;
                    let b = c * 5 + j;
                    assert!(t.positions[a].dist(&t.positions[b]) <= 20.0 + 1e-9);
                }
            }
        }
        // Different clusters are farther apart than cluster members.
        assert!(t.positions[0].dist(&t.positions[24]) > 20.0);
    }

    #[test]
    fn deterministic_generation() {
        let a = topo(8);
        let b = topo(8);
        assert_eq!(a.positions, b.positions);
        assert_eq!(a.bw, b.bw);
    }
}
