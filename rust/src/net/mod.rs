//! Edge-network substrate: 2-D geography, transmission ranges, and the
//! pairwise bandwidth/latency model.
//!
//! The paper's testbeds shape bandwidth with `tcconfig` (containers) and
//! `wondershaper` (Raspberry Pis); here a [`Topology`] carries an explicit
//! symmetric bandwidth matrix plus node positions.  Geographic proximity
//! drives both cluster formation (§III) and the neighbor sets that bound
//! every MARL agent's action space ("edge nodes in its transmission
//! range", §I).
//!
//! Positions are *mutable*: the [`mobility`] subsystem evolves them over
//! simulated time.  Neighbor sets are served from a cached adjacency
//! index (built at construction, O(degree) per query, no allocation via
//! [`Topology::neighbors_ref`]); whoever mutates `positions` must call
//! [`Topology::rebuild_adjacency`] — the explicit invalidation hook the
//! mobility tick uses — which also refreshes the [`grid`] spatial hash
//! that makes the rebuild itself (and radius queries such as the
//! blast-radius victim search) sub-quadratic.

pub mod grid;
pub mod mobility;

pub use grid::SpatialGrid;
pub use mobility::{DynamicTopology, MobilityModel, MobilityState};

use crate::util::Rng;

/// 2-D position in meters (arbitrary plane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pos {
    pub x: f64,
    pub y: f64,
}

impl Pos {
    pub fn dist(&self, other: &Pos) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }
}

/// Network topology over `n` edge nodes.
#[derive(Debug, Clone)]
pub struct Topology {
    pub positions: Vec<Pos>,
    /// Transmission range in meters: nodes within range are neighbors.
    pub range: f64,
    /// Symmetric pairwise bandwidth in Mbps (`bw[i][j]`, `bw[i][i] = inf`).
    pub bw: Vec<Vec<f64>>,
    /// One-way latency in seconds for control messages.
    pub latency: Vec<Vec<f64>>,
    /// Cached neighbor lists (ascending node id), derived from
    /// `positions` + `range`.  Invalidated explicitly via
    /// [`Topology::rebuild_adjacency`] when positions change.
    adjacency: Vec<Vec<usize>>,
    /// Spatial hash over `positions` (cells sized to `range`), rebuilt
    /// together with the adjacency cache.  Backs the O(n·k) adjacency
    /// rebuild and the radius queries ([`Topology::nodes_within_into`]).
    grid: SpatialGrid,
}

impl Topology {
    /// Assemble a topology from its raw parts and build the adjacency
    /// cache.
    pub fn from_parts(
        positions: Vec<Pos>,
        range: f64,
        bw: Vec<Vec<f64>>,
        latency: Vec<Vec<f64>>,
    ) -> Topology {
        let grid = SpatialGrid::build(&[], 1.0);
        let mut topo = Topology { positions, range, bw, latency, adjacency: Vec::new(), grid };
        topo.rebuild_adjacency();
        topo
    }

    pub fn n(&self) -> usize {
        self.positions.len()
    }

    /// All nodes within transmission range of `i` (excluding `i`),
    /// served from the adjacency cache.  Allocates a clone — hot paths
    /// use [`Topology::neighbors_ref`].
    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        self.adjacency[i].clone()
    }

    /// Borrowed view of `i`'s cached neighbor list (ascending).
    #[inline]
    pub fn neighbors_ref(&self, i: usize) -> &[usize] {
        &self.adjacency[i]
    }

    /// Reference O(n) neighbor scan straight off `positions` — the
    /// pre-cache implementation, kept as the equivalence baseline for
    /// the cache and the spatial grid (tests, `benches/hotpath.rs`).
    pub fn neighbors_scan(&self, i: usize) -> Vec<usize> {
        (0..self.n())
            .filter(|&j| j != i && self.positions[i].dist(&self.positions[j]) <= self.range)
            .collect()
    }

    /// Reference O(n²) adjacency rebuild (the pre-grid implementation):
    /// one full scan per node.  Kept as the equivalence baseline the
    /// grid-backed [`Topology::rebuild_adjacency`] is pinned against
    /// (tests, `benches/hotpath.rs` grid-vs-scan cells).
    pub fn adjacency_scan(&self) -> Vec<Vec<usize>> {
        (0..self.n()).map(|i| self.neighbors_scan(i)).collect()
    }

    /// Recompute the adjacency cache (and the spatial grid behind it)
    /// from the current positions.  Must be called after any mutation of
    /// `positions` (the mobility tick does; so do the generators).
    ///
    /// O(n·k): the positions are binned into a range-sized [`SpatialGrid`]
    /// once, then each node queries its surrounding cells — instead of
    /// the seed's O(n²) all-pairs scan.  The grid's CSR buffers and the
    /// per-node list buffers are all reused across rebuilds, so a
    /// steady-state mobility tick does not allocate here.
    pub fn rebuild_adjacency(&mut self) {
        self.grid.rebuild(&self.positions, self.range);
        let n = self.n();
        self.adjacency.resize_with(n, Vec::new);
        for i in 0..n {
            let mut list = std::mem::take(&mut self.adjacency[i]);
            self.grid.within_into(&self.positions, self.positions[i], self.range, i, &mut list);
            self.adjacency[i] = list;
        }
    }

    /// Reference O(n) radius scan: all nodes within `r` meters of node
    /// `center` (excluding it), ascending — the baseline the grid query
    /// is pinned against.
    pub fn nodes_within_scan(&self, center: usize, r: f64) -> Vec<usize> {
        let c = self.positions[center];
        (0..self.n()).filter(|&j| j != center && self.positions[j].dist(&c) <= r).collect()
    }

    /// All nodes within `r` meters of node `center` (excluding it),
    /// ascending, via the spatial grid — the blast-radius victim query
    /// of the dynamic driver.  `out` is cleared and refilled (reuse the
    /// buffer on hot paths).  The grid reflects the positions as of the
    /// last [`Topology::rebuild_adjacency`]; callers that move nodes
    /// must rebuild first (the mobility tick already does).
    pub fn nodes_within_into(&self, center: usize, r: f64, out: &mut Vec<usize>) {
        self.grid.within_into(&self.positions, self.positions[center], r, center, out);
    }

    pub fn bandwidth(&self, a: usize, b: usize) -> f64 {
        if a == b {
            f64::INFINITY
        } else {
            self.bw[a][b]
        }
    }

    pub fn latency(&self, a: usize, b: usize) -> f64 {
        if a == b {
            0.0
        } else {
            self.latency[a][b]
        }
    }

    /// Transfer time in seconds for `mb` megabytes between `a` and `b`,
    /// with `flows` concurrent flows sharing the link.  Degenerate
    /// inputs resolve conservatively: a zero-size (or negative) transfer
    /// is free, a link with zero / negative / NaN bandwidth never
    /// completes (`+inf`).
    pub fn transfer_secs(&self, a: usize, b: usize, mb: f64, flows: usize) -> f64 {
        if a == b || mb <= 0.0 {
            return 0.0;
        }
        let link = self.bandwidth(a, b);
        if link.is_nan() || link <= 0.0 {
            // An unusable link reads as "never completes", not as a NaN
            // silently propagating into the JCT sums.
            return f64::INFINITY;
        }
        let bw = link / flows.max(1) as f64; // Mbps
        self.latency(a, b) + mb * 8.0 / bw
    }

    /// Generate a topology: positions uniform in a `side`×`side` square,
    /// bandwidth sampled uniformly from `bw_choices` per unordered pair.
    pub fn generate(
        rng: &mut Rng,
        n: usize,
        side: f64,
        range: f64,
        bw_choices: &[f64],
        latency_s: f64,
    ) -> Topology {
        let positions: Vec<Pos> =
            (0..n).map(|_| Pos { x: rng.range_f64(0.0, side), y: rng.range_f64(0.0, side) }).collect();
        let mut bw = vec![vec![0.0; n]; n];
        let mut latency = vec![vec![0.0; n]; n];
        for i in 0..n {
            bw[i][i] = f64::INFINITY;
            for j in (i + 1)..n {
                let b = *rng.choose(bw_choices);
                bw[i][j] = b;
                bw[j][i] = b;
                let l = latency_s * rng.range_f64(0.5, 1.5);
                latency[i][j] = l;
                latency[j][i] = l;
            }
        }
        Topology::from_parts(positions, range, bw, latency)
    }

    /// Generate positions pre-grouped into geographic clusters of
    /// `cluster_size`: each cluster gets a well-separated center and its
    /// members are placed within `spread` of it.  This mirrors the paper's
    /// "clusters of edges are created according to geographical locations".
    pub fn generate_clustered(
        rng: &mut Rng,
        n: usize,
        cluster_size: usize,
        spread: f64,
        range: f64,
        bw_choices: &[f64],
        latency_s: f64,
    ) -> Topology {
        let n_clusters = n.div_ceil(cluster_size);
        let grid = (n_clusters as f64).sqrt().ceil() as usize;
        let cell = spread * 4.0;
        let mut positions = Vec::with_capacity(n);
        for c in 0..n_clusters {
            let cx = (c % grid) as f64 * cell + cell / 2.0;
            let cy = (c / grid) as f64 * cell + cell / 2.0;
            let members = ((c * cluster_size)..n.min((c + 1) * cluster_size)).count();
            for _ in 0..members {
                let ang = rng.range_f64(0.0, std::f64::consts::TAU);
                let r = spread * rng.f64().sqrt();
                positions.push(Pos { x: cx + r * ang.cos(), y: cy + r * ang.sin() });
            }
        }
        let mut topo = Topology::generate(rng, n, 1.0, range, bw_choices, latency_s);
        topo.positions = positions;
        topo.rebuild_adjacency();
        topo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(n: usize) -> Topology {
        let mut rng = Rng::new(1);
        Topology::generate(&mut rng, n, 100.0, 40.0, &[50.0, 100.0], 0.002)
    }

    #[test]
    fn symmetric_bandwidth() {
        let t = topo(10);
        for i in 0..10 {
            for j in 0..10 {
                assert_eq!(t.bw[i][j], t.bw[j][i]);
            }
        }
    }

    #[test]
    fn neighbors_within_range_and_symmetric() {
        let t = topo(15);
        for i in 0..15 {
            for &j in &t.neighbors(i) {
                assert!(t.positions[i].dist(&t.positions[j]) <= t.range);
                assert!(t.neighbors(j).contains(&i));
            }
            assert!(!t.neighbors(i).contains(&i));
        }
    }

    #[test]
    fn cached_adjacency_matches_scan() {
        let t = topo(20);
        for i in 0..20 {
            assert_eq!(t.neighbors(i), t.neighbors_scan(i));
            assert_eq!(t.neighbors_ref(i), &t.neighbors_scan(i)[..]);
            assert!(t.neighbors_ref(i).windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn rebuild_adjacency_tracks_moved_positions() {
        let mut t = topo(12);
        // Teleport node 0 far away: after explicit invalidation it must
        // drop out of everyone's neighbor list.
        t.positions[0] = Pos { x: 1e6, y: 1e6 };
        t.rebuild_adjacency();
        assert!(t.neighbors_ref(0).is_empty());
        for i in 1..12 {
            assert!(!t.neighbors_ref(i).contains(&0));
            assert_eq!(t.neighbors(i), t.neighbors_scan(i));
        }
        // Teleport it back onto node 1: they become neighbors again.
        t.positions[0] = t.positions[1];
        t.rebuild_adjacency();
        assert!(t.neighbors_ref(0).contains(&1));
        assert!(t.neighbors_ref(1).contains(&0));
    }

    #[test]
    fn grid_rebuild_matches_scan_reference() {
        // The grid-backed rebuild must reproduce the O(n²) reference
        // exactly, across sizes and after arbitrary position churn.
        let mut rng = Rng::new(0x9a1d);
        for n in [1usize, 2, 17, 60, 150] {
            let mut t = Topology::generate(&mut rng, n, 120.0, 35.0, &[100.0], 0.001);
            assert_eq!(t.adjacency, t.adjacency_scan(), "n={n} after generate");
            for round in 0..5 {
                for _ in 0..n.div_ceil(3) {
                    let i = rng.below(n);
                    t.positions[i] =
                        Pos { x: rng.range_f64(-50.0, 200.0), y: rng.range_f64(-50.0, 200.0) };
                }
                t.rebuild_adjacency();
                assert_eq!(t.adjacency, t.adjacency_scan(), "n={n} round={round}");
            }
        }
    }

    #[test]
    fn radius_query_matches_scan_reference() {
        let mut rng = Rng::new(0xb1a57);
        let t = topo(40);
        let mut out = vec![123];
        for _ in 0..50 {
            let center = rng.below(40);
            let r = [0.0, 10.0, 35.0, 80.0, 1e9][rng.below(5)];
            t.nodes_within_into(center, r, &mut out);
            assert_eq!(out, t.nodes_within_scan(center, r), "center={center} r={r}");
        }
        // Radius queries see position changes once the caches rebuild.
        let mut t = t;
        t.positions[5] = t.positions[9];
        t.rebuild_adjacency();
        t.nodes_within_into(9, 0.0, &mut out);
        assert!(out.contains(&5));
        assert_eq!(out, t.nodes_within_scan(9, 0.0));
    }

    #[test]
    fn transfer_time_scales_with_size_and_flows() {
        let t = topo(5);
        let t1 = t.transfer_secs(0, 1, 10.0, 1);
        let t2 = t.transfer_secs(0, 1, 20.0, 1);
        let t4 = t.transfer_secs(0, 1, 10.0, 2);
        assert!(t2 > t1);
        assert!(t4 > t1);
        assert_eq!(t.transfer_secs(3, 3, 10.0, 1), 0.0);
    }

    #[test]
    fn transfer_degenerate_inputs() {
        let mut t = topo(5);
        // Zero-size (and negative-size) transfers are free.
        assert_eq!(t.transfer_secs(0, 1, 0.0, 1), 0.0);
        assert_eq!(t.transfer_secs(0, 1, -3.0, 1), 0.0);
        // Self-transfers are free even with broken links.
        t.bw[2][2] = 0.0;
        assert_eq!(t.transfer_secs(2, 2, 10.0, 1), 0.0);
        // Zero, negative and NaN bandwidth are unusable links, not NaN
        // leaking into JCT sums.
        t.bw[0][1] = 0.0;
        assert_eq!(t.transfer_secs(0, 1, 10.0, 1), f64::INFINITY);
        t.bw[0][1] = -5.0;
        assert_eq!(t.transfer_secs(0, 1, 10.0, 1), f64::INFINITY);
        t.bw[0][1] = f64::NAN;
        assert_eq!(t.transfer_secs(0, 1, 10.0, 1), f64::INFINITY);
        // Zero flows behaves like one flow.
        let a = t.transfer_secs(0, 2, 10.0, 0);
        let b = t.transfer_secs(0, 2, 10.0, 1);
        assert_eq!(a, b);
        assert!(a.is_finite());
    }

    #[test]
    fn clustered_positions_are_grouped() {
        let mut rng = Rng::new(2);
        let t = Topology::generate_clustered(&mut rng, 25, 5, 10.0, 25.0, &[100.0], 0.001);
        assert_eq!(t.n(), 25);
        // Within-cluster distances are bounded by the spread diameter.
        for c in 0..5 {
            for i in 0..5 {
                for j in 0..5 {
                    let a = c * 5 + i;
                    let b = c * 5 + j;
                    assert!(t.positions[a].dist(&t.positions[b]) <= 20.0 + 1e-9);
                }
            }
        }
        // Different clusters are farther apart than cluster members.
        assert!(t.positions[0].dist(&t.positions[24]) > 20.0);
        // The adjacency cache was rebuilt for the regrouped positions.
        for i in 0..25 {
            assert_eq!(t.neighbors(i), t.neighbors_scan(i));
        }
    }

    #[test]
    fn clustered_generation_with_ragged_last_cluster() {
        // n not divisible by cluster_size: the last cluster is smaller
        // but every node still gets a position inside its cluster disc.
        for (n, cs) in [(13usize, 5usize), (7, 3), (11, 4), (5, 5), (6, 5)] {
            let mut rng = Rng::new(9);
            let t = Topology::generate_clustered(&mut rng, n, cs, 10.0, 25.0, &[100.0], 0.001);
            assert_eq!(t.n(), n, "n={n} cs={cs}");
            assert_eq!(t.bw.len(), n);
            assert_eq!(t.latency.len(), n);
            let n_clusters = n.div_ceil(cs);
            // Each cluster's members stay within the spread diameter of
            // each other, including the ragged final cluster.
            for c in 0..n_clusters {
                let lo = c * cs;
                let hi = n.min((c + 1) * cs);
                assert!(hi > lo, "empty cluster {c} for n={n} cs={cs}");
                for a in lo..hi {
                    for b in lo..hi {
                        assert!(
                            t.positions[a].dist(&t.positions[b]) <= 20.0 + 1e-9,
                            "n={n} cs={cs}: nodes {a},{b} too far apart"
                        );
                    }
                }
            }
            for i in 0..n {
                assert_eq!(t.neighbors(i), t.neighbors_scan(i));
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = topo(8);
        let b = topo(8);
        assert_eq!(a.positions, b.positions);
        assert_eq!(a.bw, b.bw);
    }
}
