//! Edge-network substrate: 2-D geography, transmission ranges, and the
//! sparse on-demand link-pricing model.
//!
//! The paper's testbeds shape bandwidth with `tcconfig` (containers) and
//! `wondershaper` (Raspberry Pis); here a [`Topology`] carries node
//! positions plus per-node [`link::LinkParams`], and every pairwise link
//! quality is *priced on demand* (distance-attenuated bottleneck rate —
//! see [`link`]) instead of being stored in O(n²) matrices.  Geographic
//! proximity drives both cluster formation (§III) and the neighbor sets
//! that bound every MARL agent's action space ("edge nodes in its
//! transmission range", §I).
//!
//! Positions are *mutable*: the [`mobility`] subsystem evolves them over
//! simulated time.  Neighbor sets are served from a cached adjacency
//! index (built at construction, O(degree) per query, no allocation via
//! [`Topology::neighbors_ref`]); whoever mutates `positions` must call
//! [`Topology::rebuild_adjacency`] — the explicit invalidation hook that
//! refreshes the [`grid`] spatial hash, the adjacency lists *and* the
//! cached link prices together.  The mobility tick uses the cheaper
//! [`Topology::advance_links`], whose sparse repricing is O(moved·k)
//! instead of the dense reference's O(moved·n) row rewrite.
//!
//! # Example
//!
//! ```
//! use srole::net::Topology;
//! use srole::util::Rng;
//!
//! let mut rng = Rng::new(7);
//! let topo = Topology::generate(&mut rng, 25, 100.0, 40.0, &[50.0, 100.0], 0.002);
//!
//! // Neighbor sets come from the cached spatial-grid adjacency…
//! for &j in topo.neighbors_ref(0) {
//!     assert!(topo.positions[0].dist(&topo.positions[j]) <= topo.range);
//! }
//! // …and link prices are derived on demand: symmetric, no matrices.
//! assert_eq!(topo.bandwidth(0, 1), topo.bandwidth(1, 0));
//! assert!(topo.transfer_secs(0, 1, 10.0, 1) > 0.0);
//! assert_eq!(topo.transfer_secs(3, 3, 10.0, 1), 0.0); // self-transfers are free
//! ```

pub mod grid;
pub mod link;
pub mod mobility;

pub use grid::SpatialGrid;
pub use link::{LinkModel, LinkParams};
pub use mobility::{DynamicTopology, MobilityModel, MobilityState};

use crate::obs;
use crate::util::Rng;

/// 2-D position in meters (arbitrary plane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pos {
    pub x: f64,
    pub y: f64,
}

impl Pos {
    pub fn dist(&self, other: &Pos) -> f64 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }
}

/// Network topology over `n` edge nodes.
#[derive(Debug, Clone)]
pub struct Topology {
    pub positions: Vec<Pos>,
    /// Transmission range in meters: nodes within range are neighbors.
    pub range: f64,
    /// Per-node link parameters every pair price derives from (O(n)
    /// state — the dense matrices are gone).
    pub params: LinkParams,
    /// The link store: sparse on-demand pricing (default) or the dense
    /// materialized reference ([`Topology::use_dense_links`]).
    link: LinkModel,
    /// Cached neighbor lists (ascending node id), derived from
    /// `positions` + `range`.  Invalidated explicitly via
    /// [`Topology::rebuild_adjacency`] when positions change.
    adjacency: Vec<Vec<usize>>,
    /// Spatial hash over `positions` (cells sized to `range`), rebuilt
    /// together with the adjacency cache.  Backs the O(n·k) adjacency
    /// rebuild and the radius queries ([`Topology::nodes_within_into`]).
    grid: SpatialGrid,
}

impl Topology {
    /// Assemble a topology from positions and per-node link parameters,
    /// then build the adjacency cache and the sparse link cache.
    pub fn from_parts(positions: Vec<Pos>, range: f64, params: LinkParams) -> Topology {
        assert_eq!(positions.len(), params.n(), "one LinkParams entry per node");
        let grid = SpatialGrid::build(&[], 1.0);
        let mut topo = Topology {
            positions,
            range,
            params,
            link: LinkModel::Sparse(link::SparseLinks::default()),
            adjacency: Vec::new(),
            grid,
        };
        topo.rebuild_adjacency();
        topo
    }

    pub fn n(&self) -> usize {
        self.positions.len()
    }

    /// All nodes within transmission range of `i` (excluding `i`),
    /// served from the adjacency cache.  Allocates a clone — hot paths
    /// use [`Topology::neighbors_ref`].
    pub fn neighbors(&self, i: usize) -> Vec<usize> {
        self.adjacency[i].clone()
    }

    /// Borrowed view of `i`'s cached neighbor list (ascending).
    #[inline]
    pub fn neighbors_ref(&self, i: usize) -> &[usize] {
        &self.adjacency[i]
    }

    /// Reference O(n) neighbor scan straight off `positions` — the
    /// pre-cache implementation, kept as the equivalence baseline for
    /// the cache and the spatial grid (tests, `benches/hotpath.rs`).
    pub fn neighbors_scan(&self, i: usize) -> Vec<usize> {
        (0..self.n())
            .filter(|&j| j != i && self.positions[i].dist(&self.positions[j]) <= self.range)
            .collect()
    }

    /// Reference O(n²) adjacency rebuild (the pre-grid implementation):
    /// one full scan per node.  Kept as the equivalence baseline the
    /// grid-backed [`Topology::rebuild_adjacency`] is pinned against
    /// (tests, `benches/hotpath.rs` grid-vs-scan cells).
    pub fn adjacency_scan(&self) -> Vec<Vec<usize>> {
        (0..self.n()).map(|i| self.neighbors_scan(i)).collect()
    }

    /// Rebuild the spatial grid and the adjacency lists from the current
    /// positions — O(n·k), buffers reused across rebuilds.
    fn rebuild_adjacency_index(&mut self) {
        self.grid.rebuild(&self.positions, self.range);
        let n = self.n();
        self.adjacency.resize_with(n, Vec::new);
        for i in 0..n {
            let mut list = std::mem::take(&mut self.adjacency[i]);
            self.grid.within_into(&self.positions, self.positions[i], self.range, i, &mut list);
            self.adjacency[i] = list;
        }
    }

    /// Recompute every position-derived cache — spatial grid, adjacency
    /// lists *and* link prices — from the current positions.  Must be
    /// called after any mutation of `positions` (the generators do; so
    /// does any test that teleports nodes).
    ///
    /// O(n·k) with the sparse link model: positions are binned into a
    /// range-sized [`SpatialGrid`] once, each node queries its
    /// surrounding cells, and the sparse link cache re-prices exactly
    /// the adjacency rows.  The dense reference model re-materializes
    /// its full matrices here (O(n²)) — that cost is the reason it is
    /// only a reference.  The mobility tick uses the incremental
    /// [`Topology::advance_links`] instead.
    pub fn rebuild_adjacency(&mut self) {
        self.rebuild_adjacency_index();
        let _sp = obs::span(obs::Phase::LinkReprice);
        match &mut self.link {
            LinkModel::Sparse(s) => {
                s.refresh_all(&self.params, &self.positions, self.range, &self.adjacency)
            }
            LinkModel::Dense(d) => d.refresh_all(&self.params, &self.positions, self.range),
        }
    }

    /// The mobility-tick path: positions of `moved` changed — rebuild
    /// the grid + adjacency index (O(n·k)) and reprice incrementally:
    /// O(moved·k) on the sparse model versus the dense reference's
    /// O(moved·n) row rewrite.  Equivalent to
    /// [`Topology::rebuild_adjacency`] whenever only `moved` nodes
    /// actually changed position (pinned by randomized tests).
    pub fn advance_links(&mut self, moved: &[usize]) {
        self.rebuild_adjacency_index();
        self.reprice_moved(moved);
    }

    /// Incremental link repricing after `moved` changed position.  The
    /// adjacency index must already reflect the new positions
    /// ([`Topology::advance_links`] bundles both); exposed separately so
    /// `benches/hotpath.rs` can time the repricing alone.
    pub fn reprice_moved(&mut self, moved: &[usize]) {
        let _sp = obs::span(obs::Phase::LinkReprice);
        match &mut self.link {
            LinkModel::Sparse(s) => s.reprice_moved(
                &self.params,
                &self.positions,
                self.range,
                &self.adjacency,
                moved,
            ),
            LinkModel::Dense(d) => {
                d.reprice_moved(&self.params, &self.positions, self.range, moved)
            }
        }
    }

    /// Reference O(n) radius scan: all nodes within `r` meters of node
    /// `center` (excluding it), ascending — the baseline the grid query
    /// is pinned against.
    pub fn nodes_within_scan(&self, center: usize, r: f64) -> Vec<usize> {
        let c = self.positions[center];
        (0..self.n()).filter(|&j| j != center && self.positions[j].dist(&c) <= r).collect()
    }

    /// All nodes within `r` meters of node `center` (excluding it),
    /// ascending, via the spatial grid — the blast-radius victim query
    /// of the dynamic driver.  `out` is cleared and refilled (reuse the
    /// buffer on hot paths).  The grid reflects the positions as of the
    /// last [`Topology::rebuild_adjacency`]; callers that move nodes
    /// must rebuild first (the mobility tick already does).
    ///
    /// ```
    /// use srole::net::Topology;
    /// use srole::util::Rng;
    ///
    /// let mut rng = Rng::new(3);
    /// let topo = Topology::generate(&mut rng, 30, 80.0, 25.0, &[100.0], 0.001);
    /// let mut out = Vec::new();
    /// topo.nodes_within_into(0, 40.0, &mut out);
    /// assert_eq!(out, topo.nodes_within_scan(0, 40.0)); // pinned to the scan reference
    /// ```
    pub fn nodes_within_into(&self, center: usize, r: f64, out: &mut Vec<usize>) {
        self.grid.within_into(&self.positions, self.positions[center], r, center, out);
    }

    /// `(bandwidth Mbps, one-way latency s)` of link `(a, b)` under the
    /// active link model — one lookup for both quantities.
    #[inline]
    pub fn link_price(&self, a: usize, b: usize) -> (f64, f64) {
        match &self.link {
            LinkModel::Sparse(s) => s.link(&self.params, &self.positions, self.range, a, b),
            LinkModel::Dense(d) => d.link(a, b),
        }
    }

    pub fn bandwidth(&self, a: usize, b: usize) -> f64 {
        self.link_price(a, b).0
    }

    pub fn latency(&self, a: usize, b: usize) -> f64 {
        self.link_price(a, b).1
    }

    /// Transfer time in seconds for `mb` megabytes between `a` and `b`,
    /// with `flows` concurrent flows sharing the link.  Degenerate
    /// inputs resolve conservatively: a zero-size (or negative) transfer
    /// is free, a link with zero / negative / NaN bandwidth — whether
    /// priced on demand or served from a (possibly poisoned) cache /
    /// dense entry — never completes (`+inf`).
    pub fn transfer_secs(&self, a: usize, b: usize, mb: f64, flows: usize) -> f64 {
        if a == b || mb <= 0.0 {
            return 0.0;
        }
        let (link, lat) = self.link_price(a, b);
        if link.is_nan() || link <= 0.0 || lat.is_nan() {
            // An unusable link — degenerate bandwidth OR latency — reads
            // as "never completes", not as a NaN silently propagating
            // into the JCT sums.
            return f64::INFINITY;
        }
        let bw = link / flows.max(1) as f64; // Mbps
        lat + mb * 8.0 / bw
    }

    /// Whether the dense reference store is active (tests / benches).
    pub fn is_dense(&self) -> bool {
        self.link.is_dense()
    }

    /// Switch to the dense reference store, materializing the full
    /// matrices from the pricing function — O(n²) memory, kept in-tree
    /// only so the sparse model stays pinned to it.  No RNG draws, so a
    /// scenario's stream (and therefore everything downstream) is
    /// unchanged by the switch.
    pub fn use_dense_links(&mut self) {
        let mut dense = link::DenseLinks::default();
        dense.refresh_all(&self.params, &self.positions, self.range);
        self.link = LinkModel::Dense(dense);
    }

    /// Switch (back) to the sparse on-demand store.
    pub fn use_sparse_links(&mut self) {
        let mut sparse = link::SparseLinks::default();
        sparse.refresh_all(&self.params, &self.positions, self.range, &self.adjacency);
        self.link = LinkModel::Sparse(sparse);
    }

    /// Total directed links currently materialized, self-links excluded
    /// on both stores so the two figures are comparable (sparse: cached
    /// adjacency entries, O(n·k); dense: the n·(n−1) off-diagonal
    /// matrix cells).
    pub fn materialized_links(&self) -> usize {
        match &self.link {
            LinkModel::Sparse(s) => s.cached_links(),
            LinkModel::Dense(d) => d.bw.len() * d.bw.len().saturating_sub(1),
        }
    }

    /// Fault injection (tests): force the *stored* bandwidth of `(a, b)`
    /// to `bw` — the dense matrix entry, or a sparse cache entry with
    /// current epochs — so degenerate-value guards can be exercised
    /// against what reads actually serve.
    pub fn poison_link_bw(&mut self, a: usize, b: usize, bw: f64) {
        match &mut self.link {
            LinkModel::Dense(d) => d.poison_bw(a, b, bw),
            LinkModel::Sparse(s) => {
                let (_, lat) = link::price(&self.params, &self.positions, self.range, a, b);
                s.poison_bw(a, b, bw, lat);
                s.poison_bw(b, a, bw, lat);
            }
        }
    }

    /// Generate a topology: positions uniform in a `side`×`side` square,
    /// per-node base link rates sampled uniformly from `bw_choices`
    /// (O(n) draws — the dense seed drew one value per pair).
    pub fn generate(
        rng: &mut Rng,
        n: usize,
        side: f64,
        range: f64,
        bw_choices: &[f64],
        latency_s: f64,
    ) -> Topology {
        let positions: Vec<Pos> =
            (0..n).map(|_| Pos { x: rng.range_f64(0.0, side), y: rng.range_f64(0.0, side) }).collect();
        let params = LinkParams::generate(rng, n, bw_choices, latency_s);
        Topology::from_parts(positions, range, params)
    }

    /// Generate positions pre-grouped into geographic clusters of
    /// `cluster_size`: each cluster gets a well-separated center and its
    /// members are placed within `spread` of it.  This mirrors the paper's
    /// "clusters of edges are created according to geographical locations".
    pub fn generate_clustered(
        rng: &mut Rng,
        n: usize,
        cluster_size: usize,
        spread: f64,
        range: f64,
        bw_choices: &[f64],
        latency_s: f64,
    ) -> Topology {
        let n_clusters = n.div_ceil(cluster_size);
        let grid = (n_clusters as f64).sqrt().ceil() as usize;
        let cell = spread * 4.0;
        let mut positions = Vec::with_capacity(n);
        for c in 0..n_clusters {
            let cx = (c % grid) as f64 * cell + cell / 2.0;
            let cy = (c / grid) as f64 * cell + cell / 2.0;
            let members = ((c * cluster_size)..n.min((c + 1) * cluster_size)).count();
            for _ in 0..members {
                let ang = rng.range_f64(0.0, std::f64::consts::TAU);
                let r = spread * rng.f64().sqrt();
                positions.push(Pos { x: cx + r * ang.cos(), y: cy + r * ang.sin() });
            }
        }
        let params = LinkParams::generate(rng, n, bw_choices, latency_s);
        Topology::from_parts(positions, range, params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(n: usize) -> Topology {
        let mut rng = Rng::new(1);
        Topology::generate(&mut rng, n, 100.0, 40.0, &[50.0, 100.0], 0.002)
    }

    #[test]
    fn symmetric_bandwidth_and_latency() {
        let t = topo(10);
        for i in 0..10 {
            for j in 0..10 {
                assert_eq!(t.bandwidth(i, j), t.bandwidth(j, i));
                assert_eq!(t.latency(i, j), t.latency(j, i));
            }
            assert_eq!(t.bandwidth(i, i), f64::INFINITY);
            assert_eq!(t.latency(i, i), 0.0);
        }
    }

    #[test]
    fn prices_follow_the_pricing_function() {
        // Every read — cached or on demand, sparse or dense — must be
        // exactly the pure pricing function of the current state.
        let mut t = topo(12);
        for dense in [false, true] {
            if dense {
                t.use_dense_links();
            }
            for i in 0..12 {
                for j in 0..12 {
                    let want = link::price(&t.params, &t.positions, t.range, i, j);
                    assert_eq!(t.link_price(i, j), want, "dense={dense} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn sparse_materializes_only_adjacency() {
        let t = topo(30);
        let degree_total: usize = (0..30).map(|i| t.neighbors_ref(i).len()).sum();
        assert_eq!(t.materialized_links(), degree_total);
        assert!(degree_total < 30 * 30);
        let mut dense = t.clone();
        dense.use_dense_links();
        assert_eq!(dense.materialized_links(), 30 * 29);
    }

    #[test]
    fn link_model_round_trips_between_stores() {
        // dense → sparse → dense: every switch re-derives from the same
        // pricing function, so prices survive the round trip bit-for-bit
        // — including after a teleport + rebuild while dense.
        let all_prices = |t: &Topology| -> Vec<(f64, f64)> {
            let mut v = Vec::with_capacity(15 * 15);
            for i in 0..15 {
                for j in 0..15 {
                    v.push(t.link_price(i, j));
                }
            }
            v
        };
        let mut t = topo(15);
        t.use_dense_links();
        t.positions[3] = Pos { x: 5.0, y: 5.0 };
        t.rebuild_adjacency();
        let want = all_prices(&t);
        t.use_sparse_links();
        assert!(!t.is_dense());
        assert_eq!(all_prices(&t), want);
        t.use_dense_links();
        assert!(t.is_dense());
        assert_eq!(all_prices(&t), want);
    }

    #[test]
    fn neighbors_within_range_and_symmetric() {
        let t = topo(15);
        for i in 0..15 {
            for &j in &t.neighbors(i) {
                assert!(t.positions[i].dist(&t.positions[j]) <= t.range);
                assert!(t.neighbors(j).contains(&i));
            }
            assert!(!t.neighbors(i).contains(&i));
        }
    }

    #[test]
    fn cached_adjacency_matches_scan() {
        let t = topo(20);
        for i in 0..20 {
            assert_eq!(t.neighbors(i), t.neighbors_scan(i));
            assert_eq!(t.neighbors_ref(i), &t.neighbors_scan(i)[..]);
            assert!(t.neighbors_ref(i).windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn rebuild_adjacency_tracks_moved_positions() {
        let mut t = topo(12);
        // Teleport node 0 far away: after explicit invalidation it must
        // drop out of everyone's neighbor list, and its link prices must
        // follow the new distance.
        let bw_before = t.bandwidth(0, 1);
        t.positions[0] = Pos { x: 1e6, y: 1e6 };
        t.rebuild_adjacency();
        assert!(t.neighbors_ref(0).is_empty());
        for i in 1..12 {
            assert!(!t.neighbors_ref(i).contains(&0));
            assert_eq!(t.neighbors(i), t.neighbors_scan(i));
        }
        let bw_far = t.bandwidth(0, 1);
        assert!(bw_far <= bw_before, "teleporting away must not improve the link");
        assert_eq!(
            bw_far,
            t.params.rate[0].min(t.params.rate[1]) * link::EDGE_ATTENUATION,
            "far links floor at the edge attenuation"
        );
        // Teleport it back onto node 1: they become neighbors again and
        // the link prices at full strength.
        t.positions[0] = t.positions[1];
        t.rebuild_adjacency();
        assert!(t.neighbors_ref(0).contains(&1));
        assert!(t.neighbors_ref(1).contains(&0));
        assert_eq!(t.bandwidth(0, 1), t.params.rate[0].min(t.params.rate[1]));
    }

    #[test]
    fn advance_links_matches_full_rebuild() {
        // The incremental mobility path must leave exactly the state a
        // full rebuild produces — adjacency and prices — across random
        // churn, on both link models.
        let mut rng = Rng::new(0x5fa7);
        for dense in [false, true] {
            let mut t = topo(25);
            if dense {
                t.use_dense_links();
            }
            for round in 0..10 {
                let moved: Vec<usize> = {
                    let mut m: Vec<usize> = (0..25).filter(|_| rng.chance(0.25)).collect();
                    if m.is_empty() {
                        m.push(rng.below(25));
                    }
                    m
                };
                for &i in &moved {
                    t.positions[i] =
                        Pos { x: rng.range_f64(0.0, 120.0), y: rng.range_f64(0.0, 120.0) };
                }
                t.advance_links(&moved);
                let mut full = t.clone();
                full.rebuild_adjacency();
                for i in 0..25 {
                    assert_eq!(t.neighbors_ref(i), full.neighbors_ref(i));
                    for j in 0..25 {
                        assert_eq!(
                            t.link_price(i, j),
                            full.link_price(i, j),
                            "dense={dense} round={round} ({i},{j})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn grid_rebuild_matches_scan_reference() {
        // The grid-backed rebuild must reproduce the O(n²) reference
        // exactly, across sizes and after arbitrary position churn.
        let mut rng = Rng::new(0x9a1d);
        for n in [1usize, 2, 17, 60, 150] {
            let mut t = Topology::generate(&mut rng, n, 120.0, 35.0, &[100.0], 0.001);
            assert_eq!(t.adjacency, t.adjacency_scan(), "n={n} after generate");
            for round in 0..5 {
                for _ in 0..n.div_ceil(3) {
                    let i = rng.below(n);
                    t.positions[i] =
                        Pos { x: rng.range_f64(-50.0, 200.0), y: rng.range_f64(-50.0, 200.0) };
                }
                t.rebuild_adjacency();
                assert_eq!(t.adjacency, t.adjacency_scan(), "n={n} round={round}");
            }
        }
    }

    #[test]
    fn radius_query_matches_scan_reference() {
        let mut rng = Rng::new(0xb1a57);
        let t = topo(40);
        let mut out = vec![123];
        for _ in 0..50 {
            let center = rng.below(40);
            let r = [0.0, 10.0, 35.0, 80.0, 1e9][rng.below(5)];
            t.nodes_within_into(center, r, &mut out);
            assert_eq!(out, t.nodes_within_scan(center, r), "center={center} r={r}");
        }
        // Radius queries see position changes once the caches rebuild.
        let mut t = t;
        t.positions[5] = t.positions[9];
        t.rebuild_adjacency();
        t.nodes_within_into(9, 0.0, &mut out);
        assert!(out.contains(&5));
        assert_eq!(out, t.nodes_within_scan(9, 0.0));
    }

    #[test]
    fn transfer_time_scales_with_size_and_flows() {
        let t = topo(5);
        let t1 = t.transfer_secs(0, 1, 10.0, 1);
        let t2 = t.transfer_secs(0, 1, 20.0, 1);
        let t4 = t.transfer_secs(0, 1, 10.0, 2);
        assert!(t2 > t1);
        assert!(t4 > t1);
        assert_eq!(t.transfer_secs(3, 3, 10.0, 1), 0.0);
    }

    #[test]
    fn transfer_degenerate_inputs() {
        for dense in [false, true] {
            let mut t = topo(5);
            if dense {
                t.use_dense_links();
            }
            // Zero-size (and negative-size) transfers are free.
            assert_eq!(t.transfer_secs(0, 1, 0.0, 1), 0.0);
            assert_eq!(t.transfer_secs(0, 1, -3.0, 1), 0.0);
            // Self-transfers are free regardless of any stored value.
            assert_eq!(t.transfer_secs(2, 2, 10.0, 1), 0.0);
            // Zero, negative and NaN *stored* bandwidth (a poisoned cache
            // entry on the sparse path, a poisoned matrix cell on the
            // dense one) are unusable links, not NaN leaking into JCT
            // sums — the satellite bugfix guard.
            for bad in [0.0, -5.0, f64::NAN] {
                t.poison_link_bw(0, 1, bad);
                assert_eq!(
                    t.transfer_secs(0, 1, 10.0, 1),
                    f64::INFINITY,
                    "dense={dense} bad={bad}"
                );
                assert_eq!(t.transfer_secs(1, 0, 10.0, 1), f64::INFINITY);
            }
            // Degenerate per-node rates poison the *on-demand* path the
            // same way (no poisoned cache entry involved).  A zero rate
            // bottlenecks the pair to zero; `f64::min` ignores a single
            // NaN operand, so the NaN case needs both ends degenerate.
            let mut t2 = topo(5);
            if dense {
                t2.use_dense_links();
            }
            t2.params.rate[3] = 0.0;
            t2.rebuild_adjacency();
            assert_eq!(t2.transfer_secs(3, 4, 10.0, 1), f64::INFINITY, "dense={dense}");
            t2.params.rate[3] = f64::NAN;
            t2.params.rate[4] = f64::NAN;
            t2.rebuild_adjacency();
            assert_eq!(t2.transfer_secs(3, 4, 10.0, 1), f64::INFINITY, "dense={dense}");
            // Degenerate *latency* (NaN jitter) must not leak NaN into
            // JCT sums either, even when bandwidth is healthy.
            let mut t3 = topo(5);
            if dense {
                t3.use_dense_links();
            }
            t3.params.jitter[1] = f64::NAN;
            t3.rebuild_adjacency();
            assert!(t3.bandwidth(1, 2) > 0.0, "bandwidth side stays healthy");
            assert_eq!(t3.transfer_secs(1, 2, 10.0, 1), f64::INFINITY, "dense={dense}");
            // Zero flows behaves like one flow.
            let a = t.transfer_secs(0, 2, 10.0, 0);
            let b = t.transfer_secs(0, 2, 10.0, 1);
            assert_eq!(a, b);
            assert!(a.is_finite());
        }
    }

    #[test]
    fn clustered_positions_are_grouped() {
        let mut rng = Rng::new(2);
        let t = Topology::generate_clustered(&mut rng, 25, 5, 10.0, 25.0, &[100.0], 0.001);
        assert_eq!(t.n(), 25);
        // Within-cluster distances are bounded by the spread diameter.
        for c in 0..5 {
            for i in 0..5 {
                for j in 0..5 {
                    let a = c * 5 + i;
                    let b = c * 5 + j;
                    assert!(t.positions[a].dist(&t.positions[b]) <= 20.0 + 1e-9);
                }
            }
        }
        // Different clusters are farther apart than cluster members.
        assert!(t.positions[0].dist(&t.positions[24]) > 20.0);
        // The adjacency cache was rebuilt for the regrouped positions.
        for i in 0..25 {
            assert_eq!(t.neighbors(i), t.neighbors_scan(i));
        }
    }

    #[test]
    fn clustered_generation_with_ragged_last_cluster() {
        // n not divisible by cluster_size: the last cluster is smaller
        // but every node still gets a position inside its cluster disc.
        for (n, cs) in [(13usize, 5usize), (7, 3), (11, 4), (5, 5), (6, 5)] {
            let mut rng = Rng::new(9);
            let t = Topology::generate_clustered(&mut rng, n, cs, 10.0, 25.0, &[100.0], 0.001);
            assert_eq!(t.n(), n, "n={n} cs={cs}");
            assert_eq!(t.params.rate.len(), n);
            assert_eq!(t.params.jitter.len(), n);
            let n_clusters = n.div_ceil(cs);
            // Each cluster's members stay within the spread diameter of
            // each other, including the ragged final cluster.
            for c in 0..n_clusters {
                let lo = c * cs;
                let hi = n.min((c + 1) * cs);
                assert!(hi > lo, "empty cluster {c} for n={n} cs={cs}");
                for a in lo..hi {
                    for b in lo..hi {
                        assert!(
                            t.positions[a].dist(&t.positions[b]) <= 20.0 + 1e-9,
                            "n={n} cs={cs}: nodes {a},{b} too far apart"
                        );
                    }
                }
            }
            for i in 0..n {
                assert_eq!(t.neighbors(i), t.neighbors_scan(i));
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = topo(8);
        let b = topo(8);
        assert_eq!(a.positions, b.positions);
        assert_eq!(a.params, b.params);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(a.link_price(i, j), b.link_price(i, j));
            }
        }
    }
}
