//! Parallel multi-scenario evaluation harness.
//!
//! A [`Scenario`] is one `(method × configuration)` cell of the paper's
//! evaluation grid — cluster size, workload mix, model, κ, seed — and a
//! [`Sweep`] expands the cartesian product into a scenario list.
//! [`run_parallel`] executes independent scenarios across OS threads via
//! a work-stealing index queue.
//!
//! Determinism: each scenario is self-contained — it builds its own
//! deployment, policy and RNG stream from `cfg.seed` (the coordinator
//! derives per-repetition streams as `seed + 1000·rep`), shares no
//! mutable state with other scenarios, and its report is written back to
//! its own slot.  The same sweep therefore produces bit-identical
//! reports regardless of thread count or completion order — pinned by
//! the `serial_and_parallel_agree` test below.
//!
//! This is the substrate the figure regeneration (`bin/figures.rs`), the
//! CLI (`srole run`) and the `benches/` drivers run on.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::ExperimentConfig;
use crate::coordinator::{Experiment, Method};
use crate::dnn::ModelKind;
use crate::metrics::RunMetrics;
use crate::net::MobilityModel;
use crate::obs::{ObsReport, TraceMode};
use crate::util::json::{obj, Json};
use crate::util::stats::Summary;
use crate::util::table::{f, Table};
use crate::workload::ArrivalProcess;

/// One independent evaluation cell.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Human-readable cell label (method/edges/workload/model/seed, plus
    /// churn/arrival tags when those axes are active).
    pub label: String,
    pub method: Method,
    pub cfg: ExperimentConfig,
}

impl Scenario {
    pub fn new(method: Method, cfg: ExperimentConfig) -> Scenario {
        let mut label = format!(
            "{}/e{}/w{:.0}%/{}/k{:.0}/s{}",
            method.name(),
            cfg.n_edges,
            cfg.workload * 100.0,
            cfg.model.name(),
            cfg.reward.kappa,
            cfg.seed
        );
        if cfg.failure_rate > 0.0 {
            label.push_str(&format!("/f{}", cfg.failure_rate));
        }
        if cfg.blast_radius_m > 0.0 {
            label.push_str(&format!("/r{}", cfg.blast_radius_m));
        }
        if !matches!(cfg.arrival, ArrivalProcess::Batched { .. }) {
            label.push_str(&format!("/a{}", cfg.arrival.label()));
        }
        if cfg.mobility.enabled() {
            label.push_str(&format!("/m{}", cfg.mobility.label()));
        }
        if cfg.dense_links {
            label.push_str("/dense");
        }
        if cfg.shards > 0 {
            label.push_str(&format!("/sh{}", cfg.shards));
        }
        if cfg.tree_fanout > 0 {
            label.push_str(&format!("/tree{}", cfg.tree_fanout));
        }
        if cfg.cross_cluster {
            label.push_str("/xc");
        }
        if !cfg.batch_decisions {
            label.push_str("/perdec");
        }
        if cfg.batched_eval_cost {
            label.push_str("/bcost");
        }
        if cfg.trace != TraceMode::Off {
            label.push_str(&format!("/tr{}", cfg.trace.name()));
        }
        if cfg.serving {
            label.push_str("/serve");
        }
        Scenario { label, method, cfg }
    }
}

/// Result of one scenario: pooled metrics plus the wall-clock it took.
#[derive(Debug)]
pub struct ScenarioReport {
    pub scenario: Scenario,
    pub metrics: RunMetrics,
    /// Observability report from the scenario's first repetition —
    /// `Some` only when `cfg.trace != off` (`Experiment::run_traced`).
    pub obs: Option<ObsReport>,
    /// Wall-clock seconds this scenario took on its worker thread.
    pub wall_secs: f64,
}

/// Cartesian sweep builder.  Dimensions left empty fall back to the base
/// configuration's value, so a sweep over `(methods × edges)` is just
/// those two setters.
#[derive(Debug, Clone)]
pub struct Sweep {
    pub base: ExperimentConfig,
    pub methods: Vec<Method>,
    pub edges: Vec<usize>,
    pub workloads: Vec<f64>,
    pub models: Vec<ModelKind>,
    pub kappas: Vec<f64>,
    pub seeds: Vec<u64>,
    /// Churn axis: node failures per 1000 simulated seconds (0 = static).
    pub failure_rates: Vec<f64>,
    /// Correlated-failure axis: geographic blast radius in meters
    /// (0 = independent failures).
    pub blast_radii: Vec<f64>,
    /// Arrival-process axis (batched waves / Poisson / trace).
    pub arrivals: Vec<ArrivalProcess>,
    /// Mobility axis (speed × pause grid, trace patrols, or static).
    pub mobility: Vec<MobilityModel>,
}

impl Sweep {
    pub fn new(base: ExperimentConfig) -> Sweep {
        Sweep {
            base,
            methods: Vec::new(),
            edges: Vec::new(),
            workloads: Vec::new(),
            models: Vec::new(),
            kappas: Vec::new(),
            seeds: Vec::new(),
            failure_rates: Vec::new(),
            blast_radii: Vec::new(),
            arrivals: Vec::new(),
            mobility: Vec::new(),
        }
    }

    pub fn methods(mut self, m: &[Method]) -> Sweep {
        self.methods = m.to_vec();
        self
    }

    pub fn edges(mut self, e: &[usize]) -> Sweep {
        self.edges = e.to_vec();
        self
    }

    pub fn workloads(mut self, w: &[f64]) -> Sweep {
        self.workloads = w.to_vec();
        self
    }

    pub fn models(mut self, m: &[ModelKind]) -> Sweep {
        self.models = m.to_vec();
        self
    }

    pub fn kappas(mut self, k: &[f64]) -> Sweep {
        self.kappas = k.to_vec();
        self
    }

    pub fn seeds(mut self, s: &[u64]) -> Sweep {
        self.seeds = s.to_vec();
        self
    }

    /// Churn axis: node failures per 1000 simulated seconds.
    pub fn failure_rates(mut self, r: &[f64]) -> Sweep {
        self.failure_rates = r.to_vec();
        self
    }

    /// Correlated-failure axis: blast radius in meters.
    pub fn blast_radii(mut self, r: &[f64]) -> Sweep {
        self.blast_radii = r.to_vec();
        self
    }

    /// Arrival-process axis.
    pub fn arrivals(mut self, a: &[ArrivalProcess]) -> Sweep {
        self.arrivals = a.to_vec();
        self
    }

    /// Mobility axis: one scenario per motion model (e.g. a
    /// speed × pause random-waypoint grid plus the static baseline).
    pub fn mobility(mut self, m: &[MobilityModel]) -> Sweep {
        self.mobility = m.to_vec();
        self
    }

    /// Expand the cartesian product, methods varying fastest (so a
    /// figure row's four method cells are adjacent in the list).
    pub fn scenarios(&self) -> Vec<Scenario> {
        fn dim<T: Clone>(v: &[T], base: T) -> Vec<T> {
            if v.is_empty() {
                vec![base]
            } else {
                v.to_vec()
            }
        }
        let methods = dim(&self.methods, Method::SroleC);
        let edges = dim(&self.edges, self.base.n_edges);
        let workloads = dim(&self.workloads, self.base.workload);
        let models = dim(&self.models, self.base.model);
        let kappas = dim(&self.kappas, self.base.reward.kappa);
        let seeds = dim(&self.seeds, self.base.seed);
        let failure_rates = dim(&self.failure_rates, self.base.failure_rate);
        let blast_radii = dim(&self.blast_radii, self.base.blast_radius_m);
        let arrivals = dim(&self.arrivals, self.base.arrival.clone());
        let mobility = dim(&self.mobility, self.base.mobility.clone());

        let mut out = Vec::new();
        for &seed in &seeds {
            for mob in &mobility {
                for arrival in &arrivals {
                    for &failure_rate in &failure_rates {
                        for &blast in &blast_radii {
                            for &model in &models {
                                for &e in &edges {
                                    for &w in &workloads {
                                        for &kappa in &kappas {
                                            for &method in &methods {
                                                let mut cfg = self.base.clone();
                                                cfg.seed = seed;
                                                cfg.model = model;
                                                cfg.n_edges = e;
                                                cfg.workload = w;
                                                cfg.reward.kappa = kappa;
                                                cfg.failure_rate = failure_rate;
                                                cfg.blast_radius_m = blast;
                                                cfg.arrival = arrival.clone();
                                                cfg.mobility = mob.clone();
                                                // Keep cluster size valid on small sweeps.
                                                if cfg.cluster_size > e {
                                                    cfg.cluster_size = e.max(1);
                                                }
                                                out.push(Scenario::new(method, cfg));
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Default worker count: one per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run every scenario, `threads` at a time, and return the reports in
/// scenario order.  `threads = 0` means [`default_threads`].
pub fn run_parallel(scenarios: &[Scenario], threads: usize) -> Vec<ScenarioReport> {
    let threads = if threads == 0 { default_threads() } else { threads };
    let threads = threads.clamp(1, scenarios.len().max(1));
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<ScenarioReport>>> =
        Mutex::new((0..scenarios.len()).map(|_| None).collect());

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= scenarios.len() {
                    break;
                }
                let sc = &scenarios[i];
                let t0 = Instant::now();
                let exp = Experiment::new(sc.cfg.clone());
                let (result, obs) = exp.run_traced(sc.method);
                let report = ScenarioReport {
                    scenario: sc.clone(),
                    metrics: result.metrics,
                    obs,
                    wall_secs: t0.elapsed().as_secs_f64(),
                };
                slots.lock().unwrap()[i] = Some(report);
            });
        }
    });

    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("scenario slot unfilled"))
        .collect()
}

/// Render a sweep's headline metrics as a console table.
pub fn report_table(title: &str, reports: &[ScenarioReport]) -> Table {
    let mut t = Table::new(
        title,
        &["scenario", "jct_median_s", "collisions", "sched_s", "shield_s", "wall_s"],
    );
    for r in reports {
        t.row(vec![
            r.scenario.label.clone(),
            if r.metrics.jct.is_empty() { "-".into() } else { f(r.metrics.jct_summary().median) },
            r.metrics.collisions.to_string(),
            format!("{:.3}", r.metrics.mean_sched_secs()),
            format!("{:.3}", r.metrics.mean_shield_secs()),
            format!("{:.2}", r.wall_secs),
        ]);
    }
    t
}

/// Write a machine-readable benchmark report `BENCH_<name>.json` into
/// `dir`: per-scenario wall-clock milliseconds plus mean/p50/p95
/// aggregates, so the perf trajectory is tracked across PRs.
pub fn write_bench_json(
    name: &str,
    reports: &[ScenarioReport],
    dir: &Path,
) -> std::io::Result<PathBuf> {
    let walls_ms: Vec<f64> = reports.iter().map(|r| r.wall_secs * 1e3).collect();
    let scenarios = Json::Arr(
        reports
            .iter()
            .map(|r| {
                obj(vec![
                    ("label", Json::Str(r.scenario.label.clone())),
                    ("wall_ms", Json::Num(r.wall_secs * 1e3)),
                ])
            })
            .collect(),
    );
    let aggregate = if walls_ms.is_empty() {
        Json::Null
    } else {
        let s = Summary::of(&walls_ms);
        obj(vec![
            ("mean_ms", Json::Num(s.mean)),
            ("p50_ms", Json::Num(s.median)),
            ("p95_ms", Json::Num(s.p95)),
            ("n", Json::Num(s.n as f64)),
        ])
    };
    let doc = obj(vec![
        ("bench", Json::Str(name.to_string())),
        ("scenarios", scenarios),
        ("wall_ms", aggregate),
    ]);
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, doc.to_string())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::serving::RateShape;

    fn tiny_base() -> ExperimentConfig {
        ExperimentConfig {
            n_edges: 5,
            cluster_size: 5,
            model: ModelKind::Rnn,
            iterations: 3,
            pretrain_episodes: 5,
            repetitions: 1,
            jobs_per_cluster: 2,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_expands_cartesian_product() {
        let sw = Sweep::new(tiny_base())
            .methods(&[Method::Marl, Method::SroleC])
            .edges(&[5, 10])
            .seeds(&[1, 2, 3]);
        let scenarios = sw.scenarios();
        assert_eq!(scenarios.len(), 2 * 2 * 3);
        // Methods vary fastest.
        assert_eq!(scenarios[0].method, Method::Marl);
        assert_eq!(scenarios[1].method, Method::SroleC);
        assert_eq!(scenarios[0].cfg.n_edges, scenarios[1].cfg.n_edges);
        // Labels are unique.
        let mut labels: Vec<&str> = scenarios.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), scenarios.len());
    }

    #[test]
    fn empty_dims_use_base() {
        let sw = Sweep::new(tiny_base()).methods(&[Method::Rl]);
        let scenarios = sw.scenarios();
        assert_eq!(scenarios.len(), 1);
        assert_eq!(scenarios[0].cfg.n_edges, 5);
        assert_eq!(scenarios[0].cfg.seed, 1);
    }

    #[test]
    fn cluster_size_clamped_to_edges() {
        let mut base = tiny_base();
        base.cluster_size = 5;
        let sw = Sweep::new(base).methods(&[Method::Marl]).edges(&[3]);
        let scenarios = sw.scenarios();
        assert_eq!(scenarios[0].cfg.cluster_size, 3);
        scenarios[0].cfg.validate().unwrap();
    }

    #[test]
    fn serial_and_parallel_agree() {
        // The determinism contract: same sweep → same reports, whether
        // run on one thread or many, in any completion order.
        let sw = Sweep::new(tiny_base())
            .methods(&[Method::Marl, Method::SroleC, Method::SroleD, Method::Rl]);
        let scenarios = sw.scenarios();
        assert_eq!(scenarios.len(), 4, "a ≥4-scenario sweep");
        let serial = run_parallel(&scenarios, 1);
        let parallel = run_parallel(&scenarios, 4);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.scenario.label, p.scenario.label, "order preserved");
            assert_eq!(s.metrics.jct, p.metrics.jct, "{}", s.scenario.label);
            assert_eq!(s.metrics.collisions, p.metrics.collisions);
            assert_eq!(s.metrics.decision_secs, p.metrics.decision_secs);
            assert_eq!(s.metrics.runtime_overloads, p.metrics.runtime_overloads);
        }
    }

    #[test]
    fn churn_and_arrival_axes_expand_and_tag_labels() {
        let sw = Sweep::new(tiny_base())
            .methods(&[Method::Marl, Method::SroleD])
            // Sub-0.1 rates pin the un-rounded label formatting.
            .failure_rates(&[0.0, 0.01, 0.02, 2.0])
            .arrivals(&[ArrivalProcess::default(), ArrivalProcess::Poisson { rate: 0.05 }]);
        let scenarios = sw.scenarios();
        assert_eq!(scenarios.len(), 2 * 4 * 2);
        let mut labels: Vec<&str> = scenarios.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), scenarios.len(), "churn axes must keep labels unique");
        assert!(scenarios.iter().any(|s| s.label.contains("/f2")));
        assert!(scenarios.iter().any(|s| s.label.contains("/f0.01")));
        assert!(scenarios.iter().any(|s| s.label.contains("/ap0.05")));
        // The static cell keeps its legacy label untouched.
        assert!(scenarios
            .iter()
            .any(|s| !s.label.contains("/f") && !s.label.contains("/a")));
    }

    #[test]
    fn churn_runs_are_byte_identical_across_thread_counts() {
        // The determinism contract extended to dynamic scenarios: same
        // seed + failure events enabled must produce byte-identical
        // reports whether the sweep runs on 1 thread or several.
        let mut base = tiny_base();
        base.failure_rate = 3.0;
        base.rejoin_secs = 120.0;
        let sw = Sweep::new(base)
            .methods(&[Method::Marl, Method::SroleC, Method::SroleD, Method::Rl]);
        let scenarios = sw.scenarios();
        assert!(scenarios.iter().all(|s| s.cfg.dynamic()), "churn must be active");
        let serial = run_parallel(&scenarios, 1);
        let parallel = run_parallel(&scenarios, 4);
        assert_eq!(serial.len(), parallel.len());
        let mut failures = 0usize;
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.scenario.label, p.scenario.label);
            assert_eq!(
                s.metrics.to_json().to_string(),
                p.metrics.to_json().to_string(),
                "{}: report not byte-identical across thread counts",
                s.scenario.label
            );
            failures += s.metrics.node_failures;
        }
        assert!(failures > 0, "vacuous: no failure event fired in any scenario");
    }

    #[test]
    fn mobility_and_blast_axes_expand_and_tag_labels() {
        let rwp = |s: f64, p: f64| MobilityModel::RandomWaypoint { speed_mps: s, pause_secs: p };
        let sw = Sweep::new(tiny_base())
            .methods(&[Method::Marl, Method::SroleD])
            .mobility(&[MobilityModel::Static, rwp(0.5, 0.0), rwp(2.0, 30.0)])
            .failure_rates(&[0.0, 2.0])
            .blast_radii(&[0.0, 15.0]);
        let scenarios = sw.scenarios();
        assert_eq!(scenarios.len(), 2 * 3 * 2 * 2);
        let mut labels: Vec<&str> = scenarios.iter().map(|s| s.label.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), scenarios.len(), "mobility axes must keep labels unique");
        assert!(scenarios.iter().any(|s| s.label.contains("/mw0.5p0")));
        assert!(scenarios.iter().any(|s| s.label.contains("/mw2p30")));
        assert!(scenarios.iter().any(|s| s.label.contains("/r15")));
        // The static baseline cell keeps its legacy label untouched
        // (six bare segments, no churn/blast/mobility tags appended).
        let plain = scenarios
            .iter()
            .find(|s| {
                s.cfg.failure_rate == 0.0
                    && s.cfg.blast_radius_m == 0.0
                    && !s.cfg.mobility.enabled()
            })
            .expect("a static baseline cell exists");
        assert_eq!(plain.label.split('/').count(), 6, "baseline tagged: {}", plain.label);
        for s in &scenarios {
            s.cfg.validate().unwrap();
        }
    }

    #[test]
    fn mobility_runs_are_byte_identical_across_thread_counts() {
        // The acceptance criterion: mobility sweeps must replay
        // byte-identically regardless of harness thread count.
        let mut base = tiny_base();
        base.mobility =
            MobilityModel::RandomWaypoint { speed_mps: 3.0, pause_secs: 0.0 };
        base.mobility_tick_secs = 10.0;
        let sw = Sweep::new(base)
            .methods(&[Method::Marl, Method::SroleC, Method::SroleD, Method::Rl]);
        let scenarios = sw.scenarios();
        assert!(scenarios.iter().all(|s| s.cfg.dynamic()), "mobility must be active");
        let serial = run_parallel(&scenarios, 1);
        let parallel = run_parallel(&scenarios, 4);
        assert_eq!(serial.len(), parallel.len());
        let mut moves = 0usize;
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.scenario.label, p.scenario.label);
            assert_eq!(
                s.metrics.to_json().to_string(),
                p.metrics.to_json().to_string(),
                "{}: report not byte-identical across thread counts",
                s.scenario.label
            );
            moves += s.metrics.mobility_moves;
        }
        assert!(moves > 0, "vacuous: nothing moved in any mobility scenario");
    }

    #[test]
    fn sharded_runs_are_byte_identical_across_shard_counts() {
        // The region-sharded tick engine's acceptance criterion at
        // harness altitude: the same churn sweep must produce
        // byte-identical `RunMetrics` whether each scenario runs its
        // lanes serially (shards = 1) or across worker threads
        // (shards = 2, 8), and the shard knob must tag the label.
        let mut base = tiny_base();
        base.n_edges = 10; // two clusters → two lanes
        base.cluster_size = 5;
        base.failure_rate = 3.0;
        base.rejoin_secs = 120.0;
        let sweep = |shards: usize| {
            let mut b = base.clone();
            b.shards = shards;
            Sweep::new(b).methods(&[Method::Marl, Method::SroleD])
        };
        let serial = run_parallel(&sweep(1).scenarios(), 2);
        let mut failures = 0usize;
        for &shards in &[2usize, 8] {
            let wide = run_parallel(&sweep(shards).scenarios(), 2);
            assert_eq!(serial.len(), wide.len());
            for (s, w) in serial.iter().zip(&wide) {
                assert!(s.scenario.label.ends_with("/sh1"), "{}", s.scenario.label);
                assert!(
                    w.scenario.label.ends_with(&format!("/sh{shards}")),
                    "{}",
                    w.scenario.label
                );
                assert_eq!(
                    s.metrics.to_json().to_string(),
                    w.metrics.to_json().to_string(),
                    "{}: report diverged between shards=1 and shards={shards}",
                    s.scenario.label
                );
                failures += s.metrics.node_failures;
            }
        }
        assert!(failures > 0, "vacuous: no churn fired in any sharded scenario");
    }

    #[test]
    fn shield_tree_sweeps_are_byte_identical_across_fanouts() {
        // The shield-tree acceptance criterion at harness altitude:
        // with `cross_cluster` off, the same churn + mobility sweep must
        // produce byte-identical `RunMetrics` for every `tree_fanout`
        // (0 = the flat serial driver, the pinned reference) at every
        // shard count, and the tree knob must tag the label.
        let mut base = tiny_base();
        base.n_edges = 10; // two clusters → two lanes
        base.cluster_size = 5;
        base.failure_rate = 3.0;
        base.rejoin_secs = 120.0;
        base.mobility =
            crate::net::MobilityModel::RandomWaypoint { speed_mps: 2.0, pause_secs: 0.0 };
        base.mobility_tick_secs = 10.0;
        let sweep = |shards: usize, fanout: usize| {
            let mut b = base.clone();
            b.shards = shards;
            b.tree_fanout = fanout;
            Sweep::new(b).methods(&[Method::Marl, Method::SroleD])
        };
        let mut failures = 0usize;
        for &shards in &[1usize, 8] {
            let flat = run_parallel(&sweep(shards, 0).scenarios(), 2);
            for &fanout in &[2usize, 8] {
                let tree = run_parallel(&sweep(shards, fanout).scenarios(), 2);
                assert_eq!(flat.len(), tree.len());
                for (f, t) in flat.iter().zip(&tree) {
                    assert!(!f.scenario.label.contains("/tree"), "{}", f.scenario.label);
                    assert!(
                        t.scenario.label.contains(&format!("/tree{fanout}")),
                        "{}",
                        t.scenario.label
                    );
                    assert!(!t.scenario.label.contains("/xc"), "{}", t.scenario.label);
                    assert_eq!(
                        f.metrics.to_json().to_string(),
                        t.metrics.to_json().to_string(),
                        "{}: report diverged between fanout=0 and fanout={fanout} \
                         at shards={shards}",
                        f.scenario.label
                    );
                    failures += f.metrics.node_failures;
                }
            }
        }
        assert!(failures > 0, "vacuous: no churn fired in any tree scenario");
    }

    #[test]
    fn sparse_and_dense_link_models_are_byte_identical() {
        // The link-model equivalence contract, at full-system altitude:
        // ragged clusters (n % cluster_size != 0) with simultaneous
        // random-waypoint mobility AND correlated blast-radius churn,
        // across methods and seeds, must produce byte-identical
        // `RunMetrics` whether links are priced by the sparse on-demand
        // cache or read from the dense materialized reference.
        let mut base = tiny_base();
        base.n_edges = 13; // ragged: 5 + 5 + 3
        base.cluster_size = 5;
        base.mobility = MobilityModel::RandomWaypoint { speed_mps: 3.0, pause_secs: 0.0 };
        base.mobility_tick_secs = 10.0;
        base.failure_rate = 3.0;
        base.rejoin_secs = 60.0;
        base.blast_radius_m = 30.0;
        let sweep = |dense: bool| {
            let mut b = base.clone();
            b.dense_links = dense;
            Sweep::new(b)
                .methods(&[Method::Marl, Method::SroleC, Method::SroleD, Method::Rl])
                .seeds(&[1, 2])
        };
        let sparse = run_parallel(&sweep(false).scenarios(), 2);
        let dense = run_parallel(&sweep(true).scenarios(), 2);
        assert_eq!(sparse.len(), dense.len());
        let (mut moves, mut failures, mut correlated) = (0usize, 0usize, 0usize);
        for (s, d) in sparse.iter().zip(&dense) {
            assert!(d.scenario.label.ends_with("/dense"), "{}", d.scenario.label);
            assert_eq!(
                s.metrics.to_json().to_string(),
                d.metrics.to_json().to_string(),
                "{}: sparse and dense link models diverged",
                s.scenario.label
            );
            moves += s.metrics.mobility_moves;
            failures += s.metrics.node_failures;
            correlated += s.metrics.correlated_failures;
        }
        assert!(moves > 0, "vacuous: nothing moved");
        assert!(failures > 0, "vacuous: no churn fired");
        assert!(correlated > 0, "vacuous: no correlated blast fired");
    }

    #[test]
    fn decision_path_knobs_tag_labels() {
        let mut cfg = tiny_base();
        cfg.batch_decisions = false;
        cfg.batched_eval_cost = true;
        let s = Scenario::new(Method::Marl, cfg);
        assert!(s.label.ends_with("/perdec/bcost"), "{}", s.label);
        // The default (batched, legacy cost) keeps the bare label.
        let d = Scenario::new(Method::Marl, tiny_base());
        assert_eq!(d.label.split('/').count(), 6, "defaults must not tag: {}", d.label);
    }

    #[test]
    fn batched_decisions_replay_per_decision_reference_byte_identically() {
        // The batched decision path's acceptance criterion at harness
        // altitude: under churn + mobility, on the legacy driver and on
        // every shard count, batched runs must produce byte-identical
        // `RunMetrics` to the per-decision reference, and the reference
        // knob must tag the label.
        let mut base = tiny_base();
        base.n_edges = 10; // two clusters → two lanes when sharded
        base.cluster_size = 5;
        base.failure_rate = 3.0;
        base.rejoin_secs = 120.0;
        base.mobility = MobilityModel::RandomWaypoint { speed_mps: 3.0, pause_secs: 0.0 };
        base.mobility_tick_secs = 10.0;
        let sweep = |batch: bool, shards: usize| {
            let mut b = base.clone();
            b.batch_decisions = batch;
            b.shards = shards;
            Sweep::new(b).methods(&[Method::Marl, Method::SroleD])
        };
        let (mut failures, mut moves) = (0usize, 0usize);
        for &shards in &[0usize, 1, 2, 8] {
            let batched = run_parallel(&sweep(true, shards).scenarios(), 2);
            let perdec = run_parallel(&sweep(false, shards).scenarios(), 2);
            assert_eq!(batched.len(), perdec.len());
            for (b, p) in batched.iter().zip(&perdec) {
                assert!(p.scenario.label.ends_with("/perdec"), "{}", p.scenario.label);
                assert!(!b.scenario.label.contains("/perdec"), "{}", b.scenario.label);
                assert_eq!(
                    b.metrics.to_json().to_string(),
                    p.metrics.to_json().to_string(),
                    "{}: batched diverged from the per-decision reference (shards={shards})",
                    b.scenario.label
                );
                failures += b.metrics.node_failures;
                moves += b.metrics.mobility_moves;
            }
        }
        assert!(failures > 0, "vacuous: no churn fired in any scenario");
        assert!(moves > 0, "vacuous: nothing moved in any scenario");
    }

    #[test]
    fn trace_modes_leave_metrics_byte_identical() {
        // The observability layer's acceptance criterion: arming the
        // tracer (profile or full) under churn + mobility, on the legacy
        // driver and on every shard count, must leave `RunMetrics`
        // byte-identical to the trace-off reference — the obs layer only
        // reads state and never draws RNG — while the traced runs carry
        // a populated `ObsReport` and the trace knob tags the label.
        let mut base = tiny_base();
        base.n_edges = 10; // two clusters → two lanes when sharded
        base.cluster_size = 5;
        base.failure_rate = 3.0;
        base.rejoin_secs = 120.0;
        base.mobility = MobilityModel::RandomWaypoint { speed_mps: 3.0, pause_secs: 0.0 };
        base.mobility_tick_secs = 10.0;
        let sweep = |trace: TraceMode, shards: usize| {
            let mut b = base.clone();
            b.trace = trace;
            b.shards = shards;
            Sweep::new(b).methods(&[Method::Marl, Method::SroleD])
        };
        let (mut failures, mut moves) = (0usize, 0usize);
        for &shards in &[0usize, 1, 8] {
            let off = run_parallel(&sweep(TraceMode::Off, shards).scenarios(), 2);
            for o in &off {
                assert!(o.obs.is_none(), "{}: trace off must carry no report", o.scenario.label);
                assert!(!o.scenario.label.contains("/tr"), "{}", o.scenario.label);
            }
            for mode in [TraceMode::Profile, TraceMode::Full] {
                let traced = run_parallel(&sweep(mode, shards).scenarios(), 2);
                assert_eq!(off.len(), traced.len());
                for (o, t) in off.iter().zip(&traced) {
                    assert!(
                        t.scenario.label.ends_with(&format!("/tr{}", mode.name())),
                        "{}",
                        t.scenario.label
                    );
                    assert_eq!(
                        o.metrics.to_json().to_string(),
                        t.metrics.to_json().to_string(),
                        "{}: tracing perturbed the run (shards={shards})",
                        t.scenario.label
                    );
                    let obs = t.obs.as_ref().expect("traced run must carry a report");
                    assert_eq!(obs.mode, mode);
                    assert!(
                        obs.total_profile().count.iter().sum::<u64>() > 0,
                        "{}: no phase ever timed",
                        t.scenario.label
                    );
                    if mode == TraceMode::Full {
                        assert!(!obs.records.is_empty(), "{}", t.scenario.label);
                    }
                    if shards > 0 {
                        // Two cluster lanes plus the driver row.
                        assert!(obs.lanes.len() >= 3, "{}: {:?}", t.scenario.label, obs.lanes);
                    }
                    failures += t.metrics.node_failures;
                    moves += t.metrics.mobility_moves;
                }
            }
        }
        assert!(failures > 0, "vacuous: no churn fired in any scenario");
        assert!(moves > 0, "vacuous: nothing moved in any scenario");
    }

    /// Serving harness base: two clusters (two lanes when sharded) under
    /// churn + mobility, training waves suppressed by `serving = true`.
    fn serving_base() -> ExperimentConfig {
        let mut base = tiny_base();
        base.n_edges = 10;
        base.cluster_size = 5;
        base.iterations = 1;
        base.serving = true;
        base.request_rate = 0.05;
        base.failure_rate = 3.0;
        base.rejoin_secs = 120.0;
        base.mobility = MobilityModel::RandomWaypoint { speed_mps: 2.0, pause_secs: 0.0 };
        base.mobility_tick_secs = 10.0;
        base
    }

    #[test]
    fn serving_sweeps_are_byte_identical_across_shards_and_trace_modes() {
        // The serving acceptance criterion at harness altitude: unlike
        // training (where the legacy driver and the sharded engine are
        // pinned as separate references), serving runs no waves and
        // draws its request table before the engines diverge, so
        // shards = 0 and every sharded width must agree byte for byte —
        // with or without the tracer armed — under churn + mobility.
        // The serving knob must also tag the label.
        let base = serving_base();
        let sweep = |shards: usize, trace: TraceMode| {
            let mut b = base.clone();
            b.shards = shards;
            b.trace = trace;
            Sweep::new(b).methods(&[Method::Marl, Method::SroleD])
        };
        let reference = run_parallel(&sweep(0, TraceMode::Off).scenarios(), 2);
        let (mut served, mut failures, mut moves) = (0usize, 0usize, 0usize);
        for r in &reference {
            assert!(r.scenario.label.ends_with("/serve"), "{}", r.scenario.label);
            assert!(r.metrics.jct.is_empty(), "{}: serving must suppress waves", r.scenario.label);
            served += r.metrics.requests_served;
            failures += r.metrics.node_failures;
            moves += r.metrics.mobility_moves;
        }
        assert!(served > 0, "vacuous: no request was ever served");
        assert!(failures > 0, "vacuous: no churn fired");
        assert!(moves > 0, "vacuous: nothing moved");
        for &shards in &[1usize, 8] {
            for mode in [TraceMode::Off, TraceMode::Profile, TraceMode::Full] {
                let cell = run_parallel(&sweep(shards, mode).scenarios(), 2);
                assert_eq!(reference.len(), cell.len());
                for (a, b) in reference.iter().zip(&cell) {
                    assert!(
                        b.scenario.label.contains(&format!("/sh{shards}")),
                        "{}",
                        b.scenario.label
                    );
                    assert_eq!(
                        a.metrics.to_json().to_string(),
                        b.metrics.to_json().to_string(),
                        "{}: serving diverged at shards={shards} trace={}",
                        a.scenario.label,
                        mode.name()
                    );
                }
            }
        }
    }

    #[test]
    fn serving_trace_replay_is_byte_identical_across_thread_counts() {
        // Real-trace replay: the trace offsets ARE each cluster's request
        // schedule, and the same sweep must reproduce byte-identically
        // whatever the harness thread count.  Offsets deliberately avoid
        // the 60 s view-refresh / 600 s sample barriers so no request
        // ties an engine barrier event.
        let mut base = serving_base();
        base.arrival = ArrivalProcess::Trace(vec![7.3, 13.9, 101.7, 250.1, 333.3, 487.9]);
        let sw = Sweep::new(base)
            .methods(&[Method::Marl, Method::SroleC, Method::SroleD, Method::Rl]);
        let scenarios = sw.scenarios();
        assert!(scenarios.iter().all(|s| s.cfg.dynamic()), "serving must be dynamic");
        let serial = run_parallel(&scenarios, 1);
        let parallel = run_parallel(&scenarios, 4);
        assert_eq!(serial.len(), parallel.len());
        let mut served = 0usize;
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.scenario.label, p.scenario.label);
            assert!(s.scenario.label.ends_with("/serve"), "{}", s.scenario.label);
            assert_eq!(
                s.metrics.to_json().to_string(),
                p.metrics.to_json().to_string(),
                "{}: trace replay not byte-identical across thread counts",
                s.scenario.label
            );
            served += s.metrics.requests_served;
        }
        assert!(served > 0, "vacuous: trace replay served nothing");
    }

    #[test]
    fn zero_rate_serving_yields_empty_serving_metrics() {
        // Degenerate input: a zero-rate generator produces no requests,
        // so every serving metric must stay at its empty default — on
        // the legacy driver and on the sharded engine alike.
        let mut base = serving_base();
        base.request_rate = 0.0;
        for &shards in &[0usize, 8] {
            let mut b = base.clone();
            b.shards = shards;
            let sw = Sweep::new(b).methods(&[Method::Marl, Method::SroleD]);
            for r in &run_parallel(&sw.scenarios(), 2) {
                assert!(r.scenario.label.ends_with("/serve"), "{}", r.scenario.label);
                assert_eq!(r.metrics.requests_served, 0, "{}", r.scenario.label);
                assert_eq!(r.metrics.requests_rejected, 0, "{}", r.scenario.label);
                assert_eq!(r.metrics.requests_failed, 0, "{}", r.scenario.label);
                assert_eq!(r.metrics.slo_violations, 0, "{}", r.scenario.label);
                assert!(r.metrics.request_latency.is_empty(), "{}", r.scenario.label);
                assert!(r.metrics.request_summary().is_none(), "{}", r.scenario.label);
                assert!(r.metrics.jct.is_empty(), "{}: waves not suppressed", r.scenario.label);
            }
        }
    }

    #[test]
    fn bursty_blast_requests_flow_through_the_serving_pipeline() {
        // Degenerate input: requests arriving inside a Bursty
        // correlated-blast window must be served like any other.
        // Observable at metrics altitude: at equal base rate the 8×
        // blast windows add ~56% more arrivals, so the bursty cell must
        // serve strictly more than the constant cell — which can only
        // happen if blast-window requests traverse the full pipeline —
        // with the latency tail still ordered and SLO accounting sane.
        let mut base = serving_base();
        base.request_rate = 0.1;
        base.failure_rate = 0.0; // isolate the rate shape: no churn losses
        base.mobility = MobilityModel::Static;
        let run = |shape: RateShape| {
            let mut b = base.clone();
            b.rate_shape = shape;
            run_parallel(&Sweep::new(b).methods(&[Method::SroleD]).scenarios(), 1)
        };
        let constant = &run(RateShape::Constant)[0];
        let bursty = &run(RateShape::Bursty)[0];
        assert!(constant.metrics.requests_served > 0, "vacuous: constant cell served nothing");
        assert!(
            bursty.metrics.requests_served > constant.metrics.requests_served,
            "blast windows invisible: {} vs {} served",
            bursty.metrics.requests_served,
            constant.metrics.requests_served
        );
        for r in [constant, bursty] {
            let m = &r.metrics;
            assert_eq!(m.request_latency.len(), m.requests_served, "{}", r.scenario.label);
            let p = m.request_summary().expect("served requests must summarize");
            assert!(p.p50 <= p.p99 && p.p99 <= p.p999, "{}: tail disordered", r.scenario.label);
            assert!(m.slo_violations <= m.requests_served, "{}", r.scenario.label);
        }
        // Fixed seed → the bursty cell itself replays byte-identically.
        let again = &run(RateShape::Bursty)[0];
        assert_eq!(
            bursty.metrics.to_json().to_string(),
            again.metrics.to_json().to_string(),
            "bursty serving run not deterministic"
        );
    }

    #[test]
    fn bench_json_written_with_aggregates() {
        let sw = Sweep::new(tiny_base()).methods(&[Method::Marl]);
        let reports = run_parallel(&sw.scenarios(), 1);
        let dir = std::env::temp_dir();
        let path = write_bench_json("harness_test", &reports, &dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("bench").and_then(|b| b.as_str()), Some("harness_test"));
        let cells = parsed.get("scenarios").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(cells.len(), 1);
        assert!(cells[0].get("wall_ms").and_then(|w| w.as_f64()).unwrap() >= 0.0);
        assert!(parsed.at(&["wall_ms", "p95_ms"]).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rerun_is_bit_identical() {
        let sw = Sweep::new(tiny_base()).methods(&[Method::SroleD]).seeds(&[7, 8]);
        let a = run_parallel(&sw.scenarios(), 2);
        let b = run_parallel(&sw.scenarios(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.metrics.jct, y.metrics.jct);
            assert_eq!(x.metrics.collisions, y.metrics.collisions);
        }
    }

    #[test]
    fn report_table_renders_all_rows() {
        let sw = Sweep::new(tiny_base()).methods(&[Method::Marl]);
        let reports = run_parallel(&sw.scenarios(), 1);
        let t = report_table("test", &reports);
        assert_eq!(t.n_rows(), 1);
        assert!(t.render().contains("MARL"));
    }
}
