//! Experiment coordinator: wires deployment + workload + policy + shields
//! + DES into one measured run per (method, configuration, repetition),
//! exactly the grid the paper's Figures 4–13 sweep.
//!
//! Static configurations replay the paper's pre-batched waves
//! ([`Experiment::run_once`]); configurations with node churn or an
//! online arrival process route through the event-driven [`dynamic`]
//! driver instead.

pub mod dynamic;
pub mod shard;

use crate::cluster::Deployment;
use crate::config::ExperimentConfig;
use crate::dnn::ModelGraph;
use crate::metrics::RunMetrics;
use crate::obs::{self, ObsReport, Recorder, TraceMode};
use crate::rl::{Policy, TabularQ};
use crate::sched::{central_wave, marl_wave, JobSchedule, WaveOutcome};
use crate::shield::{CentralShield, DecentralShield, Shield};
use crate::sim::{Executor, ResourceState};
use crate::util::Rng;
use crate::workload::{Workload, WorkloadSpec};

/// The four compared methods (§V-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Centralized RL at the cluster head.
    Rl,
    /// Multi-agent RL without shielding.
    Marl,
    /// MARL + centralized shield (Algorithm 1).
    SroleC,
    /// MARL + decentralized sub-cluster shields.
    SroleD,
}

impl Method {
    pub const ALL: [Method; 4] = [Method::Rl, Method::Marl, Method::SroleC, Method::SroleD];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Rl => "RL",
            Method::Marl => "MARL",
            Method::SroleC => "SROLE-C",
            Method::SroleD => "SROLE-D",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "rl" | "central" => Some(Method::Rl),
            "marl" => Some(Method::Marl),
            "srole-c" | "srole_c" | "srolec" => Some(Method::SroleC),
            "srole-d" | "srole_d" | "sroled" => Some(Method::SroleD),
            _ => None,
        }
    }

    pub fn shielded(&self) -> bool {
        matches!(self, Method::SroleC | Method::SroleD)
    }
}

/// One experiment: a configuration to run for any method.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub cfg: ExperimentConfig,
}

/// Result of a pooled (multi-repetition) run.
#[derive(Debug)]
pub struct ExperimentResult {
    pub method: Method,
    pub metrics: RunMetrics,
}

impl Experiment {
    pub fn new(cfg: ExperimentConfig) -> Experiment {
        cfg.validate().expect("invalid config");
        Experiment { cfg }
    }

    /// Run `cfg.repetitions` independent repetitions (different seeds, as
    /// the paper repeats each experiment 5 times) and pool the samples.
    pub fn run(&self, method: Method) -> ExperimentResult {
        let mut pooled = RunMetrics::default();
        for rep in 0..self.cfg.repetitions {
            let m = self.run_once(method, self.cfg.seed + 1000 * rep as u64);
            pooled.absorb(&m);
        }
        ExperimentResult { method, metrics: pooled }
    }

    /// [`Experiment::run`] with the observability layer armed on the
    /// *first* repetition (when `cfg.trace != off`): repetition 0 runs
    /// traced, the rest plain, and the pooled metrics are byte-identical
    /// to an untraced [`Experiment::run`] — tracing only reads state and
    /// draws no RNG.
    pub fn run_traced(&self, method: Method) -> (ExperimentResult, Option<ObsReport>) {
        let mut pooled = RunMetrics::default();
        let mut report = None;
        for rep in 0..self.cfg.repetitions {
            let seed = self.cfg.seed + 1000 * rep as u64;
            if rep == 0 {
                let (m, r) = self.run_once_traced(method, seed);
                pooled.absorb(&m);
                report = r;
            } else {
                pooled.absorb(&self.run_once(method, seed));
            }
        }
        (ExperimentResult { method, metrics: pooled }, report)
    }

    /// One measured run with a driver [`Recorder`] installed around the
    /// unchanged [`Experiment::run_once`] (lane recorders are installed
    /// by the sharded engine itself).  With `trace: off` this *is*
    /// `run_once`: no recorder exists and every instrumentation point
    /// stays an inert pointer check.
    pub fn run_once_traced(&self, method: Method, seed: u64) -> (RunMetrics, Option<ObsReport>) {
        if self.cfg.trace == TraceMode::Off {
            return (self.run_once(method, seed), None);
        }
        let mut rec = Recorder::new(self.cfg.trace, obs::DRIVER_LANE);
        let metrics = obs::with_recorder(&mut rec, || self.run_once(method, seed));
        (metrics, Some(rec.into_report()))
    }

    /// One measured run.  Configurations with churn or online arrivals
    /// run on the event-driven dynamic driver; the paper's static setup
    /// keeps the pre-batched wave path (bit-identical to previous
    /// releases).
    pub fn run_once(&self, method: Method, seed: u64) -> RunMetrics {
        if self.cfg.dynamic() {
            return dynamic::run_dynamic(&self.cfg, method, seed);
        }
        let cfg = &self.cfg;
        let mut rng = Rng::new(seed);
        let mut dep = Deployment::generate_spread(
            &mut rng,
            cfg.n_edges,
            cfg.cluster_size,
            cfg.profile.resource_profile(),
            cfg.cluster_spread_m,
        );
        if cfg.dense_links {
            // The dense reference store: identical prices, no RNG draws —
            // the run must replay the sparse model byte-identically.
            dep.topo.use_dense_links();
        }
        let graph = cfg.model.build();
        let spec = WorkloadSpec {
            model: cfg.model,
            jobs_per_cluster: cfg.jobs_per_cluster,
            iterations: cfg.iterations,
            workload: cfg.workload,
            arrival: cfg.arrival.clone(),
        };
        let workload = Workload::generate(&mut rng, &dep, &spec, 500_000.0);

        // The policy is pre-trained offline (§V-A "RL Training") without
        // any shield: every method starts from the same base policy.
        // Shield κ feedback then acts *online* during the measured run
        // ("the shield also notifies the edges ... and assigns a constant
        // negative reward κ"), which is what bends Fig 8's collision
        // counts down as |κ| grows.
        let mut policy = TabularQ::new(cfg.lr, cfg.epsilon);
        pretrain(&mut policy, cfg, &mut rng.fork(0xbeef));
        // Baseline after pretraining: the run's metric must count only
        // forward errors the measured run itself experienced.
        let fwd_errors_baseline = policy.fwd_errors();
        let batch_baseline = policy.batch_stats();

        let mut state = ResourceState::new(&dep);
        // The PageRank background load is already running when the DL
        // jobs arrive — schedulers must see it.
        let pre_placed = crate::sim::engine::place_initial_background(&mut state, &workload);
        let mut metrics = RunMetrics::default();
        let mut all_schedules: Vec<JobSchedule> = Vec::new();

        // One scheduling wave per cluster (its jobs arrive together).
        for (ci, _cluster) in dep.clusters.iter().enumerate() {
            let jobs: Vec<_> =
                workload.dl_jobs.iter().filter(|j| j.cluster == ci).cloned().collect();
            if jobs.is_empty() {
                continue;
            }
            let out = self.run_wave(method, &dep, &mut state, &graph, &jobs, &mut policy, &mut rng);
            metrics.collisions += out.collisions;
            metrics.shield_corrections += out.shield_corrections;
            for s in &out.schedules {
                metrics.decision_secs.push(s.decision_secs);
                metrics.sched_secs.push(s.sched_secs);
                metrics.shield_secs.push(s.shield_secs);
                metrics.memory_violations += s.memory_violations;
            }
            all_schedules.extend(out.schedules);
        }

        // Execute everything on the shared deployment state.
        let mut executor = Executor::new(&dep, &workload, &graph, cfg.reward.alpha);
        // Common sampling horizon across methods: the nominal experiment
        // duration at the target iteration rate (plus slack).
        executor.sample_horizon =
            cfg.iterations as f64 * crate::dnn::profile::TARGET_ITER_SECS * 2.5;
        let report = executor.run_with_background(&mut state, &mut all_schedules, pre_placed);

        // Rewards: the realized training time O closes each episode.
        for s in &all_schedules {
            if let Some(j) = report.jobs.iter().find(|j| j.job_id == s.job.id) {
                policy.learn(&s.episode, j.train_secs.max(1.0), &cfg.reward);
                metrics.jct.push(j.train_secs);
            }
        }
        metrics.qnet_fwd_errors = policy.fwd_errors().saturating_sub(fwd_errors_baseline);
        let (fwds, rows, pads) = policy.batch_stats();
        metrics.qnet_batch_fwds = fwds.saturating_sub(batch_baseline.0);
        metrics.qnet_batch_rows = rows.saturating_sub(batch_baseline.1);
        metrics.qnet_batch_pad_rows = pads.saturating_sub(batch_baseline.2);
        metrics.runtime_overloads = report.runtime_overloads;
        metrics.tasks_per_device = report.tasks_per_device;
        metrics.util_cpu = report.util_cpu;
        metrics.util_mem = report.util_mem;
        metrics.util_bw = report.util_bw;
        metrics.makespan = report.makespan;
        metrics
    }

    fn run_wave(
        &self,
        method: Method,
        dep: &Deployment,
        state: &mut ResourceState,
        graph: &ModelGraph,
        jobs: &[crate::workload::DlJob],
        policy: &mut dyn Policy,
        rng: &mut Rng,
    ) -> WaveOutcome {
        let cfg = &self.cfg;
        match method {
            Method::Rl => central_wave(dep, state, graph, jobs, policy, &cfg.reward, rng),
            Method::Marl => marl_wave(
                dep, state, graph, jobs, policy, None, &cfg.reward, cfg.refresh_rounds, rng,
            ),
            Method::SroleC => {
                let mut shield = CentralShield::new();
                marl_wave(
                    dep, state, graph, jobs, policy,
                    Some(&mut shield as &mut dyn Shield),
                    &cfg.reward, cfg.refresh_rounds, rng,
                )
            }
            Method::SroleD => {
                let members = dep.clusters[jobs[0].cluster].members.clone();
                let mut shield = DecentralShield::new(dep, &members, cfg.subclusters);
                marl_wave(
                    dep, state, graph, jobs, policy,
                    Some(&mut shield as &mut dyn Shield),
                    &cfg.reward, cfg.refresh_rounds, rng,
                )
            }
        }
    }
}

/// Offline pre-training (§V-A "RL Training"): small random edge
/// configurations — 2–10 nodes, CPU ∈ [0.5, 2] GHz-equivalents,
/// memory ∈ [64, 4096] MB, pairwise BW ∈ [128, 1000] Mbps — each episode
/// schedules a concurrent wave of jobs (MARL, no shield) and learns from
/// the simulated training times.
pub fn pretrain(policy: &mut dyn Policy, cfg: &ExperimentConfig, rng: &mut Rng) {
    let graph = cfg.model.build();
    for _ in 0..cfg.pretrain_episodes {
        let n = rng.range_i64(2, 10) as usize;
        let dep = pretrain_deployment(rng, n);
        let mut state = ResourceState::new(&dep);
        // Concurrent jobs: collisions (and hence κ feedback) only arise
        // when several agents decide simultaneously.
        let n_jobs = cfg.jobs_per_cluster.max(2);
        let jobs: Vec<crate::workload::DlJob> = (0..n_jobs)
            .map(|id| crate::workload::DlJob {
                id,
                cluster: 0,
                owner: *rng.choose(&dep.clusters[0].members),
                model: cfg.model,
                arrival: 0.0,
                iterations: 3,
            })
            .collect();
        let out = marl_wave(
            &dep, &mut state, &graph, &jobs, policy, None, &cfg.reward, cfg.refresh_rounds, rng,
        );
        let spec = WorkloadSpec {
            model: cfg.model,
            jobs_per_cluster: 0,
            iterations: 3,
            workload: rng.range_f64(0.6, 1.0),
            arrival: crate::workload::ArrivalProcess::Batched { window: 1.0 },
        };
        let wl = Workload::generate(rng, &dep, &spec, 10_000.0);
        let mut schedules = out.schedules;
        let exec = Executor::new(&dep, &wl, &graph, cfg.reward.alpha);
        let report = exec.run(&mut state, &mut schedules);
        for s in &schedules {
            if let Some(j) = report.jobs.iter().find(|j| j.job_id == s.job.id) {
                // Scale 3-iteration time to the configured horizon so the
                // reward magnitude matches the measured runs.
                let o = j.train_secs * cfg.iterations as f64 / 3.0;
                policy.learn(&s.episode, o.max(1.0), &cfg.reward);
            }
        }
    }
}

/// Random pretraining deployment per §V-A's RL-training ranges.
fn pretrain_deployment(rng: &mut Rng, n: usize) -> Deployment {
    use crate::cluster::{ClusterSpec, EdgeNode, Resources};
    use crate::net::Topology;
    let topo = Topology::generate(rng, n, 20.0, 50.0, &[128.0, 256.0, 512.0, 1000.0], 0.002);
    let nodes: Vec<EdgeNode> = (0..n)
        .map(|id| EdgeNode {
            id,
            caps: Resources {
                // CPU [0.5, 2] GHz on a 2 GHz reference -> host ratio.
                cpu: rng.range_f64(0.25, 1.0),
                mem: rng.range_f64(64.0, 4096.0),
                bw: *rng.choose(&[128.0, 256.0, 512.0, 1000.0]),
            },
        })
        .collect();
    let head = (0..n)
        .max_by(|&a, &b| {
            (nodes[a].caps.cpu * nodes[a].caps.mem)
                .partial_cmp(&(nodes[b].caps.cpu * nodes[b].caps.mem))
                .unwrap()
        })
        .unwrap();
    let clusters = vec![ClusterSpec { members: (0..n).collect(), head }];
    Deployment::new(nodes, topo, clusters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;

    fn quick_cfg() -> ExperimentConfig {
        ExperimentConfig {
            n_edges: 10,
            cluster_size: 5,
            model: ModelKind::Rnn,
            iterations: 5,
            pretrain_episodes: 30,
            repetitions: 1,
            ..Default::default()
        }
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
        assert!(Method::SroleC.shielded());
        assert!(!Method::Marl.shielded());
    }

    #[test]
    fn all_methods_complete_all_jobs() {
        let exp = Experiment::new(quick_cfg());
        for m in Method::ALL {
            let r = exp.run_once(m, 3);
            assert_eq!(r.jct.len(), 2 * 3, "{}: wrong job count", m.name());
            assert!(r.jct.iter().all(|&t| t > 0.0));
            assert!(!r.decision_secs.is_empty());
        }
    }

    #[test]
    fn shielded_methods_report_shield_time() {
        let exp = Experiment::new(quick_cfg());
        let c = exp.run_once(Method::SroleC, 5);
        let marl = exp.run_once(Method::Marl, 5);
        assert!(c.mean_shield_secs() > 0.0);
        assert_eq!(marl.mean_shield_secs(), 0.0);
    }

    #[test]
    fn rl_overhead_exceeds_marl() {
        // Fig 7 ordering: RL scheduling time > MARL (head serializes jobs
        // over the whole cluster).
        let exp = Experiment::new(quick_cfg());
        let rl = exp.run_once(Method::Rl, 7);
        let marl = exp.run_once(Method::Marl, 7);
        let rl_decision: f64 =
            rl.decision_secs.iter().sum::<f64>() / rl.decision_secs.len() as f64;
        let marl_decision: f64 =
            marl.decision_secs.iter().sum::<f64>() / marl.decision_secs.len() as f64;
        assert!(rl_decision > marl_decision, "rl={rl_decision} marl={marl_decision}");
    }

    #[test]
    fn deterministic_given_seed() {
        let exp = Experiment::new(quick_cfg());
        let a = exp.run_once(Method::SroleC, 11);
        let b = exp.run_once(Method::SroleC, 11);
        assert_eq!(a.jct, b.jct);
        assert_eq!(a.collisions, b.collisions);
    }

    #[test]
    fn repetitions_pool_samples() {
        let mut cfg = quick_cfg();
        cfg.repetitions = 2;
        let exp = Experiment::new(cfg);
        let r = exp.run(Method::Marl);
        assert_eq!(r.metrics.jct.len(), 2 * 6);
    }
}
