//! Region-sharded tick engine: one dynamic scenario across OS threads.
//!
//! The legacy driver (`coordinator::dynamic`, `shards = 0`) runs a whole
//! scenario on one time-ordered queue.  That is the right reference
//! semantics, but it caps a 100k-node run at one core.  This driver
//! shards the event loop by *shield region* — one lane per cluster,
//! which is exactly the granularity at which the paper's agents, shields
//! and placements are confined:
//!
//! * **Lane-local events** (`JobArrival`, `IterEnd`, `BgStart`, `BgEnd`,
//!   and on serving runs `RequestArrival` / `RequestDone`) touch only
//!   their cluster's nodes — placements are always within-cluster — so
//!   each lane owns a private event queue, RNG stream, policy, shield
//!   and an O(cluster)-memory [`ResourceState::for_cluster`] slice, and
//!   advances independently.
//! * **Cross-region events** (`Sample`, `ViewRefresh`, `NodeFail`,
//!   `NodeJoin`, `MobilityTick`) live on a driver-owned queue.  Each
//!   iteration the driver peeks the next cross-region time `T`, advances
//!   every lane through its events with `t <= T` (the epoch), then
//!   handles the barrier event serially with exclusive access to every
//!   lane.  Joining the worker scope *is* the epoch barrier — no locks,
//!   no atomics, no channel.
//!
//! Determinism: the setup replays the legacy RNG draw order (deployment,
//! workload, mobility fork, pretraining fork, churn schedule), then
//! forks one child stream per lane in cluster order.  Lane decisions
//! draw only from their lane's stream, and barrier handlers use the
//! affected lane's stream, so metrics are **byte-identical across shard
//! counts**: `shards = 1` runs the lanes inline on the calling thread
//! and is the pinned serial reference for `shards = N` (equivalence
//! tests below).  `shards = 0` keeps the single-stream legacy driver
//! bit-for-bit untouched; its interleaved draw order is a different (also
//! deterministic) stream, so the two engines are separate baselines.
//!
//! **Serving runs are the exception**: with `workload = "serving"` no
//! training wave ever fires, both engines share the same setup prefix,
//! the request schedule comes off a dedicated fork, every per-request
//! draw uses a private `(seed, request id)` stream, and neither engine
//! breaks its loop early — so serving `RunMetrics` are byte-identical
//! across `shards = 0` **and** every `shards >= 1`, unlike training
//! (pinned by the `/serve` harness scenarios).
//!
//! Ties: a lane event at exactly the barrier time fires before the
//! barrier event (lanes advance through `t <= T` first).  This rule is
//! part of the engine's contract — it is what makes the epoch partition
//! independent of the shard count.
//!
//! # Shield tree (`tree_fanout >= 1`)
//!
//! The serial barrier is the engine's Amdahl term: every cross-region
//! event walks O(n) state on one thread while the workers idle.  With a
//! [`ShieldTree`] (clusters grouped under super-shields, see
//! `shield::tree`), the driver buckets barrier work by super-shield
//! group and handles groups concurrently in a `thread::scope`
//! ([`dispatch_groups`]) — each group worker touches only its own
//! lanes' rng/policy/shield/state plus shared read-only context:
//!
//! * `Sample` / `ViewRefresh`: per-lane reads collected group-parallel,
//!   folded into the metrics vectors / stale view serially in cluster
//!   order (the exact push order of the flat loop).
//! * `MobilityTick`: the topology/membership rebuild stays serial, then
//!   the per-lane work (region handoffs, migration scan + reschedule,
//!   overload edges) runs group-parallel; counters fold in cluster
//!   order.
//! * `NodeFail` / `NodeJoin`: maximal runs of consecutive
//!   single-victim fail/join events are *batched* — guards and
//!   membership mutations run serially in time order (the root pass),
//!   then each event's lane-confined phase runs group-parallel.  A
//!   batch only forms when no lane has a queued event at or before the
//!   batch's last time (so the epoch interleaving is provably
//!   unaffected) and each cluster appears at most once (so each
//!   cluster's membership slice equals what the serial handler saw).
//!   Blast-radius (multi-victim) events always escalate to the serial
//!   root pass, as does anything that fails the batch conditions.
//!
//! Every group-parallel fold happens in fixed cluster/event order and
//! no RNG moves between lanes, so `RunMetrics` stays **byte-identical
//! for every `tree_fanout`** — fanout 0 keeps the flat serial driver
//! verbatim as the pinned reference (equivalence tests below and in
//! `harness`).

use crate::cluster::{Deployment, Membership, NodeId, ResourceKind, Resources};
use crate::config::ExperimentConfig;
use crate::dnn::ModelGraph;
use crate::metrics::RunMetrics;
use crate::net::mobility::DynamicTopology;
use crate::obs;
use crate::rl::{Policy, TabularQ};
use crate::sched::{
    central_wave_dynamic, marl_wave_dynamic, noisy_demand, place_request, reschedule_migrated,
    reschedule_stranded, DecisionConfig, DecisionMode, Stranded, WaveOutcome,
};
use crate::shield::{CentralShield, DecentralShield, ShieldTree};
use crate::sim::engine::SAMPLE_PERIOD_SECS;
use crate::sim::event::{Event, EventKind, EventQueue};
use crate::sim::{timing, ResourceState, TaskHandle};
use crate::util::Rng;
use crate::workload::serving::{generate_requests, Request};
use crate::workload::{Workload, WorkloadSpec};

use std::collections::BTreeMap;

use super::dynamic::{
    alive_head, build_waves, ClusterShield, LiveRequest, Run, Wave, REQ_STREAM_BASE, SERVING_FORK,
    VIEW_REFRESH_SECS,
};
use super::{pretrain, Method};

/// One shield region's independent slice of the simulation: private
/// queue, RNG stream, policy, shield, and cluster-sliced resource state.
struct Lane {
    cluster: usize,
    queue: EventQueue,
    rng: Rng,
    policy: TabularQ,
    fwd_baseline: usize,
    batch_baseline: (usize, usize, usize),
    shield: ClusterShield,
    state: ResourceState,
    /// Global indices of this cluster's background segments, ascending.
    /// Lane `BgStart`/`BgEnd` payloads are indices into this list, so
    /// lane queues never reference another lane's tables.
    own_bg: Vec<usize>,
    bg_slots: Vec<Option<TaskHandle>>,
    /// Indexed by global job id; only this cluster's jobs are `Some`.
    runs: Vec<Option<Run>>,
    /// In-flight inference requests hosted in this cluster (serving
    /// runs), keyed by global request id.
    live: BTreeMap<usize, LiveRequest>,
    /// Per tracked node (`state.node_ids()` order, base-relative): when
    /// the node's serving decision pipe frees up — the queueing term of
    /// the request latency account.
    origin_busy: Vec<f64>,
    /// This cluster's jobs and requests not yet completed.
    remaining: usize,
    /// Set when the lane's last job completes past the horizon — the
    /// lane-local analogue of the legacy driver's loop `break`.
    done: bool,
    /// Per tracked node (`state.node_ids()` order): overload edge
    /// detector state for the runtime_overloads transition count.
    was_overloaded: Vec<bool>,
    metrics: RunMetrics,
    /// Per-lane trace recorder, sharing the driver's wall anchor; `None`
    /// when tracing is off ([`advance_lane`] then installs nothing).
    /// Merged into the driver recorder in cluster order at the end of
    /// the run — attribution is independent of worker-thread chunking.
    obs: Option<Box<obs::Recorder>>,
}

/// Shared read-only context for one epoch.  Everything here is frozen
/// while lanes advance; barrier handlers (which mutate the deployment,
/// membership and view) run after the scope join with `&mut` access.
#[derive(Clone, Copy)]
struct Ctx<'a> {
    dep: &'a Deployment,
    membership: &'a Membership,
    graph: &'a ModelGraph,
    workload: &'a Workload,
    waves: &'a [Wave],
    /// Serving request table (empty on training runs); lane request
    /// events index into it by global request id.
    requests: &'a [Request],
    /// Stale state view (paper §III) — frozen between barriers, so
    /// lane-confined admission gates can read it while lanes advance.
    view_demand: &'a [Resources],
    /// The run seed: per-request private RNG streams derive from it.
    seed: u64,
    cfg: &'a ExperimentConfig,
    method: Method,
    horizon: f64,
    n_clusters: usize,
    dc: DecisionConfig,
}

/// Flag overload transitions on the lane's own nodes.  Placements never
/// leave a cluster, so a node's utilization only changes at its own
/// lane's events or at barrier events handled with that lane borrowed —
/// checking lane-locally counts exactly the transitions the legacy
/// full-deployment scan would, independent of shard count.
fn check_lane_overloads(lane: &mut Lane, alpha: f64) {
    let base = lane.state.base();
    for n in lane.state.node_ids() {
        let now = lane.state.actual_overloaded(n, alpha);
        if now && !lane.was_overloaded[n - base] {
            lane.metrics.runtime_overloads += 1;
        }
        lane.was_overloaded[n - base] = now;
    }
}

/// Drain one lane's queue through every event with `t <= until`.  When
/// tracing is armed the lane's recorder is installed around the drain
/// (worker threads have no thread-local recorder of their own), so lane
/// spans land on the lane's own profile row.
fn advance_lane(lane: &mut Lane, ctx: Ctx<'_>, until: f64) {
    if let Some(mut rec) = lane.obs.take() {
        obs::with_recorder(&mut rec, || advance_lane_events(lane, ctx, until));
        lane.obs = Some(rec);
    } else {
        advance_lane_events(lane, ctx, until);
    }
}

/// The actual drain, mirroring the legacy handlers for the four
/// lane-local kinds.
fn advance_lane_events(lane: &mut Lane, ctx: Ctx<'_>, until: f64) {
    let alpha = ctx.cfg.reward.alpha;
    while !lane.done {
        match lane.queue.peek() {
            Some(head) if head.t <= until => {}
            _ => break,
        }
        let ev = lane.queue.pop().expect("peeked event vanished");
        obs::sim_time(ev.t);
        let _ev_span = obs::span(obs::Phase::EventDispatch);
        match ev.kind {
            EventKind::JobArrival { wave } => {
                let w = &ctx.waves[wave];
                obs::event(obs::TraceKind::Arrival, ev.t, w.cluster as f64, w.jobs.len() as f64);
                let out: WaveOutcome = {
                    let shield = lane.shield.as_dyn();
                    let policy: &mut dyn Policy = &mut lane.policy;
                    match ctx.method {
                        Method::Rl => central_wave_dynamic(
                            ctx.dep, ctx.membership, &mut lane.state, ctx.graph, &w.jobs,
                            policy, &ctx.cfg.reward, ctx.dc, &mut lane.rng,
                        ),
                        Method::Marl | Method::SroleC | Method::SroleD => marl_wave_dynamic(
                            ctx.dep, ctx.membership, &mut lane.state, ctx.graph, &w.jobs,
                            policy, shield, &ctx.cfg.reward, ctx.cfg.refresh_rounds, ctx.dc,
                            &mut lane.rng,
                        ),
                    }
                };
                lane.metrics.collisions += out.collisions;
                lane.metrics.shield_corrections += out.shield_corrections;
                let cl = w.cluster as f64;
                obs::event(obs::TraceKind::Placement, ev.t, cl, out.schedules.len() as f64);
                if out.collisions > 0 {
                    obs::event(obs::TraceKind::Collision, ev.t, cl, out.collisions as f64);
                }
                if out.shield_corrections > 0 {
                    obs::event(obs::TraceKind::Correction, ev.t, cl, out.shield_corrections as f64);
                }
                for s in out.schedules {
                    let ji = s.job.id;
                    let start = ev.t + s.decision_secs;
                    lane.queue.push(start, EventKind::IterEnd { job: ji });
                    lane.runs[ji] = Some(Run { sched: s, start, iters_done: 0, done: false });
                }
                check_lane_overloads(lane, alpha);
            }
            EventKind::IterEnd { job } => {
                let run = lane.runs[job].as_mut().expect("IterEnd for an unscheduled job");
                if run.done {
                    continue;
                }
                if ev.t > run.start {
                    run.iters_done += 1;
                }
                if run.iters_done >= run.sched.job.iterations {
                    run.done = true;
                    lane.remaining -= 1;
                    for &h in &run.sched.handles {
                        lane.state.release(h);
                    }
                    run.sched.handles.clear();
                    let train_secs = ev.t - run.start;
                    lane.policy.learn(&run.sched.episode, train_secs.max(1.0), &ctx.cfg.reward);
                    lane.metrics.jct.push(train_secs);
                    lane.metrics.decision_secs.push(run.sched.decision_secs);
                    lane.metrics.sched_secs.push(run.sched.sched_secs);
                    lane.metrics.shield_secs.push(run.sched.shield_secs);
                    lane.metrics.memory_violations += run.sched.memory_violations;
                    lane.metrics.makespan = lane.metrics.makespan.max(ev.t);
                    check_lane_overloads(lane, alpha);
                    if lane.remaining == 0 && ev.t >= ctx.horizon {
                        lane.done = true;
                    }
                } else {
                    let head = alive_head(ctx.dep, ctx.membership, run.sched.job.cluster);
                    let mut dt = timing::iteration_secs(
                        ctx.dep,
                        &lane.state,
                        ctx.graph,
                        &run.sched.placement,
                        run.sched.job.owner,
                        head,
                        ctx.n_clusters,
                    );
                    if run.iters_done == 0 {
                        dt += timing::pipeline_fill_secs(
                            ctx.dep,
                            &lane.state,
                            ctx.graph,
                            &run.sched.placement,
                        );
                    }
                    lane.queue.push(ev.t + dt.max(1e-6), EventKind::IterEnd { job });
                }
            }
            EventKind::BgStart { bg } => {
                let gi = lane.own_bg[bg];
                let b = &ctx.workload.background[gi];
                // A segment destined for a dead node is lost, not queued.
                if ctx.membership.is_alive(b.node) {
                    let h = lane.state.place(b.node, b.demand, b.demand, false);
                    lane.bg_slots[bg] = Some(h);
                    lane.queue.push(b.end.max(ev.t), EventKind::BgEnd { bg });
                    check_lane_overloads(lane, alpha);
                }
            }
            EventKind::BgEnd { bg } => {
                if let Some(h) = lane.bg_slots[bg].take() {
                    lane.state.release(h);
                }
                check_lane_overloads(lane, alpha);
            }
            EventKind::RequestArrival { req } => {
                // Mirrors the legacy driver's handler exactly: the
                // lane's state slice and the frozen stale view hold the
                // same values for this cluster's nodes, and every RNG
                // draw comes from the request's private stream.
                let r = &ctx.requests[req];
                let base = lane.state.base();
                let queue_wait = (lane.origin_busy[r.origin - base] - ev.t).max(0.0);
                let mut req_rng = Rng::with_stream(ctx.seed, REQ_STREAM_BASE + req as u64);
                let out = {
                    let shield = lane.shield.as_dyn();
                    let policy: &mut dyn Policy = &mut lane.policy;
                    place_request(
                        ctx.dep, ctx.membership, &lane.state, &ctx.graph.layers[0],
                        ctx.view_demand, req, r.origin, &r.demand, policy, shield,
                        &ctx.cfg.reward, &mut req_rng,
                    )
                };
                lane.metrics.collisions += out.collisions;
                lane.metrics.shield_corrections += out.corrections;
                let decision = out.sched_secs + out.shield_secs;
                lane.origin_busy[r.origin - base] = ev.t + queue_wait + decision;
                match out.target {
                    None => {
                        lane.metrics.requests_rejected += 1;
                        lane.remaining -= 1;
                    }
                    Some(host) => {
                        let actual = noisy_demand(&r.demand, &mut req_rng);
                        let h = lane.state.place(host, r.demand, actual, true);
                        let transfer = ctx.dep.topo.transfer_secs(r.origin, host, r.mb, 1)
                            / lane.state.bw_share(r.origin).min(lane.state.bw_share(host));
                        let service = r.service_secs
                            * (r.demand.cpu / lane.state.cpu_share(host, r.demand.cpu)).max(1.0)
                            * lane.state.mem_pressure(host);
                        let latency = queue_wait + decision + transfer + service;
                        lane.live.insert(req, LiveRequest { handle: h, host, latency });
                        lane.queue.push(ev.t + latency, EventKind::RequestDone { req });
                        check_lane_overloads(lane, alpha);
                    }
                }
            }
            EventKind::RequestDone { req } => {
                // Already evicted by a mid-service host failure.
                let Some(lr) = lane.live.remove(&req) else { continue };
                lane.state.release(lr.handle);
                lane.metrics.request_latency.push(lr.latency);
                lane.metrics.requests_served += 1;
                if lr.latency > ctx.cfg.slo_secs {
                    lane.metrics.slo_violations += 1;
                }
                lane.metrics.makespan = lane.metrics.makespan.max(ev.t);
                // Never sets `lane.done`: serving runs drain in both
                // engines (see the module docs' serving exception).
                lane.remaining -= 1;
                check_lane_overloads(lane, alpha);
            }
            _ => unreachable!("cross-region event in a lane queue"),
        }
    }
}

/// Advance every lane through its events with `t <= until`.  Lanes are
/// mutually independent between barriers, so chunking them across a
/// thread scope is race-free by construction; the scope join is the
/// epoch barrier.  `shards = 1` runs inline — same code path, same
/// results, no threads.
fn advance_all(lanes: &mut [Lane], ctx: Ctx<'_>, until: f64, shards: usize) {
    let workers = shards.min(lanes.len()).max(1);
    if workers <= 1 {
        for lane in lanes.iter_mut() {
            advance_lane(lane, ctx, until);
        }
        return;
    }
    let chunk = (lanes.len() + workers - 1) / workers;
    std::thread::scope(|s| {
        for group in lanes.chunks_mut(chunk) {
            s.spawn(move || {
                for lane in group {
                    advance_lane(lane, ctx, until);
                }
            });
        }
    });
}

// ---------------------------------------------------------------------
// Shield-tree group dispatch (`tree_fanout >= 1`)
// ---------------------------------------------------------------------

/// Counters produced by one lane-confined phase of a barrier event,
/// destined for the driver's `RunMetrics`.  Folded serially in a fixed
/// event/cluster order after the scope join, so the merged totals never
/// depend on worker-thread interleaving.
#[derive(Default, Clone, Copy)]
struct LaneOutcome {
    handoffs: usize,
    collisions: usize,
    corrections: usize,
    rescheduled: usize,
    migrated: usize,
}

/// The lane-confined remainder of one batched churn event, planned by
/// the serial root pass (which already applied the membership change).
#[derive(Clone, Copy)]
enum PlannedChurn {
    Fail { victim: NodeId },
    Join { node: NodeId },
}

/// Run one group-dispatch work item against `lane` with the lane's
/// recorder installed (worker threads have no thread-local recorder of
/// their own), under a [`obs::Phase::GroupDispatch`] span so tree
/// barrier work is attributed to the lanes it actually touched.
fn with_group_span<R>(lane: &mut Lane, sim_t: f64, f: impl FnOnce(&mut Lane) -> R) -> R {
    if let Some(mut rec) = lane.obs.take() {
        let out = obs::with_recorder(&mut rec, || {
            obs::sim_time(sim_t);
            let _s = obs::span(obs::Phase::GroupDispatch);
            f(lane)
        });
        lane.obs = Some(rec);
        out
    } else {
        f(lane)
    }
}

/// Run `f` once per lane, with lanes bucketed by super-shield group and
/// groups chunked across at most `shards` worker threads — the tree
/// analogue of [`advance_all`].  Lanes sort into group order (stable,
/// so ascending cluster within a group), each group stays whole on one
/// worker, and the scope join is the barrier.  Results are returned in
/// **cluster order** regardless of grouping or chunking; `f` itself
/// must not depend on cross-lane state (the callers' lane phases touch
/// only their own lane plus shared read-only context).  One worker (or
/// one group) runs inline — same code path, no threads.
fn dispatch_groups<T, F>(
    lanes: &mut [Lane],
    tree: &ShieldTree,
    shards: usize,
    sim_t: f64,
    f: F,
) -> Vec<T>
where
    T: Send,
    F: Fn(&mut Lane) -> T + Sync,
{
    let n = lanes.len();
    let mut refs: Vec<&mut Lane> = lanes.iter_mut().collect();
    refs.sort_by_key(|l| tree.group_of[l.cluster]);
    // Contiguous per-group runs of the sorted lane references.
    let mut slices: Vec<&mut [&mut Lane]> = Vec::with_capacity(tree.n_groups);
    let mut rest = refs.as_mut_slice();
    while !rest.is_empty() {
        let g = tree.group_of[rest[0].cluster];
        let len = rest.iter().take_while(|l| tree.group_of[l.cluster] == g).count();
        let (head, tail) = rest.split_at_mut(len);
        slices.push(head);
        rest = tail;
    }
    let workers = shards.min(slices.len()).max(1);
    let mut out: Vec<(usize, T)> = Vec::with_capacity(n);
    if workers <= 1 {
        for slice in slices.iter_mut() {
            for lane in slice.iter_mut() {
                let r = with_group_span(lane, sim_t, |l| f(l));
                out.push((lane.cluster, r));
            }
        }
    } else {
        let chunk = (slices.len() + workers - 1) / workers;
        let fref = &f;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(workers);
            for chunk_slices in slices.chunks_mut(chunk) {
                handles.push(s.spawn(move || {
                    let mut res: Vec<(usize, T)> = Vec::new();
                    for slice in chunk_slices.iter_mut() {
                        for lane in slice.iter_mut() {
                            let r = with_group_span(lane, sim_t, |l| fref(l));
                            res.push((lane.cluster, r));
                        }
                    }
                    res
                }));
            }
            for h in handles {
                out.extend(h.join().expect("group worker panicked"));
            }
        });
    }
    out.sort_by_key(|(c, _)| *c);
    out.into_iter().map(|(_, r)| r).collect()
}

/// Read-only per-lane sample collection (tree path): the same per-node
/// values the flat handler pushes, gathered per cluster so groups can
/// read concurrently while the driver folds in cluster order.
fn sample_lane_phase(lane: &Lane) -> [Vec<f64>; 4] {
    let mut tasks = Vec::new();
    let mut cpu = Vec::new();
    let mut mem = Vec::new();
    let mut bw = Vec::new();
    for n in lane.state.node_ids() {
        tasks.push(lane.state.task_count(n) as f64);
        cpu.push(lane.state.actual_util(n, ResourceKind::Cpu).clamp(0.0, 2.0));
        mem.push(lane.state.actual_util(n, ResourceKind::Mem).clamp(0.0, 2.0));
        bw.push(lane.state.actual_util(n, ResourceKind::Bw).clamp(0.0, 2.0));
    }
    [tasks, cpu, mem, bw]
}

/// Kill the lane's in-flight requests served by `victim` (mid-service
/// host failure): open-loop clients never retry, and each orphaned
/// `RequestDone` event later no-ops against the live map.  Runs between
/// the background release and the strand scan, exactly where the legacy
/// driver does it.
fn fail_lane_requests(lane: &mut Lane, victim: NodeId) {
    if lane.live.is_empty() {
        return;
    }
    let lost: Vec<usize> =
        lane.live.iter().filter(|(_, lr)| lr.host == victim).map(|(&id, _)| id).collect();
    for id in lost {
        let lr = lane.live.remove(&id).unwrap();
        lane.state.release(lr.handle);
        lane.metrics.requests_failed += 1;
        lane.remaining -= 1;
    }
}

/// Lane-confined phase of one batched single-victim `NodeFail`:
/// everything the flat handler does after the membership mutation —
/// shield update, background release, strand scan, reschedule,
/// placement, decision-cost charge, overload edges.  `membership`
/// already reflects the whole batch, but the batch builder admits at
/// most one event per cluster, so this cluster's slice of it (the only
/// part any of these reads touch) is exactly what the flat handler saw.
#[allow(clippy::too_many_arguments)]
fn fail_lane_phase(
    lane: &mut Lane,
    victim: NodeId,
    dep: &Deployment,
    membership: &Membership,
    graph: &ModelGraph,
    workload: &Workload,
    view_demand: &[Resources],
    cfg: &ExperimentConfig,
    dc: DecisionConfig,
) -> LaneOutcome {
    let cluster = lane.cluster;
    let mut out = LaneOutcome::default();
    match &mut lane.shield {
        ClusterShield::Central(s) => {
            s.set_alive(Some(membership.alive_cluster_set(cluster).clone()));
        }
        ClusterShield::Decentral(s) => {
            s.node_failed(dep, victim);
        }
        ClusterShield::None => {}
    }
    for (li, &gi) in lane.own_bg.iter().enumerate() {
        if workload.background[gi].node == victim {
            if let Some(h) = lane.bg_slots[li].take() {
                lane.state.release(h);
            }
        }
    }
    fail_lane_requests(lane, victim);
    let mut stranded: Vec<Stranded> = Vec::new();
    for (ji, run) in lane.runs.iter_mut().enumerate() {
        let Some(run) = run else { continue };
        if run.done {
            continue;
        }
        for (layer_id, &host) in run.sched.placement.iter().enumerate() {
            if host == victim {
                lane.state.release(run.sched.handles[layer_id]);
                stranded.push(Stranded { job: ji, owner: run.sched.job.owner, layer_id });
            }
        }
    }
    if !stranded.is_empty() {
        let outcome = {
            let shield = lane.shield.as_dyn();
            let policy: &mut dyn Policy = &mut lane.policy;
            reschedule_stranded(
                dep, membership, &lane.state, graph, view_demand, &stranded, victim, policy,
                shield, &cfg.reward, dc, &mut lane.rng,
            )
        };
        out.collisions = outcome.collisions;
        out.corrections = outcome.corrections;
        out.rescheduled = stranded.len();
        for (s, &target) in stranded.iter().zip(&outcome.targets) {
            let target = if target == usize::MAX {
                membership.alive_members(cluster)[0]
            } else {
                target
            };
            let est = graph.layers[s.layer_id].demand();
            let actual = noisy_demand(&est, &mut lane.rng);
            let h = lane.state.place(target, est, actual, true);
            let run = lane.runs[s.job].as_mut().unwrap();
            run.sched.placement[s.layer_id] = target;
            run.sched.handles[s.layer_id] = h;
        }
        let mut charged: Vec<usize> = stranded.iter().map(|s| s.job).collect();
        charged.sort_unstable();
        charged.dedup();
        for ji in charged {
            let run = lane.runs[ji].as_mut().unwrap();
            run.sched.decision_secs += outcome.sched_secs + outcome.shield_secs;
            run.sched.sched_secs += outcome.sched_secs;
            run.sched.shield_secs += outcome.shield_secs;
        }
    }
    check_lane_overloads(lane, cfg.reward.alpha);
    out
}

/// Lane-confined phase of one batched `NodeJoin`: the shield update
/// (the root pass already applied `membership.join`).
fn join_lane_phase(lane: &mut Lane, node: NodeId, dep: &Deployment, membership: &Membership) {
    match &mut lane.shield {
        ClusterShield::Central(s) => {
            s.set_alive(Some(membership.alive_cluster_set(lane.cluster).clone()));
        }
        ClusterShield::Decentral(s) => {
            s.node_joined(dep, node);
        }
        ClusterShield::None => {}
    }
}

/// Per-lane phase of a `MobilityTick` after the serial topology /
/// membership rebuild: region handoffs for this cluster's moved nodes,
/// the migration scan + reschedule, and the overload edge check.  The
/// flat handler runs these as three cluster-order loops; per-lane
/// reordering is sound because each piece touches only its own lane
/// (plus shared read-only context) and the within-lane order —
/// handoffs, then migration, then overloads — is preserved.
#[allow(clippy::too_many_arguments)]
fn mobility_lane_phase(
    lane: &mut Lane,
    moved: &[NodeId],
    dep: &Deployment,
    membership: &Membership,
    graph: &ModelGraph,
    view_demand: &[Resources],
    cfg: &ExperimentConfig,
    dc: DecisionConfig,
) -> LaneOutcome {
    let mut out = LaneOutcome::default();
    if !moved.is_empty() {
        if let ClusterShield::Decentral(s) = &mut lane.shield {
            out.handoffs = s.nodes_moved(dep, moved);
        }
    }
    let mut stranded: Vec<Stranded> = Vec::new();
    for (ji, run) in lane.runs.iter().enumerate() {
        let Some(run) = run else { continue };
        let owner = run.sched.job.owner;
        if run.done || !membership.is_alive(owner) {
            continue;
        }
        if membership.alive_neighbors(owner).is_empty() {
            continue;
        }
        for (layer_id, &host) in run.sched.placement.iter().enumerate() {
            let reachable =
                host == owner || membership.alive_neighbors(owner).binary_search(&host).is_ok();
            if !reachable && membership.is_alive(host) {
                stranded.push(Stranded { job: ji, owner, layer_id });
            }
        }
    }
    if !stranded.is_empty() {
        let mut old_hosts: Vec<NodeId> = Vec::with_capacity(stranded.len());
        for s in &stranded {
            let run = lane.runs[s.job].as_mut().unwrap();
            old_hosts.push(run.sched.placement[s.layer_id]);
            lane.state.release(run.sched.handles[s.layer_id]);
        }
        let outcome = {
            let shield = lane.shield.as_dyn();
            let policy: &mut dyn Policy = &mut lane.policy;
            reschedule_migrated(
                dep, membership, &lane.state, graph, view_demand, &stranded, policy, shield,
                &cfg.reward, dc, &mut lane.rng,
            )
        };
        out.collisions = outcome.collisions;
        out.corrections = outcome.corrections;
        for ((s, &target), &old) in stranded.iter().zip(&outcome.targets).zip(&old_hosts) {
            let target = if target == usize::MAX { old } else { target };
            if target != old {
                out.migrated += 1;
            }
            let est = graph.layers[s.layer_id].demand();
            let actual = noisy_demand(&est, &mut lane.rng);
            let h = lane.state.place(target, est, actual, true);
            let run = lane.runs[s.job].as_mut().unwrap();
            run.sched.placement[s.layer_id] = target;
            run.sched.handles[s.layer_id] = h;
        }
        let mut charged: Vec<usize> = stranded.iter().map(|s| s.job).collect();
        charged.sort_unstable();
        charged.dedup();
        for ji in charged {
            let run = lane.runs[ji].as_mut().unwrap();
            run.sched.decision_secs += outcome.sched_secs + outcome.shield_secs;
            run.sched.sched_secs += outcome.sched_secs;
            run.sched.shield_secs += outcome.shield_secs;
        }
    }
    check_lane_overloads(lane, cfg.reward.alpha);
    out
}

/// One measured dynamic run on the region-sharded engine (`cfg.shards
/// >= 1`).  Epoch-barrier loop: advance all lanes to the next
/// cross-region event's time, then handle it serially.
pub fn run_sharded(cfg: &ExperimentConfig, method: Method, seed: u64) -> RunMetrics {
    let shards = cfg.shards.max(1);
    let mut rng = Rng::new(seed);
    let profile = cfg.profile.resource_profile();
    let mut dep = Deployment::generate_spread(
        &mut rng,
        cfg.n_edges,
        cfg.cluster_size,
        profile,
        cfg.cluster_spread_m,
    );
    if cfg.dense_links {
        dep.topo.use_dense_links();
    }
    let graph = cfg.model.build();
    let spec = WorkloadSpec {
        model: cfg.model,
        // Serving: no training jobs — same override as the legacy
        // driver, so the setup RNG prefixes stay engine-identical.
        jobs_per_cluster: if cfg.serving { 0 } else { cfg.jobs_per_cluster },
        iterations: cfg.iterations,
        workload: cfg.workload,
        arrival: cfg.arrival.clone(),
    };
    let workload = Workload::generate(&mut rng, &dep, &spec, 500_000.0);

    let horizon = cfg.iterations as f64 * crate::dnn::profile::TARGET_ITER_SECS * 2.5;

    // Serving request schedule, forked at the exact stream position the
    // legacy driver forks it (immediately after workload generation).
    let requests: Vec<Request> = if cfg.serving {
        let mut req_rng = rng.fork(SERVING_FORK);
        generate_requests(&mut req_rng, &dep, &cfg.serving_spec(), &cfg.arrival, horizon)
    } else {
        Vec::new()
    };

    // Same fork discipline as the legacy driver: mobility gets its own
    // stream only when enabled, pretraining always forks.
    let mut mobility: Option<DynamicTopology> = if cfg.mobility.enabled() {
        let groups: Vec<Vec<NodeId>> = dep.clusters.iter().map(|c| c.members.clone()).collect();
        let m_rng = rng.fork(0x0b17e);
        Some(DynamicTopology::new(&dep.topo, cfg.mobility.clone(), &groups, m_rng))
    } else {
        None
    };

    let mut pretrained = TabularQ::new(cfg.lr, cfg.epsilon);
    pretrain(&mut pretrained, cfg, &mut rng.fork(0xbeef));
    let fwd_baseline = pretrained.fwd_errors();
    let batch_baseline = pretrained.batch_stats();
    let dc = DecisionConfig {
        mode: if cfg.batch_decisions { DecisionMode::Batched } else { DecisionMode::PerAgent },
        batched_eval_cost: cfg.batched_eval_cost,
    };

    let mut membership = Membership::full(&dep);
    let n_clusters = dep.clusters.len();

    // Static super-shield grouping over the t = 0 deployment (draws no
    // RNG — the churn schedule below is untouched).  `None` keeps the
    // flat serial driver, the pinned reference for every fanout.
    let tree: Option<ShieldTree> =
        (cfg.tree_fanout >= 1).then(|| ShieldTree::build(&dep, cfg.tree_fanout));

    // Cross-region (driver) queue: sampling, view refresh, mobility and
    // the up-front churn schedule — drawn from the main stream *before*
    // the lane forks, so the schedule is independent of lane activity.
    let mut driver_queue = EventQueue::new();
    driver_queue.push(SAMPLE_PERIOD_SECS, EventKind::Sample);
    driver_queue.push(VIEW_REFRESH_SECS, EventKind::ViewRefresh);
    if mobility.is_some() {
        driver_queue.push(cfg.mobility_tick_secs, EventKind::MobilityTick);
    }
    if cfg.failure_rate > 0.0 {
        let rate = cfg.failure_rate / 1000.0;
        let mut t = rng.exp(rate);
        while t < horizon {
            let node = rng.below(dep.n());
            driver_queue.push(t, EventKind::NodeFail { node });
            if cfg.rejoin_secs > 0.0 {
                driver_queue.push(t + cfg.rejoin_secs, EventKind::NodeJoin { node });
            }
            t += rng.exp(rate);
        }
    }

    let waves = build_waves(&dep, &workload);
    let n_jobs = workload.dl_jobs.len();
    let mut req_count = vec![0usize; n_clusters];
    for r in &requests {
        req_count[r.cluster] += 1;
    }

    // Lane construction: fork one child RNG per lane in cluster order
    // (the only draws after this point are lane-local or handler-local),
    // clone the shared pretrained policy, slice the resource state, and
    // seed each queue with its cluster's background churn.
    let mut lanes: Vec<Lane> = (0..n_clusters)
        .map(|ci| {
            let members = &dep.clusters[ci].members;
            let mut lane = Lane {
                cluster: ci,
                queue: EventQueue::new(),
                rng: rng.fork(ci as u64),
                policy: pretrained.clone(),
                fwd_baseline,
                batch_baseline,
                shield: match method {
                    Method::SroleC => ClusterShield::Central(CentralShield::new()),
                    Method::SroleD => ClusterShield::Decentral(DecentralShield::new(
                        &dep,
                        members,
                        cfg.subclusters,
                    )),
                    Method::Rl | Method::Marl => ClusterShield::None,
                },
                state: ResourceState::for_cluster(&dep, members),
                own_bg: Vec::new(),
                bg_slots: Vec::new(),
                runs: (0..n_jobs).map(|_| None).collect(),
                live: BTreeMap::new(),
                origin_busy: Vec::new(),
                remaining: workload.dl_jobs.iter().filter(|j| j.cluster == ci).count()
                    + req_count[ci],
                done: false,
                was_overloaded: Vec::new(),
                metrics: RunMetrics::default(),
                obs: obs::mode().map(|m| {
                    let anchor = obs::anchor().expect("mode() implies an installed recorder");
                    Box::new(obs::Recorder::with_anchor(m, ci as u32, anchor))
                }),
            };
            for (gi, bg) in workload.background.iter().enumerate() {
                if dep.cluster_of(bg.node) == ci {
                    lane.own_bg.push(gi);
                }
            }
            lane.bg_slots = vec![None; lane.own_bg.len()];
            // The PageRank background already running at t = 0 is placed
            // now (the lane-sliced mirror of `place_initial_background`);
            // pre-placed segments seed their ends first, then pending
            // segments their starts — the legacy push order, per lane.
            for (li, &gi) in lane.own_bg.iter().enumerate() {
                let bg = &workload.background[gi];
                if bg.start <= 0.0 && bg.end > 0.0 {
                    let h = lane.state.place(bg.node, bg.demand, bg.demand, false);
                    lane.bg_slots[li] = Some(h);
                    lane.queue.push(bg.end, EventKind::BgEnd { bg: li });
                }
            }
            for (li, &gi) in lane.own_bg.iter().enumerate() {
                if lane.bg_slots[li].is_none() {
                    lane.queue.push(workload.background[gi].start, EventKind::BgStart { bg: li });
                }
            }
            lane.was_overloaded = lane
                .state
                .node_ids()
                .map(|n| lane.state.actual_overloaded(n, cfg.reward.alpha))
                .collect();
            lane.origin_busy = vec![0.0; lane.state.n()];
            lane
        })
        .collect();

    // Route arrival waves and serving requests into their cluster's lane.
    for (wi, w) in waves.iter().enumerate() {
        lanes[w.cluster].queue.push(w.t, EventKind::JobArrival { wave: wi });
    }
    for r in &requests {
        lanes[r.cluster].queue.push(r.arrival, EventKind::RequestArrival { req: r.id });
    }

    // Stale state view for failure/migration handlers (paper §III).
    let mut view_demand: Vec<Resources> =
        (0..dep.n()).map(|n| *lanes[dep.cluster_of(n)].state.demand(n)).collect();

    let mut metrics = RunMetrics::default();
    let mut blast_scratch: Vec<NodeId> = Vec::new();
    let mut moved_by_cluster: Vec<Vec<NodeId>> = vec![Vec::new(); n_clusters];
    // Collision total at the previous Sample event (windowed-delta
    // sampler state; read-only w.r.t. the simulation).
    let mut last_collisions: usize = 0;

    loop {
        let barrier = driver_queue.peek().map(|e| e.t);
        {
            let ctx = Ctx {
                dep: &dep,
                membership: &membership,
                graph: &graph,
                workload: &workload,
                waves: &waves,
                requests: &requests,
                view_demand: &view_demand,
                seed,
                cfg,
                method,
                horizon,
                n_clusters,
                dc,
            };
            advance_all(&mut lanes, ctx, barrier.unwrap_or(f64::INFINITY), shards);
        }
        let Some(ev) = driver_queue.pop() else { break };
        obs::sim_time(ev.t);
        // The whole serial barrier section (driver event + any lane
        // mutations it performs) is attributed to the driver row.
        let _barrier_span = obs::span(obs::Phase::EpochBarrier);
        let total_remaining: usize = lanes.iter().map(|l| l.remaining).sum();

        // Shield-tree churn batching: maximal runs of consecutive
        // single-victim fail/join events run their lane phases
        // group-parallel.  A batch only forms when every batched event
        // is strictly before every lane's next queued event (no lane
        // event can fire inside the window, so lane state — and with it
        // `total_remaining` — is constant across the batch; the epoch
        // interleaving and the `t <= T` tie rule are unchanged) and
        // each cluster appears at most once (each cluster's membership
        // slice after the serial root pass is then exactly what the
        // flat handler would have seen at its event).  Blast-radius
        // churn (multi-victim, with its guard/mutation interleaving and
        // in-batch rejoin pushes) always escalates to the flat serial
        // handlers below.
        if let Some(tree) = tree.as_ref() {
            // Serving runs always escalate churn to the flat serial
            // handlers: a mid-service host failure decrements a lane's
            // `remaining`, so a later event in the same batch could see
            // a stale `total_remaining` guard — the flat path re-reads
            // it per event, exactly like the legacy driver.
            if cfg.blast_radius_m == 0.0
                && !cfg.serving
                && matches!(ev.kind, EventKind::NodeFail { .. } | EventKind::NodeJoin { .. })
            {
                let lane_floor = lanes
                    .iter()
                    .filter_map(|l| l.queue.peek().map(|e| e.t))
                    .fold(f64::INFINITY, f64::min);
                let cluster_of = |e: &Event| match e.kind {
                    EventKind::NodeFail { node } | EventKind::NodeJoin { node } => {
                        dep.cluster_of(node)
                    }
                    _ => unreachable!("non-churn event in a churn batch"),
                };
                let mut seen = vec![false; n_clusters];
                seen[cluster_of(&ev)] = true;
                let mut batch: Vec<Event> = vec![ev];
                while let Some(head) = driver_queue.peek() {
                    let batchable = matches!(
                        head.kind,
                        EventKind::NodeFail { .. } | EventKind::NodeJoin { .. }
                    ) && head.t < lane_floor
                        && !seen[cluster_of(head)];
                    if !batchable {
                        break;
                    }
                    let e = driver_queue.pop().expect("peeked event vanished");
                    seen[cluster_of(&e)] = true;
                    batch.push(e);
                }
                // Root (serial) pass in time order: guards, membership
                // mutations, failure accounting and trace events —
                // exactly the flat handlers minus the lane-confined
                // work, which is planned per cluster.
                let mut plan: Vec<Option<(usize, PlannedChurn)>> = vec![None; n_clusters];
                for (bi, bev) in batch.iter().enumerate() {
                    obs::sim_time(bev.t);
                    match bev.kind {
                        EventKind::NodeFail { node } => {
                            if total_remaining == 0 {
                                continue;
                            }
                            let cluster = dep.cluster_of(node);
                            if !membership.is_alive(node)
                                || membership.alive_members(cluster).len() <= 1
                            {
                                continue;
                            }
                            membership.fail(&dep, node);
                            metrics.node_failures += 1;
                            obs::event(obs::TraceKind::Failure, bev.t, node as f64, 0.0);
                            plan[cluster] = Some((bi, PlannedChurn::Fail { victim: node }));
                        }
                        EventKind::NodeJoin { node } => {
                            if total_remaining == 0 || !membership.join(&dep, node) {
                                continue;
                            }
                            obs::event(obs::TraceKind::Join, bev.t, node as f64, 0.0);
                            plan[dep.cluster_of(node)] = Some((bi, PlannedChurn::Join { node }));
                        }
                        _ => unreachable!("non-churn event in a churn batch"),
                    }
                }
                // Group-parallel lane phases, folded in batch (time)
                // order — sums, so the fold order is for auditability.
                if plan.iter().any(Option::is_some) {
                    let t_last = batch.last().expect("batch is non-empty").t;
                    let mut outs: Vec<(usize, LaneOutcome)> = {
                        let plan = &plan;
                        let (membership, dep, graph, workload, view_demand) =
                            (&membership, &dep, &graph, &workload, &view_demand);
                        dispatch_groups(&mut lanes, tree, shards, t_last, |lane| {
                            plan[lane.cluster].map(|(bi, planned)| {
                                let out = match planned {
                                    PlannedChurn::Fail { victim } => fail_lane_phase(
                                        lane, victim, dep, membership, graph, workload,
                                        view_demand, cfg, dc,
                                    ),
                                    PlannedChurn::Join { node } => {
                                        join_lane_phase(lane, node, dep, membership);
                                        LaneOutcome::default()
                                    }
                                };
                                (bi, out)
                            })
                        })
                        .into_iter()
                        .flatten()
                        .collect()
                    };
                    outs.sort_unstable_by_key(|&(bi, _)| bi);
                    for (_, o) in outs {
                        metrics.collisions += o.collisions;
                        metrics.shield_corrections += o.corrections;
                        metrics.rescheduled_layers += o.rescheduled;
                    }
                }
                continue;
            }
        }
        match ev.kind {
            EventKind::Sample => {
                if total_remaining > 0 || ev.t < horizon {
                    if let Some(tree) = tree.as_ref() {
                        // Group-parallel read of the per-lane samples,
                        // folded in cluster order — lanes hold
                        // contiguous ascending node spans, so this is
                        // the flat handler's push order exactly.
                        for q in
                            dispatch_groups(&mut lanes, tree, shards, ev.t, |lane| {
                                sample_lane_phase(lane)
                            })
                        {
                            metrics.tasks_per_device.extend_from_slice(&q[0]);
                            metrics.util_cpu.extend_from_slice(&q[1]);
                            metrics.util_mem.extend_from_slice(&q[2]);
                            metrics.util_bw.extend_from_slice(&q[3]);
                        }
                    } else {
                        // Lanes hold contiguous ascending node spans, so
                        // cluster-order iteration reproduces the legacy
                        // whole-deployment node order.
                        for lane in &lanes {
                            for n in lane.state.node_ids() {
                                metrics.tasks_per_device.push(lane.state.task_count(n) as f64);
                                metrics.util_cpu.push(
                                    lane.state.actual_util(n, ResourceKind::Cpu).clamp(0.0, 2.0),
                                );
                                metrics.util_mem.push(
                                    lane.state.actual_util(n, ResourceKind::Mem).clamp(0.0, 2.0),
                                );
                                metrics.util_bw.push(
                                    lane.state.actual_util(n, ResourceKind::Bw).clamp(0.0, 2.0),
                                );
                            }
                        }
                    }
                    // Windowed samplers: read-only over the samples just
                    // pushed and lane state (no RNG, pinned).
                    if obs::active() {
                        let n = dep.n();
                        let tail =
                            |v: &[f64]| crate::util::stats::mean_of(&v[v.len() - n..]);
                        let depth = driver_queue.len()
                            + lanes.iter().map(|l| l.queue.len()).sum::<usize>();
                        obs::sample(obs::Series::QueueDepth, ev.t, depth as f64);
                        obs::sample(obs::Series::UtilCpu, ev.t, tail(&metrics.util_cpu));
                        obs::sample(obs::Series::UtilMem, ev.t, tail(&metrics.util_mem));
                        obs::sample(obs::Series::UtilBw, ev.t, tail(&metrics.util_bw));
                        let total = metrics.collisions
                            + lanes.iter().map(|l| l.metrics.collisions).sum::<usize>();
                        let window = total - last_collisions;
                        obs::sample(obs::Series::CollisionsWindow, ev.t, window as f64);
                        last_collisions = total;
                        let (mut rows, mut pads) = (0usize, 0usize);
                        for lane in &lanes {
                            let (_, r, p) = lane.policy.batch_stats();
                            rows += r.saturating_sub(lane.batch_baseline.1);
                            pads += p.saturating_sub(lane.batch_baseline.2);
                        }
                        let occ =
                            if rows + pads > 0 { rows as f64 / (rows + pads) as f64 } else { 0.0 };
                        obs::sample(obs::Series::QnetOccupancy, ev.t, occ);
                    }
                    driver_queue.push(ev.t + SAMPLE_PERIOD_SECS, EventKind::Sample);
                }
            }
            EventKind::ViewRefresh => {
                if let Some(tree) = tree.as_ref() {
                    // Group-parallel snapshot of each lane's demand
                    // span, written back serially in cluster order
                    // (lanes hold contiguous ascending node spans, so
                    // the running offset is each lane's span start).
                    let mut at = 0usize;
                    for v in dispatch_groups(&mut lanes, tree, shards, ev.t, |lane| {
                        lane.state
                            .node_ids()
                            .map(|n| *lane.state.demand(n))
                            .collect::<Vec<Resources>>()
                    }) {
                        view_demand[at..at + v.len()].copy_from_slice(&v);
                        at += v.len();
                    }
                } else {
                    for lane in &lanes {
                        for n in lane.state.node_ids() {
                            view_demand[n] = *lane.state.demand(n);
                        }
                    }
                }
                if total_remaining > 0 {
                    driver_queue.push(ev.t + VIEW_REFRESH_SECS, EventKind::ViewRefresh);
                }
            }
            EventKind::NodeFail { node } => {
                if total_remaining == 0 {
                    continue;
                }
                if !membership.is_alive(node)
                    || membership.alive_members(dep.cluster_of(node)).len() <= 1
                {
                    continue;
                }
                let mut victims = vec![node];
                if cfg.blast_radius_m > 0.0 {
                    dep.topo.nodes_within_into(node, cfg.blast_radius_m, &mut blast_scratch);
                    victims
                        .extend(blast_scratch.iter().copied().filter(|&v| membership.is_alive(v)));
                }
                for (vi, &victim) in victims.iter().enumerate() {
                    let cluster = dep.cluster_of(victim);
                    if !membership.is_alive(victim)
                        || membership.alive_members(cluster).len() <= 1
                    {
                        continue;
                    }
                    membership.fail(&dep, victim);
                    metrics.node_failures += 1;
                    obs::event(
                        obs::TraceKind::Failure,
                        ev.t,
                        victim as f64,
                        if vi > 0 { 1.0 } else { 0.0 },
                    );
                    if vi > 0 {
                        metrics.correlated_failures += 1;
                        if cfg.rejoin_secs > 0.0 {
                            let back = ev.t + cfg.rejoin_secs;
                            driver_queue.push(back, EventKind::NodeJoin { node: victim });
                        }
                    }
                    let lane = &mut lanes[cluster];
                    match &mut lane.shield {
                        ClusterShield::Central(s) => {
                            s.set_alive(Some(membership.alive_cluster_set(cluster).clone()));
                        }
                        ClusterShield::Decentral(s) => {
                            s.node_failed(&dep, victim);
                        }
                        ClusterShield::None => {}
                    }
                    for (li, &gi) in lane.own_bg.iter().enumerate() {
                        if workload.background[gi].node == victim {
                            if let Some(h) = lane.bg_slots[li].take() {
                                lane.state.release(h);
                            }
                        }
                    }
                    fail_lane_requests(lane, victim);
                    let mut stranded: Vec<Stranded> = Vec::new();
                    for (ji, run) in lane.runs.iter_mut().enumerate() {
                        let Some(run) = run else { continue };
                        if run.done {
                            continue;
                        }
                        for (layer_id, &host) in run.sched.placement.iter().enumerate() {
                            if host == victim {
                                lane.state.release(run.sched.handles[layer_id]);
                                stranded.push(Stranded {
                                    job: ji,
                                    owner: run.sched.job.owner,
                                    layer_id,
                                });
                            }
                        }
                    }
                    if !stranded.is_empty() {
                        let outcome = {
                            let shield = lane.shield.as_dyn();
                            let policy: &mut dyn Policy = &mut lane.policy;
                            reschedule_stranded(
                                &dep, &membership, &lane.state, &graph, &view_demand, &stranded,
                                victim, policy, shield, &cfg.reward, dc, &mut lane.rng,
                            )
                        };
                        metrics.collisions += outcome.collisions;
                        metrics.shield_corrections += outcome.corrections;
                        metrics.rescheduled_layers += stranded.len();
                        for (s, &target) in stranded.iter().zip(&outcome.targets) {
                            let target = if target == usize::MAX {
                                membership.alive_members(cluster)[0]
                            } else {
                                target
                            };
                            let est = graph.layers[s.layer_id].demand();
                            let actual = noisy_demand(&est, &mut lane.rng);
                            let h = lane.state.place(target, est, actual, true);
                            let run = lane.runs[s.job].as_mut().unwrap();
                            run.sched.placement[s.layer_id] = target;
                            run.sched.handles[s.layer_id] = h;
                        }
                        let mut charged: Vec<usize> = stranded.iter().map(|s| s.job).collect();
                        charged.sort_unstable();
                        charged.dedup();
                        for ji in charged {
                            let run = lane.runs[ji].as_mut().unwrap();
                            run.sched.decision_secs += outcome.sched_secs + outcome.shield_secs;
                            run.sched.sched_secs += outcome.sched_secs;
                            run.sched.shield_secs += outcome.shield_secs;
                        }
                    }
                    check_lane_overloads(lane, cfg.reward.alpha);
                }
            }
            EventKind::NodeJoin { node } => {
                if total_remaining == 0 || !membership.join(&dep, node) {
                    continue;
                }
                obs::event(obs::TraceKind::Join, ev.t, node as f64, 0.0);
                let cluster = dep.cluster_of(node);
                match &mut lanes[cluster].shield {
                    ClusterShield::Central(s) => {
                        s.set_alive(Some(membership.alive_cluster_set(cluster).clone()));
                    }
                    ClusterShield::Decentral(s) => {
                        s.node_joined(&dep, node);
                    }
                    ClusterShield::None => {}
                }
            }
            EventKind::MobilityTick => {
                if total_remaining == 0 {
                    continue;
                }
                let Some(dyn_topo) = mobility.as_mut() else { continue };
                driver_queue.push(ev.t + cfg.mobility_tick_secs, EventKind::MobilityTick);
                let moved = dyn_topo.advance(ev.t, cfg.mobility_tick_secs, &mut dep.topo);
                if moved.is_empty() {
                    continue;
                }
                metrics.mobility_moves += moved.len();
                dep.refresh_adjacency();
                let alive = membership.alive_set().clone();
                membership = Membership::rebuild(&dep, &alive);
                for &node in &moved {
                    moved_by_cluster[dep.cluster_of(node)].push(node);
                }
                if let Some(tree) = tree.as_ref() {
                    // Group-parallel per-lane phase (handoffs, migration
                    // scan + reschedule, overload edges); counters and
                    // handoff trace events fold in cluster order — the
                    // flat loops' order exactly.
                    let outs = {
                        let (membership, dep, graph, view_demand, moved_by_cluster) =
                            (&membership, &dep, &graph, &view_demand, &moved_by_cluster);
                        dispatch_groups(&mut lanes, tree, shards, ev.t, |lane| {
                            mobility_lane_phase(
                                lane,
                                &moved_by_cluster[lane.cluster],
                                dep,
                                membership,
                                graph,
                                view_demand,
                                cfg,
                                dc,
                            )
                        })
                    };
                    for (cluster, o) in outs.iter().enumerate() {
                        metrics.region_handoffs += o.handoffs;
                        if o.handoffs > 0 {
                            let (c, h) = (cluster as f64, o.handoffs as f64);
                            obs::event(obs::TraceKind::Handoff, ev.t, c, h);
                        }
                        metrics.collisions += o.collisions;
                        metrics.shield_corrections += o.corrections;
                        metrics.migrated_layers += o.migrated;
                    }
                    for nodes in moved_by_cluster.iter_mut() {
                        nodes.clear();
                    }
                    continue;
                }
                for (cluster, nodes) in moved_by_cluster.iter_mut().enumerate() {
                    if nodes.is_empty() {
                        continue;
                    }
                    if let ClusterShield::Decentral(s) = &mut lanes[cluster].shield {
                        let handoffs = s.nodes_moved(&dep, nodes);
                        metrics.region_handoffs += handoffs;
                        if handoffs > 0 {
                            let (c, h) = (cluster as f64, handoffs as f64);
                            obs::event(obs::TraceKind::Handoff, ev.t, c, h);
                        }
                    }
                    nodes.clear();
                }
                // Mobility-aware migration, lane by lane (a job's layers
                // never leave its cluster, so per-lane run scans are the
                // legacy per-cluster grouping).
                for lane in lanes.iter_mut() {
                    let mut stranded: Vec<Stranded> = Vec::new();
                    for (ji, run) in lane.runs.iter().enumerate() {
                        let Some(run) = run else { continue };
                        let owner = run.sched.job.owner;
                        if run.done || !membership.is_alive(owner) {
                            continue;
                        }
                        if membership.alive_neighbors(owner).is_empty() {
                            continue;
                        }
                        for (layer_id, &host) in run.sched.placement.iter().enumerate() {
                            let reachable = host == owner
                                || membership.alive_neighbors(owner).binary_search(&host).is_ok();
                            if !reachable && membership.is_alive(host) {
                                stranded.push(Stranded { job: ji, owner, layer_id });
                            }
                        }
                    }
                    if stranded.is_empty() {
                        continue;
                    }
                    let mut old_hosts: Vec<NodeId> = Vec::with_capacity(stranded.len());
                    for s in &stranded {
                        let run = lane.runs[s.job].as_mut().unwrap();
                        old_hosts.push(run.sched.placement[s.layer_id]);
                        lane.state.release(run.sched.handles[s.layer_id]);
                    }
                    let outcome = {
                        let shield = lane.shield.as_dyn();
                        let policy: &mut dyn Policy = &mut lane.policy;
                        reschedule_migrated(
                            &dep, &membership, &lane.state, &graph, &view_demand, &stranded,
                            policy, shield, &cfg.reward, dc, &mut lane.rng,
                        )
                    };
                    metrics.collisions += outcome.collisions;
                    metrics.shield_corrections += outcome.corrections;
                    for ((s, &target), &old) in
                        stranded.iter().zip(&outcome.targets).zip(&old_hosts)
                    {
                        let target = if target == usize::MAX { old } else { target };
                        if target != old {
                            metrics.migrated_layers += 1;
                        }
                        let est = graph.layers[s.layer_id].demand();
                        let actual = noisy_demand(&est, &mut lane.rng);
                        let h = lane.state.place(target, est, actual, true);
                        let run = lane.runs[s.job].as_mut().unwrap();
                        run.sched.placement[s.layer_id] = target;
                        run.sched.handles[s.layer_id] = h;
                    }
                    let mut charged: Vec<usize> = stranded.iter().map(|s| s.job).collect();
                    charged.sort_unstable();
                    charged.dedup();
                    for ji in charged {
                        let run = lane.runs[ji].as_mut().unwrap();
                        run.sched.decision_secs += outcome.sched_secs + outcome.shield_secs;
                        run.sched.sched_secs += outcome.sched_secs;
                        run.sched.shield_secs += outcome.shield_secs;
                    }
                }
                for lane in lanes.iter_mut() {
                    check_lane_overloads(lane, cfg.reward.alpha);
                }
            }
            _ => unreachable!("lane-local event in the driver queue"),
        }
    }

    // Merge lane recorders into the driver recorder in cluster order —
    // the same merge rule as the metrics below, so the per-lane profile
    // rows are independent of worker-thread chunking.
    for lane in lanes.iter_mut() {
        if let Some(rec) = lane.obs.take() {
            obs::merge_lane(*rec);
        }
    }

    // Merge: lane metrics in cluster order, then the driver's
    // cross-region samples and counters — both orders are fixed by the
    // cluster layout, never by the shard count.
    let mut merged = RunMetrics::default();
    let mut qnet = 0usize;
    let mut batch = (0usize, 0usize, 0usize);
    for lane in &lanes {
        merged.absorb(&lane.metrics);
        qnet += lane.policy.fwd_errors().saturating_sub(lane.fwd_baseline);
        let (fwds, rows, pads) = lane.policy.batch_stats();
        batch.0 += fwds.saturating_sub(lane.batch_baseline.0);
        batch.1 += rows.saturating_sub(lane.batch_baseline.1);
        batch.2 += pads.saturating_sub(lane.batch_baseline.2);
    }
    merged.absorb(&metrics);
    merged.qnet_fwd_errors = qnet;
    merged.qnet_batch_fwds = batch.0;
    merged.qnet_batch_rows = batch.1;
    merged.qnet_batch_pad_rows = batch.2;
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::ModelKind;

    fn sharded_cfg(shards: usize) -> ExperimentConfig {
        ExperimentConfig {
            n_edges: 10,
            cluster_size: 5,
            model: ModelKind::Rnn,
            iterations: 5,
            pretrain_episodes: 20,
            repetitions: 1,
            failure_rate: 3.0,
            rejoin_secs: 120.0,
            shards,
            ..Default::default()
        }
    }

    #[test]
    fn sharded_runs_complete_all_jobs() {
        let cfg = sharded_cfg(1);
        assert!(cfg.dynamic(), "shards > 0 must route through the event engines");
        for m in Method::ALL {
            let r = run_sharded(&cfg, m, 5);
            assert_eq!(r.jct.len(), 2 * 3, "{}: wrong job count", m.name());
            assert!(r.jct.iter().all(|&t| t.is_finite() && t > 0.0), "{}", m.name());
            assert!(!r.decision_secs.is_empty());
        }
    }

    #[test]
    fn metrics_are_byte_identical_across_shard_counts() {
        // shards = 1 (inline serial) is the pinned reference for every
        // worker count, including more workers than lanes.
        for m in [Method::Marl, Method::SroleD] {
            let base = run_sharded(&sharded_cfg(1), m, 11).to_json().to_string();
            for shards in [2usize, 8] {
                let r = run_sharded(&sharded_cfg(shards), m, 11).to_json().to_string();
                assert_eq!(base, r, "{} diverges at shards={}", m.name(), shards);
            }
        }
    }

    #[test]
    fn sharding_composes_with_mobility_and_blast_churn() {
        let mut cfg = sharded_cfg(1);
        cfg.mobility =
            crate::net::MobilityModel::RandomWaypoint { speed_mps: 2.0, pause_secs: 0.0 };
        cfg.mobility_tick_secs = 10.0;
        cfg.blast_radius_m = 200.0;
        let a = run_sharded(&cfg, Method::SroleD, 9).to_json().to_string();
        cfg.shards = 2;
        let b = run_sharded(&cfg, Method::SroleD, 9).to_json().to_string();
        cfg.shards = 8;
        let c = run_sharded(&cfg, Method::SroleD, 9).to_json().to_string();
        assert_eq!(a, b, "mobility + blast churn diverges at shards=2");
        assert_eq!(a, c, "mobility + blast churn diverges at shards=8");
    }

    #[test]
    fn run_dynamic_routes_shards_to_the_sharded_engine() {
        let cfg = sharded_cfg(2);
        let routed = super::super::dynamic::run_dynamic(&cfg, Method::Marl, 7);
        let direct = run_sharded(&cfg, Method::Marl, 7);
        assert_eq!(routed.to_json().to_string(), direct.to_json().to_string());
    }

    #[test]
    fn churn_fires_and_reschedules_under_sharding() {
        let mut failures = 0;
        let mut rescheduled = 0;
        for seed in [1u64, 2, 3] {
            let r = run_sharded(&sharded_cfg(2), Method::SroleC, seed);
            failures += r.node_failures;
            rescheduled += r.rescheduled_layers;
        }
        assert!(failures > 0, "no failure event fired across 3 seeds");
        assert!(rescheduled > 0, "failures never stranded a layer");
    }

    #[test]
    fn metrics_are_byte_identical_across_tree_fanouts() {
        // Fanout 0 (the flat serial driver) is the pinned reference for
        // every tree shape, both with blast churn (which escalates to
        // the serial root pass) and without it (where fail/join events
        // batch group-parallel), under mobility, for every shard count.
        for blast in [0.0f64, 200.0] {
            let mut cfg = sharded_cfg(1);
            cfg.mobility =
                crate::net::MobilityModel::RandomWaypoint { speed_mps: 2.0, pause_secs: 0.0 };
            cfg.mobility_tick_secs = 10.0;
            cfg.blast_radius_m = blast;
            let base = run_sharded(&cfg, Method::SroleD, 9).to_json().to_string();
            for fanout in [2usize, 8] {
                for shards in [1usize, 8] {
                    cfg.shards = shards;
                    cfg.tree_fanout = fanout;
                    let r = run_sharded(&cfg, Method::SroleD, 9).to_json().to_string();
                    assert_eq!(
                        base, r,
                        "tree diverges at fanout={fanout} shards={shards} blast={blast}"
                    );
                }
            }
        }
    }

    fn serving_cfg(shards: usize) -> ExperimentConfig {
        ExperimentConfig {
            n_edges: 10,
            cluster_size: 5,
            model: ModelKind::Rnn,
            iterations: 1,
            pretrain_episodes: 20,
            repetitions: 1,
            serving: true,
            request_rate: 0.05,
            failure_rate: 3.0,
            rejoin_secs: 120.0,
            shards,
            ..Default::default()
        }
    }

    #[test]
    fn serving_metrics_are_byte_identical_across_engines_and_shards() {
        // The serving headline claim: unlike training, the legacy
        // single-stream driver (shards = 0) and every sharded
        // configuration produce bitwise-equal RunMetrics — under churn.
        for m in [Method::Marl, Method::SroleD] {
            let legacy = super::super::dynamic::run_dynamic(&serving_cfg(0), m, 11);
            assert!(legacy.requests_served > 0, "{}: vacuous equivalence", m.name());
            let legacy = legacy.to_json().to_string();
            for shards in [1usize, 2, 8] {
                let r = run_sharded(&serving_cfg(shards), m, 11).to_json().to_string();
                assert_eq!(legacy, r, "{} diverges at shards={}", m.name(), shards);
            }
        }
    }

    #[test]
    fn serving_byte_identity_survives_the_shield_tree() {
        // Churn always escalates to the flat serial handlers on serving
        // runs (a mid-service failure moves `remaining`), so the tree
        // driver must still replay the legacy engine byte for byte.
        let legacy = super::super::dynamic::run_dynamic(&serving_cfg(0), Method::SroleD, 13);
        assert!(legacy.requests_served > 0);
        let legacy = legacy.to_json().to_string();
        for fanout in [1usize, 4] {
            let mut cfg = serving_cfg(8);
            cfg.tree_fanout = fanout;
            let r = run_sharded(&cfg, Method::SroleD, 13).to_json().to_string();
            assert_eq!(legacy, r, "serving diverges under tree_fanout={fanout}");
        }
    }

    #[test]
    fn group_parallel_driver_matches_serial_over_many_churn_steps() {
        // Heavy single-victim churn (mean interarrival ~3 s over a
        // ~100 s+ horizon, quick rejoins) plus mobility ticks: well over
        // a hundred driver-queue steps, most of them fail/join events
        // that exercise the batch builder — the group-parallel driver
        // must replay the flat serial driver byte for byte.
        let mut cfg = sharded_cfg(1);
        cfg.failure_rate = 300.0;
        cfg.rejoin_secs = 20.0;
        cfg.mobility =
            crate::net::MobilityModel::RandomWaypoint { speed_mps: 2.0, pause_secs: 0.0 };
        cfg.mobility_tick_secs = 5.0;
        let base = run_sharded(&cfg, Method::SroleD, 13);
        assert!(
            base.node_failures >= 20,
            "expected heavy churn, saw {} failures",
            base.node_failures
        );
        let base = base.to_json().to_string();
        for shards in [1usize, 8] {
            cfg.shards = shards;
            cfg.tree_fanout = 2;
            let r = run_sharded(&cfg, Method::SroleD, 13).to_json().to_string();
            assert_eq!(base, r, "group-parallel driver diverges at shards={shards}");
        }
    }
}
