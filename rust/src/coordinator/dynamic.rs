//! Event-driven experiment driver for *dynamic* edge scenarios.
//!
//! The paper's evaluation freezes the deployment: jobs arrive in
//! pre-batched waves and membership never changes.  This driver runs the
//! same methods on the unified event core (`sim::event`) with the full
//! event vocabulary live:
//!
//! * `JobArrival` — arrival batches (Poisson / trace / batched, from
//!   `workload::ArrivalProcess`) trigger a membership-aware scheduling
//!   wave at arrival time;
//! * `IterEnd` — iterations re-price against current contention, exactly
//!   as in the static executor;
//! * `BgStart` / `BgEnd` — background churn (segments on dead nodes are
//!   lost);
//! * `Sample` / `ViewRefresh` — periodic utilization sampling and the
//!   stale state-view refresh the failure handler observes;
//! * `NodeFail` / `NodeJoin` — membership churn: the incremental
//!   [`Membership`] indexes update in O(cluster + degree), shields
//!   re-partition region responsibility incrementally, and layers
//!   stranded on the failed host are rescheduled by the owning agents
//!   (`sched::reschedule_stranded`) with full decision-latency
//!   accounting, so the overhead figures stay regenerable under churn;
//! * `RequestArrival` / `RequestDone` — the inference-serving workload
//!   (`workload = "serving"`): an open-loop request stream placed one
//!   request at a time through the same shield/policy stack, with
//!   admission control against the stale view and full latency
//!   accounting (queue + decision + transfer + service) into
//!   `RunMetrics::request_latency`.
//!
//! With `cross_cluster = true` (requires `tree_fanout >= 1`; this
//! engine only — lane-sliced resource windows cannot host foreign
//! layers), reschedule fallbacks that exhaust the in-cluster search may
//! target alive boundary-pair neighbors in adjacent clusters, shielded
//! through the shield tree's pair visible sets (`shield::tree`).
//! `RunMetrics` counts the placements (`cross_cluster_placements`) and
//! the pairs that crossed super-shield groups
//! (`shield_tree_escalations`); both counters increment only on this
//! path, so `cross_cluster = false` runs replay byte-identically.
//!
//! Determinism: one RNG stream drives generation and the single-stream
//! event loop, so a `(config, method, seed)` triple replays bit-identically
//! regardless of harness thread count.  With `cfg.shards >= 1` the run
//! routes to the region-sharded engine (`coordinator::shard`) instead,
//! which forks per-region RNG streams and is byte-identical across shard
//! counts (but a different — equally deterministic — stream than this
//! legacy single-stream driver, which `shards = 0` keeps untouched).
//!
//! The `IterEnd`/`BgStart`/`BgEnd`/`Sample` handlers deliberately mirror
//! `sim::engine` rather than share its code: the static executor is the
//! bit-stable baseline for the paper's figures (pinned by its own
//! determinism tests), while these handlers additionally consult live
//! membership (alive-head re-election, dead-node background loss).  When
//! changing completion/sampling semantics, change both drivers.

use crate::cluster::{Deployment, Membership, NodeId, Resources};
use crate::config::ExperimentConfig;
use crate::metrics::RunMetrics;
use crate::net::mobility::DynamicTopology;
use crate::obs;
use crate::rl::{Policy, TabularQ};
use crate::sched::{
    central_wave_dynamic, cross_candidates_into, marl_wave_dynamic, noisy_demand, place_request,
    reschedule_migrated, reschedule_stranded, DecisionConfig, DecisionMode, JobSchedule, Stranded,
    WaveOutcome,
};
use crate::shield::{CentralShield, DecentralShield, Shield, ShieldTree};
use crate::sim::engine::SAMPLE_PERIOD_SECS;
use crate::sim::event::{EventKind, EventQueue};
use crate::sim::{timing, ResourceState, TaskHandle};
use crate::util::Rng;
use crate::workload::serving::{generate_requests, Request};
use crate::workload::{DlJob, Workload, WorkloadSpec};

use std::collections::BTreeMap;

use super::{pretrain, Method};

/// Seconds between refreshes of the (stale) state view the failure
/// handler observes — the paper's periodic resource reports (§III).
pub const VIEW_REFRESH_SECS: f64 = 60.0;

/// Jobs arriving within this window of a batch's first arrival are
/// scheduled in one concurrent wave (simultaneous decisions are what
/// makes action collisions possible).
pub const WAVE_BATCH_SECS: f64 = 5.0;

/// RNG fork tag for the serving request schedule.  Both engines fork it
/// from the main stream immediately after `Workload::generate` (and only
/// when `workload = "serving"`), so the request schedules — and every
/// later main-stream draw — match byte for byte across engines.
pub(super) const SERVING_FORK: u64 = 0x5e7e;

/// Per-request private RNG stream base: request `i` draws its decision
/// noise and demand perturbation from `Rng::with_stream(seed,
/// REQ_STREAM_BASE + i)`.  Every per-request draw is a function of
/// `(run seed, request id)` alone — independent of event interleaving
/// and engine — which is the keystone of the sharded engine's
/// byte-identity with this driver on serving runs.
pub(super) const REQ_STREAM_BASE: u64 = 0x5e7e_0000;

/// Bookkeeping for an admitted, in-flight inference request.  Dropped
/// from the live map either at `RequestDone` (served) or when its host
/// fails mid-service (counted as `requests_failed`, never retried — the
/// open-loop client's perspective).
pub(super) struct LiveRequest {
    pub(super) handle: TaskHandle,
    pub(super) host: NodeId,
    /// Full accounted latency: queue + decision + transfer + service.
    pub(super) latency: f64,
}

/// Per-cluster shield instance (lives across waves and churn events, so
/// its incremental region state persists).  Shared with the sharded
/// engine, where each lane owns its cluster's instance.
pub(super) enum ClusterShield {
    None,
    Central(CentralShield),
    Decentral(DecentralShield),
}

impl ClusterShield {
    pub(super) fn as_dyn(&mut self) -> Option<&mut dyn Shield> {
        match self {
            ClusterShield::None => None,
            ClusterShield::Central(s) => Some(s),
            ClusterShield::Decentral(s) => Some(s),
        }
    }
}

/// One arrival batch: the cluster's jobs that decide concurrently.
pub(super) struct Wave {
    pub(super) cluster: usize,
    pub(super) jobs: Vec<DlJob>,
    /// Fire time: the latest arrival in the batch.
    pub(super) t: f64,
}

/// Execution bookkeeping for one scheduled job.
pub(super) struct Run {
    pub(super) sched: JobSchedule,
    pub(super) start: f64,
    pub(super) iters_done: usize,
    pub(super) done: bool,
}

/// Group a cluster's jobs into concurrent-decision waves: jobs arriving
/// within [`WAVE_BATCH_SECS`] of a batch's first arrival share its wave.
pub(super) fn build_waves(dep: &Deployment, workload: &Workload) -> Vec<Wave> {
    let mut waves = Vec::new();
    for ci in 0..dep.clusters.len() {
        let mut jobs: Vec<DlJob> =
            workload.dl_jobs.iter().filter(|j| j.cluster == ci).cloned().collect();
        jobs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        let mut i = 0usize;
        while i < jobs.len() {
            let batch_start = jobs[i].arrival;
            let mut batch = Vec::new();
            while i < jobs.len() && jobs[i].arrival <= batch_start + WAVE_BATCH_SECS {
                batch.push(jobs[i].clone());
                i += 1;
            }
            let t = batch.last().map(|j| j.arrival).unwrap_or(batch_start);
            waves.push(Wave { cluster: ci, jobs: batch, t });
        }
    }
    waves
}

/// Highest-capacity *alive* member of a cluster — the acting head after
/// the original head fails (deterministic re-election).
pub(super) fn alive_head(dep: &Deployment, membership: &Membership, cluster: usize) -> NodeId {
    let members = membership.alive_members(cluster);
    members
        .iter()
        .copied()
        .max_by(|&a, &b| {
            let ka = dep.nodes[a].caps.cpu * dep.nodes[a].caps.mem;
            let kb = dep.nodes[b].caps.cpu * dep.nodes[b].caps.mem;
            ka.partial_cmp(&kb).unwrap()
        })
        .unwrap_or(dep.clusters[cluster].head)
}

/// Opt-in cross-cluster rescue for a reschedule fallback
/// (`cross_cluster`, this engine only — lane-sliced resource windows
/// cannot host foreign-cluster layers).  The pool is the owner's alive
/// out-of-cluster transmission neighbors, shielded through the tree:
/// a candidate must share a boundary pair with the owner's cluster with
/// both endpoints in the pair's build-time visible set, and placing the
/// layer must keep it under the overload threshold against the stale
/// view (the same report the in-cluster reschedule consults — the
/// shields' admission rule applied to the pair's visible scope).
/// Returns the chosen host and whether the pair crossed super-shield
/// groups (escalated to the root rather than resolved group-locally).
fn cross_rescue(
    tree: &ShieldTree,
    dep: &Deployment,
    membership: &Membership,
    view_demand: &[Resources],
    est: &Resources,
    owner: NodeId,
    alpha: f64,
    scratch: &mut Vec<NodeId>,
) -> Option<(NodeId, bool)> {
    cross_candidates_into(dep, membership, owner, scratch);
    scratch.retain(|&c| {
        crate::cluster::ResourceKind::ALL
            .iter()
            .all(|&k| dep.nodes[c].caps.utilization(&view_demand[c].add(est), k) <= alpha)
    });
    tree.cross_rescue_target(dep, owner, scratch)
}

/// One measured dynamic run: the event-driven counterpart of
/// `Experiment::run_once` for configurations with churn or online
/// arrivals.
pub fn run_dynamic(cfg: &ExperimentConfig, method: Method, seed: u64) -> RunMetrics {
    if cfg.shards > 0 {
        return super::shard::run_sharded(cfg, method, seed);
    }
    let mut rng = Rng::new(seed);
    let profile = cfg.profile.resource_profile();
    let mut dep = Deployment::generate_spread(
        &mut rng,
        cfg.n_edges,
        cfg.cluster_size,
        profile,
        cfg.cluster_spread_m,
    );
    if cfg.dense_links {
        // Dense reference store: same prices, no RNG draws — dynamic
        // runs must replay the sparse model byte-identically too.
        dep.topo.use_dense_links();
    }
    let graph = cfg.model.build();
    let spec = WorkloadSpec {
        model: cfg.model,
        // Serving runs host no training jobs: the request stream is the
        // workload (background jobs still churn underneath it).  Both
        // engines apply the same override, so no wave ever fires and the
        // main RNG stream stays engine-independent.
        jobs_per_cluster: if cfg.serving { 0 } else { cfg.jobs_per_cluster },
        iterations: cfg.iterations,
        workload: cfg.workload,
        arrival: cfg.arrival.clone(),
    };
    let workload = Workload::generate(&mut rng, &dep, &spec, 500_000.0);

    // Horizon shared with the static path: the nominal experiment
    // duration at the target iteration rate (plus slack).  Serving runs
    // use it as the request-stream window.
    let horizon = cfg.iterations as f64 * crate::dnn::profile::TARGET_ITER_SECS * 2.5;

    // Serving workload: draw the open-loop request schedule on its own
    // fork (fires only when serving, like the mobility fork below, so
    // training runs replay their pre-serving RNG streams exactly).
    let requests: Vec<Request> = if cfg.serving {
        let mut req_rng = rng.fork(SERVING_FORK);
        generate_requests(&mut req_rng, &dep, &cfg.serving_spec(), &cfg.arrival, horizon)
    } else {
        Vec::new()
    };

    // Mobility: couple the topology to its motion process (own forked
    // RNG stream, separate from scheduling draws).  The fork fires only
    // for mobility-enabled configs, so churn-only / Poisson scenarios
    // replay their pre-mobility RNG streams — and results — exactly.
    // Link prices are always the distance-attenuated pricing function
    // of the current positions (`net::link`), mobile or not; `figures
    // mobility` keeps a stationary-trace baseline so its rows differ
    // from the mobile cells only in actual motion (same RNG fork).
    let mut mobility: Option<DynamicTopology> = if cfg.mobility.enabled() {
        let groups: Vec<Vec<NodeId>> = dep.clusters.iter().map(|c| c.members.clone()).collect();
        let m_rng = rng.fork(0x0b17e);
        Some(DynamicTopology::new(&dep.topo, cfg.mobility.clone(), &groups, m_rng))
    } else {
        None
    };

    let mut policy = TabularQ::new(cfg.lr, cfg.epsilon);
    pretrain(&mut policy, cfg, &mut rng.fork(0xbeef));
    let policy: &mut dyn Policy = &mut policy;
    // Baseline after pretraining: the run's metric must count only
    // forward errors the measured run itself experienced.
    let fwd_errors_baseline = policy.fwd_errors();
    let batch_baseline = policy.batch_stats();
    // Decision path: batched greedy forwards by default, replaying the
    // per-agent reference byte-identically (pinned by harness tests).
    let dc = DecisionConfig {
        mode: if cfg.batch_decisions { DecisionMode::Batched } else { DecisionMode::PerAgent },
        batched_eval_cost: cfg.batched_eval_cost,
    };

    let mut membership = Membership::full(&dep);
    let mut shields: Vec<ClusterShield> = dep
        .clusters
        .iter()
        .map(|c| match method {
            Method::SroleC => ClusterShield::Central(CentralShield::new()),
            Method::SroleD => {
                ClusterShield::Decentral(DecentralShield::new(&dep, &c.members, cfg.subclusters))
            }
            Method::Rl | Method::Marl => ClusterShield::None,
        })
        .collect();

    // Opt-in cross-cluster rescue (`validate()` requires `tree_fanout
    // >= 1` and this global-state driver).  The shield tree carries the
    // boundary-pair visible sets rescue proposals are shielded through;
    // both counters below increment only on this path, so every
    // `cross_cluster = false` run is untouched byte for byte.
    let tree: Option<ShieldTree> =
        cfg.cross_cluster.then(|| ShieldTree::build(&dep, cfg.tree_fanout));
    let mut cross_scratch: Vec<NodeId> = Vec::new();

    let mut state = ResourceState::new(&dep);
    let pre_placed = crate::sim::engine::place_initial_background(&mut state, &workload);
    let mut metrics = RunMetrics::default();
    let mut queue = EventQueue::new();

    // Background churn events (pre-placed segments only need their end).
    let mut bg_handles = vec![None; workload.background.len()];
    for (i, h) in pre_placed {
        bg_handles[i] = Some(h);
        queue.push(workload.background[i].end, EventKind::BgEnd { bg: i });
    }
    for (i, bg) in workload.background.iter().enumerate() {
        if bg_handles[i].is_none() {
            queue.push(bg.start, EventKind::BgStart { bg: i });
        }
    }

    // Arrival waves (empty on serving runs) and the request stream.
    let waves = build_waves(&dep, &workload);
    for (wi, w) in waves.iter().enumerate() {
        queue.push(w.t, EventKind::JobArrival { wave: wi });
    }
    for r in &requests {
        queue.push(r.arrival, EventKind::RequestArrival { req: r.id });
    }

    queue.push(SAMPLE_PERIOD_SECS, EventKind::Sample);
    queue.push(VIEW_REFRESH_SECS, EventKind::ViewRefresh);
    if mobility.is_some() {
        queue.push(cfg.mobility_tick_secs, EventKind::MobilityTick);
    }

    // Node churn schedule, drawn up-front from the run's RNG stream so
    // replays are exact.  Rejoins follow failures after `rejoin_secs`.
    if cfg.failure_rate > 0.0 {
        let rate = cfg.failure_rate / 1000.0;
        let mut t = rng.exp(rate);
        while t < horizon {
            let node = rng.below(dep.n());
            queue.push(t, EventKind::NodeFail { node });
            if cfg.rejoin_secs > 0.0 {
                queue.push(t + cfg.rejoin_secs, EventKind::NodeJoin { node });
            }
            t += rng.exp(rate);
        }
    }

    let mut runs: Vec<Option<Run>> = (0..workload.dl_jobs.len()).map(|_| None).collect();
    let mut remaining = workload.dl_jobs.len() + requests.len();
    let n_clusters = dep.clusters.len();

    // Serving bookkeeping: in-flight requests, the per-origin decision
    // queue (an origin handles one placement decision at a time — the
    // queueing term of the latency account), and per-cluster latency
    // buffers appended in cluster order at run end, matching the sharded
    // engine's lane-merge order byte for byte.
    let mut live: BTreeMap<usize, LiveRequest> = BTreeMap::new();
    let mut origin_busy: Vec<f64> = vec![0.0; dep.n()];
    let mut req_latency: Vec<Vec<f64>> = vec![Vec::new(); n_clusters];
    // Stale state view for the failure handler (paper §III: agents and
    // shields act on periodic reports, not live state).
    let mut view_demand: Vec<Resources> = (0..state.n()).map(|n| *state.demand(n)).collect();

    // Event-loop scratch buffers (reused across events; the per-event
    // hot paths stay allocation-free once warm).
    let mut blast_scratch: Vec<NodeId> = Vec::new();
    let mut moved_by_cluster: Vec<Vec<NodeId>> = vec![Vec::new(); n_clusters];

    let mut was_overloaded: Vec<bool> =
        (0..dep.n()).map(|n| state.actual_overloaded(n, cfg.reward.alpha)).collect();
    let alpha = cfg.reward.alpha;
    let check_overloads =
        |state: &ResourceState, metrics: &mut RunMetrics, was: &mut Vec<bool>| {
            for n in 0..was.len() {
                let now = state.actual_overloaded(n, alpha);
                if now && !was[n] {
                    metrics.runtime_overloads += 1;
                }
                was[n] = now;
            }
        };

    // Collision count at the previous Sample event (windowed-delta
    // sampler state; read-only w.r.t. the simulation).
    let mut last_collisions: usize = 0;

    while let Some(ev) = queue.pop() {
        obs::sim_time(ev.t);
        let _ev_span = obs::span(obs::Phase::EventDispatch);
        match ev.kind {
            EventKind::JobArrival { wave } => {
                let w = &waves[wave];
                obs::event(obs::TraceKind::Arrival, ev.t, w.cluster as f64, w.jobs.len() as f64);
                let shield = shields[w.cluster].as_dyn();
                let out: WaveOutcome = match method {
                    Method::Rl => central_wave_dynamic(
                        &dep, &membership, &mut state, &graph, &w.jobs, policy, &cfg.reward, dc,
                        &mut rng,
                    ),
                    Method::Marl | Method::SroleC | Method::SroleD => marl_wave_dynamic(
                        &dep, &membership, &mut state, &graph, &w.jobs, policy, shield,
                        &cfg.reward, cfg.refresh_rounds, dc, &mut rng,
                    ),
                };
                metrics.collisions += out.collisions;
                metrics.shield_corrections += out.shield_corrections;
                let cl = w.cluster as f64;
                obs::event(obs::TraceKind::Placement, ev.t, cl, out.schedules.len() as f64);
                if out.collisions > 0 {
                    obs::event(obs::TraceKind::Collision, ev.t, cl, out.collisions as f64);
                }
                if out.shield_corrections > 0 {
                    obs::event(obs::TraceKind::Correction, ev.t, cl, out.shield_corrections as f64);
                }
                for s in out.schedules {
                    let ji = s.job.id;
                    let start = ev.t + s.decision_secs;
                    queue.push(start, EventKind::IterEnd { job: ji });
                    runs[ji] = Some(Run { sched: s, start, iters_done: 0, done: false });
                }
                check_overloads(&state, &mut metrics, &mut was_overloaded);
            }
            EventKind::IterEnd { job } => {
                let run = runs[job].as_mut().expect("IterEnd for an unscheduled job");
                if run.done {
                    continue;
                }
                if ev.t > run.start {
                    run.iters_done += 1;
                }
                if run.iters_done >= run.sched.job.iterations {
                    run.done = true;
                    remaining -= 1;
                    for &h in &run.sched.handles {
                        state.release(h);
                    }
                    run.sched.handles.clear();
                    let train_secs = ev.t - run.start;
                    policy.learn(&run.sched.episode, train_secs.max(1.0), &cfg.reward);
                    metrics.jct.push(train_secs);
                    metrics.decision_secs.push(run.sched.decision_secs);
                    metrics.sched_secs.push(run.sched.sched_secs);
                    metrics.shield_secs.push(run.sched.shield_secs);
                    metrics.memory_violations += run.sched.memory_violations;
                    metrics.makespan = metrics.makespan.max(ev.t);
                    check_overloads(&state, &mut metrics, &mut was_overloaded);
                    if remaining == 0 && ev.t >= horizon {
                        break;
                    }
                } else {
                    let head = alive_head(&dep, &membership, run.sched.job.cluster);
                    let mut dt = timing::iteration_secs(
                        &dep,
                        &state,
                        &graph,
                        &run.sched.placement,
                        run.sched.job.owner,
                        head,
                        n_clusters,
                    );
                    if run.iters_done == 0 {
                        dt += timing::pipeline_fill_secs(&dep, &state, &graph, &run.sched.placement);
                    }
                    queue.push(ev.t + dt.max(1e-6), EventKind::IterEnd { job });
                }
            }
            EventKind::BgStart { bg } => {
                let b = &workload.background[bg];
                // A segment destined for a dead node is lost, not queued.
                if membership.is_alive(b.node) {
                    let h = state.place(b.node, b.demand, b.demand, false);
                    bg_handles[bg] = Some(h);
                    queue.push(b.end.max(ev.t), EventKind::BgEnd { bg });
                    check_overloads(&state, &mut metrics, &mut was_overloaded);
                }
            }
            EventKind::BgEnd { bg } => {
                if let Some(h) = bg_handles[bg].take() {
                    state.release(h);
                }
                check_overloads(&state, &mut metrics, &mut was_overloaded);
            }
            EventKind::Sample => {
                if remaining > 0 || ev.t < horizon {
                    for n in 0..dep.n() {
                        metrics.tasks_per_device.push(state.task_count(n) as f64);
                        metrics.util_cpu.push(
                            state.actual_util(n, crate::cluster::ResourceKind::Cpu).clamp(0.0, 2.0),
                        );
                        metrics.util_mem.push(
                            state.actual_util(n, crate::cluster::ResourceKind::Mem).clamp(0.0, 2.0),
                        );
                        metrics.util_bw.push(
                            state.actual_util(n, crate::cluster::ResourceKind::Bw).clamp(0.0, 2.0),
                        );
                    }
                    // Windowed samplers: read-only over the metrics just
                    // pushed and engine state (no RNG, pinned).
                    if obs::active() {
                        let n = dep.n();
                        let tail =
                            |v: &[f64]| crate::util::stats::mean_of(&v[v.len() - n..]);
                        obs::sample(obs::Series::QueueDepth, ev.t, queue.len() as f64);
                        obs::sample(obs::Series::UtilCpu, ev.t, tail(&metrics.util_cpu));
                        obs::sample(obs::Series::UtilMem, ev.t, tail(&metrics.util_mem));
                        obs::sample(obs::Series::UtilBw, ev.t, tail(&metrics.util_bw));
                        let window = metrics.collisions - last_collisions;
                        obs::sample(obs::Series::CollisionsWindow, ev.t, window as f64);
                        last_collisions = metrics.collisions;
                        let (_, rows, pads) = policy.batch_stats();
                        let rows = rows.saturating_sub(batch_baseline.1);
                        let pads = pads.saturating_sub(batch_baseline.2);
                        let occ =
                            if rows + pads > 0 { rows as f64 / (rows + pads) as f64 } else { 0.0 };
                        obs::sample(obs::Series::QnetOccupancy, ev.t, occ);
                    }
                    queue.push(ev.t + SAMPLE_PERIOD_SECS, EventKind::Sample);
                }
            }
            EventKind::ViewRefresh => {
                for (n, v) in view_demand.iter_mut().enumerate() {
                    *v = *state.demand(n);
                }
                if remaining > 0 {
                    queue.push(ev.t + VIEW_REFRESH_SECS, EventKind::ViewRefresh);
                }
            }
            EventKind::NodeFail { node } => {
                // Churn after the last completion cannot affect any job;
                // skip it so the failure count reflects failures the
                // scheduler actually experienced.
                if remaining == 0 {
                    continue;
                }
                // A spurious seed (already dead, or its cluster's last
                // alive member) never fails, so its blast fizzles too.
                if !membership.is_alive(node)
                    || membership.alive_members(dep.cluster_of(node)).len() <= 1
                {
                    continue;
                }
                // Correlated churn: a geographic blast radius takes down
                // every alive node within `r` meters of the seed —
                // measured at event time, so under mobility the blast
                // hits whoever is *currently* nearby.  The victim query
                // runs on the topology's spatial grid (O(k), ascending —
                // the same order the old O(n) scan produced, so replays
                // are unchanged); `nodes_within_scan` stays as the
                // reference pinned by the `net` equivalence tests.
                let mut victims = vec![node];
                if cfg.blast_radius_m > 0.0 {
                    dep.topo.nodes_within_into(node, cfg.blast_radius_m, &mut blast_scratch);
                    victims
                        .extend(blast_scratch.iter().copied().filter(|&v| membership.is_alive(v)));
                }
                for (vi, &victim) in victims.iter().enumerate() {
                    let cluster = dep.cluster_of(victim);
                    // Never empty a cluster: the last alive member
                    // survives (re-checked per victim as the blast
                    // shrinks memberships).
                    if !membership.is_alive(victim)
                        || membership.alive_members(cluster).len() <= 1
                    {
                        continue;
                    }
                    membership.fail(&dep, victim);
                    metrics.node_failures += 1;
                    obs::event(
                        obs::TraceKind::Failure,
                        ev.t,
                        victim as f64,
                        if vi > 0 { 1.0 } else { 0.0 },
                    );
                    if vi > 0 {
                        metrics.correlated_failures += 1;
                        // Secondary victims rejoin on the same schedule
                        // as their seed (seeds queue theirs up-front).
                        if cfg.rejoin_secs > 0.0 {
                            let back = ev.t + cfg.rejoin_secs;
                            queue.push(back, EventKind::NodeJoin { node: victim });
                        }
                    }
                    match &mut shields[cluster] {
                        ClusterShield::Central(s) => {
                            s.set_alive(Some(membership.alive_cluster_set(cluster).clone()));
                        }
                        ClusterShield::Decentral(s) => {
                            s.node_failed(&dep, victim);
                        }
                        ClusterShield::None => {}
                    }
                    // Background segments resident on the node are lost.
                    for (i, slot) in bg_handles.iter_mut().enumerate() {
                        if workload.background[i].node == victim {
                            if let Some(h) = slot.take() {
                                state.release(h);
                            }
                        }
                    }
                    // In-flight requests served by the node die with it
                    // (open-loop clients never retry); their stale
                    // `RequestDone` events no-op against the live map.
                    if !live.is_empty() {
                        let lost: Vec<usize> = live
                            .iter()
                            .filter(|(_, lr)| lr.host == victim)
                            .map(|(&id, _)| id)
                            .collect();
                        for id in lost {
                            let lr = live.remove(&id).unwrap();
                            state.release(lr.handle);
                            metrics.requests_failed += 1;
                            remaining -= 1;
                        }
                    }
                    // Strand and reschedule the DL layers the node hosted.
                    let mut stranded: Vec<Stranded> = Vec::new();
                    for (ji, run) in runs.iter_mut().enumerate() {
                        let Some(run) = run else { continue };
                        if run.done {
                            continue;
                        }
                        for (layer_id, &host) in run.sched.placement.iter().enumerate() {
                            if host == victim {
                                state.release(run.sched.handles[layer_id]);
                                stranded.push(Stranded {
                                    job: ji,
                                    owner: run.sched.job.owner,
                                    layer_id,
                                });
                            }
                        }
                    }
                    if !stranded.is_empty() {
                        let shield = shields[cluster].as_dyn();
                        let outcome = reschedule_stranded(
                            &dep, &membership, &state, &graph, &view_demand, &stranded, victim,
                            policy, shield, &cfg.reward, dc, &mut rng,
                        );
                        metrics.collisions += outcome.collisions;
                        metrics.shield_corrections += outcome.corrections;
                        metrics.rescheduled_layers += stranded.len();
                        for (s, &target) in stranded.iter().zip(&outcome.targets) {
                            // The cluster always keeps ≥1 alive member, so the
                            // handler's fallback guarantees a real target.
                            // With `cross_cluster`, an exhausted in-cluster
                            // search first tries an alive boundary-pair
                            // neighbor in an adjacent cluster.
                            let target = if target == usize::MAX {
                                let est = graph.layers[s.layer_id].demand();
                                match tree.as_ref().and_then(|tr| {
                                    cross_rescue(
                                        tr, &dep, &membership, &view_demand, &est, s.owner,
                                        cfg.reward.alpha, &mut cross_scratch,
                                    )
                                }) {
                                    Some((t, escalated)) => {
                                        metrics.cross_cluster_placements += 1;
                                        if escalated {
                                            metrics.shield_tree_escalations += 1;
                                        }
                                        t
                                    }
                                    None => membership.alive_members(cluster)[0],
                                }
                            } else {
                                target
                            };
                            let est = graph.layers[s.layer_id].demand();
                            let actual = noisy_demand(&est, &mut rng);
                            let h = state.place(target, est, actual, true);
                            let run = runs[s.job].as_mut().unwrap();
                            run.sched.placement[s.layer_id] = target;
                            run.sched.handles[s.layer_id] = h;
                        }
                        // Decision-latency accounting: every affected job pays
                        // the recovery round (Fig 7/12 under churn).
                        let mut charged: Vec<usize> = stranded.iter().map(|s| s.job).collect();
                        charged.sort_unstable();
                        charged.dedup();
                        for ji in charged {
                            let run = runs[ji].as_mut().unwrap();
                            run.sched.decision_secs += outcome.sched_secs + outcome.shield_secs;
                            run.sched.sched_secs += outcome.sched_secs;
                            run.sched.shield_secs += outcome.shield_secs;
                        }
                    }
                    check_overloads(&state, &mut metrics, &mut was_overloaded);
                }
            }
            EventKind::NodeJoin { node } => {
                if remaining == 0 || !membership.join(&dep, node) {
                    continue;
                }
                obs::event(obs::TraceKind::Join, ev.t, node as f64, 0.0);
                let cluster = dep.cluster_of(node);
                match &mut shields[cluster] {
                    ClusterShield::Central(s) => {
                        s.set_alive(Some(membership.alive_cluster_set(cluster).clone()));
                    }
                    ClusterShield::Decentral(s) => {
                        s.node_joined(&dep, node);
                    }
                    ClusterShield::None => {}
                }
            }
            EventKind::MobilityTick => {
                // Ticks stop with the last completion, like churn.
                if remaining == 0 {
                    continue;
                }
                let Some(dyn_topo) = mobility.as_mut() else { continue };
                queue.push(ev.t + cfg.mobility_tick_secs, EventKind::MobilityTick);
                let moved = dyn_topo.advance(ev.t, cfg.mobility_tick_secs, &mut dep.topo);
                if moved.is_empty() {
                    continue;
                }
                metrics.mobility_moves += moved.len();
                // Every position-derived structure refreshes: the
                // cluster-restricted adjacency, the alive overlay the
                // candidate sets read, and the SROLE-D region partition
                // (batched incremental handoff, pinned to the
                // from-scratch re-partition by equivalence tests).
                // Adjacency rebuilds run on the spatial grid (O(n·k));
                // the membership overlay rebuild stays a full pass —
                // cheap next to one shield round at tick granularity.
                dep.refresh_adjacency();
                let alive = membership.alive_set().clone();
                membership = Membership::rebuild(&dep, &alive);
                // Batched per-tick region refreshes (the ROADMAP
                // follow-up): group the tick's moved nodes per cluster
                // and hand each cluster's batch to its shield at once —
                // every affected sub-cluster's boundary pairs are
                // re-derived at most once per tick instead of once per
                // moved node.  Handoff decisions and counts are pinned
                // to the per-node path by equivalence tests
                // (`cluster::subcluster`, `shield::decentral`).
                for &node in &moved {
                    moved_by_cluster[dep.cluster_of(node)].push(node);
                }
                for (cluster, nodes) in moved_by_cluster.iter_mut().enumerate() {
                    if nodes.is_empty() {
                        continue;
                    }
                    if let ClusterShield::Decentral(s) = &mut shields[cluster] {
                        let handoffs = s.nodes_moved(&dep, nodes);
                        metrics.region_handoffs += handoffs;
                        if handoffs > 0 {
                            let (c, h) = (cluster as f64, handoffs as f64);
                            obs::event(obs::TraceKind::Handoff, ev.t, c, h);
                        }
                    }
                    nodes.clear();
                }
                // Mobility-aware scheduling: layers whose (alive) host
                // drifted out of the owning agent's transmission range
                // are migrated by the owners, through the same stale-view
                // + shield path as failure recovery.  Dead owners wait
                // for the failure handler instead.
                let mut per_cluster: Vec<Vec<Stranded>> = vec![Vec::new(); n_clusters];
                for (ji, run) in runs.iter().enumerate() {
                    let Some(run) = run else { continue };
                    let owner = run.sched.job.owner;
                    if run.done || !membership.is_alive(owner) {
                        continue;
                    }
                    // An owner with no in-range alternatives would only
                    // stack every remote layer onto itself — keep the
                    // old (alive, slow) placements instead.
                    if membership.alive_neighbors(owner).is_empty() {
                        continue;
                    }
                    for (layer_id, &host) in run.sched.placement.iter().enumerate() {
                        // With `cross_cluster`, a layer rescued to an
                        // adjacent cluster stays put while its (alive)
                        // host remains in transmission range — without
                        // this clause the alive-neighbor index (which is
                        // cluster-scoped) would re-strand it every tick.
                        let reachable = host == owner
                            || membership.alive_neighbors(owner).binary_search(&host).is_ok()
                            || (tree.is_some()
                                && membership.is_alive(host)
                                && dep.topo.neighbors_ref(owner).contains(&host));
                        if !reachable && membership.is_alive(host) {
                            per_cluster[run.sched.job.cluster].push(Stranded {
                                job: ji,
                                owner,
                                layer_id,
                            });
                        }
                    }
                }
                for (cluster, stranded) in per_cluster.iter().enumerate() {
                    if stranded.is_empty() {
                        continue;
                    }
                    // Remember the old hosts (the keep-in-place fallback:
                    // unlike failures, an out-of-range host still works —
                    // slowly) and release before the owners re-decide.
                    let mut old_hosts: Vec<NodeId> = Vec::with_capacity(stranded.len());
                    for s in stranded {
                        let run = runs[s.job].as_mut().unwrap();
                        old_hosts.push(run.sched.placement[s.layer_id]);
                        state.release(run.sched.handles[s.layer_id]);
                    }
                    let shield = shields[cluster].as_dyn();
                    let outcome = reschedule_migrated(
                        &dep, &membership, &state, &graph, &view_demand, stranded, policy,
                        shield, &cfg.reward, dc, &mut rng,
                    );
                    metrics.collisions += outcome.collisions;
                    metrics.shield_corrections += outcome.corrections;
                    for ((s, &target), &old) in
                        stranded.iter().zip(&outcome.targets).zip(&old_hosts)
                    {
                        // With `cross_cluster`, an exhausted in-cluster
                        // search tries an adjacent-cluster host before
                        // settling for the old (slow) placement.
                        let target = if target == usize::MAX {
                            let est = graph.layers[s.layer_id].demand();
                            match tree.as_ref().and_then(|tr| {
                                cross_rescue(
                                    tr, &dep, &membership, &view_demand, &est, s.owner,
                                    cfg.reward.alpha, &mut cross_scratch,
                                )
                            }) {
                                Some((t, escalated)) => {
                                    metrics.cross_cluster_placements += 1;
                                    if escalated {
                                        metrics.shield_tree_escalations += 1;
                                    }
                                    t
                                }
                                None => old,
                            }
                        } else {
                            target
                        };
                        if target != old {
                            metrics.migrated_layers += 1;
                        }
                        let est = graph.layers[s.layer_id].demand();
                        let actual = noisy_demand(&est, &mut rng);
                        let h = state.place(target, est, actual, true);
                        let run = runs[s.job].as_mut().unwrap();
                        run.sched.placement[s.layer_id] = target;
                        run.sched.handles[s.layer_id] = h;
                    }
                    // Migration rounds pay decision latency exactly like
                    // failure recovery (Fig 7/12 stay regenerable).
                    let mut charged: Vec<usize> = stranded.iter().map(|s| s.job).collect();
                    charged.sort_unstable();
                    charged.dedup();
                    for ji in charged {
                        let run = runs[ji].as_mut().unwrap();
                        run.sched.decision_secs += outcome.sched_secs + outcome.shield_secs;
                        run.sched.sched_secs += outcome.sched_secs;
                        run.sched.shield_secs += outcome.shield_secs;
                    }
                }
                check_overloads(&state, &mut metrics, &mut was_overloaded);
            }
            EventKind::RequestArrival { req } => {
                let r = &requests[req];
                // Queueing: the origin serializes its placement
                // decisions, so a request arriving while the previous
                // decision is still in flight waits its turn.
                let queue_wait = (origin_busy[r.origin] - ev.t).max(0.0);
                // Per-request private stream (see `REQ_STREAM_BASE`):
                // decision noise depends on (seed, id) alone, never on
                // event interleaving, so the sharded engine replays it.
                let mut req_rng = Rng::with_stream(seed, REQ_STREAM_BASE + req as u64);
                let shield = shields[r.cluster].as_dyn();
                let out = place_request(
                    &dep, &membership, &state, &graph.layers[0], &view_demand, req, r.origin,
                    &r.demand, policy, shield, &cfg.reward, &mut req_rng,
                );
                metrics.collisions += out.collisions;
                metrics.shield_corrections += out.corrections;
                let decision = out.sched_secs + out.shield_secs;
                origin_busy[r.origin] = ev.t + queue_wait + decision;
                match out.target {
                    None => {
                        // Admission control refused: the stale view says
                        // every candidate would cross α.  Open-loop
                        // clients don't retry.
                        metrics.requests_rejected += 1;
                        remaining -= 1;
                    }
                    Some(host) => {
                        let actual = noisy_demand(&r.demand, &mut req_rng);
                        let h = state.place(host, r.demand, actual, true);
                        // Latency account: queue + decision + transfer
                        // (input shipped origin→host through both NICs'
                        // contention shares) + service (processor
                        // sharing and memory pressure on the host).
                        let transfer = dep.topo.transfer_secs(r.origin, host, r.mb, 1)
                            / state.bw_share(r.origin).min(state.bw_share(host));
                        let service = r.service_secs
                            * (r.demand.cpu / state.cpu_share(host, r.demand.cpu)).max(1.0)
                            * state.mem_pressure(host);
                        let latency = queue_wait + decision + transfer + service;
                        live.insert(req, LiveRequest { handle: h, host, latency });
                        queue.push(ev.t + latency, EventKind::RequestDone { req });
                        check_overloads(&state, &mut metrics, &mut was_overloaded);
                    }
                }
            }
            EventKind::RequestDone { req } => {
                // Already evicted by a mid-service host failure.
                let Some(lr) = live.remove(&req) else { continue };
                state.release(lr.handle);
                req_latency[requests[req].cluster].push(lr.latency);
                metrics.requests_served += 1;
                if lr.latency > cfg.slo_secs {
                    metrics.slo_violations += 1;
                }
                metrics.makespan = metrics.makespan.max(ev.t);
                // No early loop break (unlike `IterEnd`): the sharded
                // engine's lanes cannot observe the global remaining
                // count mid-epoch, so serving runs drain their queues in
                // both engines — that shared semantics is what makes
                // them byte-identical, unlike training.
                remaining -= 1;
                check_overloads(&state, &mut metrics, &mut was_overloaded);
            }
        }
    }
    for lane in &mut req_latency {
        metrics.request_latency.append(lane);
    }
    metrics.qnet_fwd_errors = policy.fwd_errors().saturating_sub(fwd_errors_baseline);
    let (fwds, rows, pads) = policy.batch_stats();
    metrics.qnet_batch_fwds = fwds.saturating_sub(batch_baseline.0);
    metrics.qnet_batch_rows = rows.saturating_sub(batch_baseline.1);
    metrics.qnet_batch_pad_rows = pads.saturating_sub(batch_baseline.2);
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Experiment;
    use crate::dnn::ModelKind;
    use crate::workload::ArrivalProcess;

    fn churn_cfg() -> ExperimentConfig {
        ExperimentConfig {
            n_edges: 10,
            cluster_size: 5,
            model: ModelKind::Rnn,
            iterations: 5,
            pretrain_episodes: 20,
            repetitions: 1,
            failure_rate: 3.0,
            rejoin_secs: 120.0,
            ..Default::default()
        }
    }

    #[test]
    fn dynamic_run_completes_all_jobs_under_failures() {
        let cfg = churn_cfg();
        assert!(cfg.dynamic());
        for m in Method::ALL {
            let r = run_dynamic(&cfg, m, 5);
            assert_eq!(r.jct.len(), 2 * 3, "{}: wrong job count", m.name());
            assert!(r.jct.iter().all(|&t| t.is_finite() && t > 0.0));
            assert!(!r.decision_secs.is_empty());
        }
    }

    #[test]
    fn dynamic_run_is_deterministic() {
        let cfg = churn_cfg();
        for m in [Method::Marl, Method::SroleD] {
            let a = run_dynamic(&cfg, m, 11);
            let b = run_dynamic(&cfg, m, 11);
            assert_eq!(a.jct, b.jct, "{}", m.name());
            assert_eq!(a.collisions, b.collisions);
            assert_eq!(a.decision_secs, b.decision_secs);
            assert_eq!(a.node_failures, b.node_failures);
            assert_eq!(a.rescheduled_layers, b.rescheduled_layers);
        }
    }

    #[test]
    fn failures_actually_fire_and_reschedule() {
        // Over a few seeds the churn schedule must deliver failures, and
        // failures on busy nodes must strand + reschedule layers.
        let mut failures = 0;
        let mut rescheduled = 0;
        for seed in [1u64, 2, 3] {
            let r = run_dynamic(&churn_cfg(), Method::SroleC, seed);
            failures += r.node_failures;
            rescheduled += r.rescheduled_layers;
        }
        assert!(failures > 0, "no failure event fired across 3 seeds");
        assert!(rescheduled > 0, "failures never stranded a layer");
    }

    #[test]
    fn experiment_routes_dynamic_configs_through_event_driver() {
        let cfg = churn_cfg();
        let exp = Experiment::new(cfg);
        let r = exp.run_once(Method::Marl, 7);
        let direct = run_dynamic(&exp.cfg, Method::Marl, 7);
        assert_eq!(r.jct, direct.jct);
        assert_eq!(r.node_failures, direct.node_failures);
    }

    #[test]
    fn poisson_arrivals_run_event_driven() {
        let mut cfg = churn_cfg();
        cfg.failure_rate = 0.0;
        cfg.arrival = ArrivalProcess::Poisson { rate: 0.05 };
        assert!(cfg.dynamic());
        let r = run_dynamic(&cfg, Method::SroleD, 3);
        assert_eq!(r.jct.len(), 6);
        assert_eq!(r.node_failures, 0);
    }

    #[test]
    fn static_configs_keep_the_wave_path() {
        // A default (non-churn) config must not route through the dynamic
        // driver — its metrics match the legacy wave path exactly.
        let mut cfg = churn_cfg();
        cfg.failure_rate = 0.0;
        assert!(!cfg.dynamic());
    }

    fn mobility_cfg(speed: f64) -> ExperimentConfig {
        ExperimentConfig {
            n_edges: 10,
            cluster_size: 5,
            model: ModelKind::Rnn,
            iterations: 5,
            pretrain_episodes: 20,
            repetitions: 1,
            mobility: crate::net::MobilityModel::RandomWaypoint {
                speed_mps: speed,
                pause_secs: 0.0,
            },
            mobility_tick_secs: 10.0,
            ..Default::default()
        }
    }

    #[test]
    fn mobile_runs_complete_all_jobs() {
        let cfg = mobility_cfg(2.0);
        assert!(cfg.dynamic(), "mobility must route through the event driver");
        for m in Method::ALL {
            let r = run_dynamic(&cfg, m, 5);
            assert_eq!(r.jct.len(), 2 * 3, "{}: wrong job count", m.name());
            assert!(r.jct.iter().all(|&t| t.is_finite() && t > 0.0), "{}", m.name());
            assert_eq!(r.node_failures, 0);
        }
    }

    #[test]
    fn mobile_runs_are_deterministic() {
        let cfg = mobility_cfg(2.0);
        for m in [Method::Marl, Method::SroleD] {
            let a = run_dynamic(&cfg, m, 11);
            let b = run_dynamic(&cfg, m, 11);
            assert_eq!(a.jct, b.jct, "{}", m.name());
            assert_eq!(a.collisions, b.collisions);
            assert_eq!(a.mobility_moves, b.mobility_moves);
            assert_eq!(a.region_handoffs, b.region_handoffs);
            assert_eq!(a.migrated_layers, b.migrated_layers);
        }
    }

    #[test]
    fn mobility_actually_moves_and_hands_off_regions() {
        // Across a few seeds, motion must be delivered and SROLE-D must
        // observe shield-region handoffs (nodes crossing sub-cluster
        // boundaries while alive — the ROADMAP follow-up this subsystem
        // exists for).
        let mut moves = 0;
        let mut handoffs = 0;
        for seed in [1u64, 2, 3] {
            let r = run_dynamic(&mobility_cfg(3.0), Method::SroleD, seed);
            moves += r.mobility_moves;
            handoffs += r.region_handoffs;
        }
        assert!(moves > 0, "no node ever moved across 3 seeds");
        assert!(handoffs > 0, "no shield-region handoff across 3 seeds");
    }

    #[test]
    fn zero_speed_mobility_is_static() {
        let cfg = mobility_cfg(0.0);
        assert!(!cfg.mobility.enabled());
        assert!(!cfg.dynamic(), "zero speed must not force the dynamic driver");
    }

    #[test]
    fn mobility_composes_with_churn() {
        let mut cfg = mobility_cfg(2.0);
        cfg.failure_rate = 3.0;
        cfg.rejoin_secs = 120.0;
        let a = run_dynamic(&cfg, Method::SroleD, 9);
        let b = run_dynamic(&cfg, Method::SroleD, 9);
        assert_eq!(a.jct.len(), 6);
        assert_eq!(a.jct, b.jct, "churn + mobility must stay deterministic");
        assert_eq!(a.node_failures, b.node_failures);
        assert_eq!(a.region_handoffs, b.region_handoffs);
    }

    #[test]
    fn cross_rescue_respects_view_overload_and_interior_pairs() {
        let mut rng = Rng::new(7);
        let dep = Deployment::generate_spread(
            &mut rng,
            20,
            5,
            &crate::cluster::CONTAINER_PROFILE,
            40.0,
        );
        let membership = Membership::full(&dep);
        let est = Resources::new(0.1, 0.1, 0.1);
        let mut scratch = Vec::new();
        let idle: Vec<Resources> = (0..dep.n()).map(|_| Resources::new(0.0, 0.0, 0.0)).collect();
        let full: Vec<Resources> = dep
            .nodes
            .iter()
            .map(|n| Resources::new(n.caps.cpu * 10.0, n.caps.mem * 10.0, n.caps.bw * 10.0))
            .collect();

        // Everything under one super-shield: every admitted rescue is
        // group-local, and a saturated stale view admits nothing.
        let one_group = ShieldTree::build(&dep, dep.clusters.len().max(1));
        let mut hits = 0usize;
        for owner in 0..dep.n() {
            if let Some((t, escalated)) =
                cross_rescue(&one_group, &dep, &membership, &idle, &est, owner, 0.8, &mut scratch)
            {
                hits += 1;
                assert!(!escalated, "a single group cannot escalate");
                assert_ne!(dep.cluster_of(t), dep.cluster_of(owner));
                assert!(membership.is_alive(t));
                assert_eq!(
                    cross_rescue(
                        &one_group, &dep, &membership, &full, &est, owner, 0.8, &mut scratch
                    ),
                    None,
                    "an overloaded view must not admit a rescue"
                );
            }
        }
        assert!(hits > 0, "no cross rescue ever admitted in a 40 m spread");

        // Fanout 1 (finest grouping): the escalation verdict must match
        // the group structure for every admitted rescue.
        let fine = ShieldTree::build(&dep, 1);
        for owner in 0..dep.n() {
            if let Some((t, escalated)) =
                cross_rescue(&fine, &dep, &membership, &idle, &est, owner, 0.8, &mut scratch)
            {
                assert_eq!(
                    escalated,
                    !fine.interior(dep.cluster_of(owner), dep.cluster_of(t))
                );
            }
        }
    }

    #[test]
    fn cross_cluster_runs_are_deterministic_and_off_by_default() {
        let mut cfg = churn_cfg();
        cfg.cluster_spread_m = 40.0;
        cfg.tree_fanout = 2;
        cfg.cross_cluster = true;
        cfg.validate().expect("cross_cluster config must validate");
        let a = run_dynamic(&cfg, Method::SroleD, 11);
        let b = run_dynamic(&cfg, Method::SroleD, 11);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.jct.len(), 6, "jobs must still complete with cross-cluster rescue on");
        // Off by default: with the rescue disabled the tree knob must
        // not perturb this engine at all.
        cfg.cross_cluster = false;
        let base = run_dynamic(&cfg, Method::SroleD, 11);
        cfg.tree_fanout = 0;
        let flat = run_dynamic(&cfg, Method::SroleD, 11);
        assert_eq!(base.to_json().to_string(), flat.to_json().to_string());
        assert_eq!(base.cross_cluster_placements, 0);
        assert_eq!(base.shield_tree_escalations, 0);
    }

    fn serving_cfg() -> ExperimentConfig {
        ExperimentConfig {
            n_edges: 10,
            cluster_size: 5,
            model: ModelKind::Rnn,
            iterations: 1,
            pretrain_episodes: 20,
            repetitions: 1,
            serving: true,
            request_rate: 0.05,
            ..Default::default()
        }
    }

    #[test]
    fn serving_runs_serve_requests_with_latency_accounting() {
        let cfg = serving_cfg();
        assert!(cfg.dynamic(), "serving must route through the event driver");
        for m in Method::ALL {
            let r = run_dynamic(&cfg, m, 5);
            assert!(r.requests_served > 0, "{}: no request served", m.name());
            assert_eq!(
                r.request_latency.len(),
                r.requests_served,
                "{}: one latency sample per served request",
                m.name()
            );
            assert!(r.request_latency.iter().all(|&l| l.is_finite() && l > 0.0), "{}", m.name());
            assert!(r.jct.is_empty(), "{}: serving runs host no training jobs", m.name());
            let p = r.request_summary().expect("served requests imply a summary");
            assert!(p.p50 <= p.p99 && p.p99 <= p.p999, "{}", m.name());
        }
    }

    #[test]
    fn serving_runs_are_deterministic_and_training_is_untouched() {
        let cfg = serving_cfg();
        let a = run_dynamic(&cfg, Method::SroleD, 11);
        let b = run_dynamic(&cfg, Method::SroleD, 11);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        // Training runs must not grow serving metrics.
        let t = run_dynamic(&churn_cfg(), Method::SroleD, 11);
        assert!(t.request_latency.is_empty());
        assert_eq!(
            (t.requests_served, t.requests_rejected, t.requests_failed, t.slo_violations),
            (0, 0, 0, 0)
        );
    }

    #[test]
    fn zero_slo_flags_every_served_request() {
        let mut cfg = serving_cfg();
        cfg.slo_secs = 0.0;
        let r = run_dynamic(&cfg, Method::SroleC, 3);
        assert!(r.requests_served > 0);
        assert_eq!(r.slo_violations, r.requests_served, "every positive latency violates SLO 0");
    }

    #[test]
    fn serving_composes_with_churn_and_mobility() {
        let mut cfg = serving_cfg();
        cfg.failure_rate = 3.0;
        cfg.rejoin_secs = 120.0;
        cfg.mobility =
            crate::net::MobilityModel::RandomWaypoint { speed_mps: 2.0, pause_secs: 0.0 };
        cfg.mobility_tick_secs = 10.0;
        let a = run_dynamic(&cfg, Method::SroleD, 9);
        let b = run_dynamic(&cfg, Method::SroleD, 9);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(a.requests_served > 0, "churn + mobility must not starve the stream");
        assert_eq!(a.requests_served, a.request_latency.len());
    }

    #[test]
    fn blast_radius_correlates_failures() {
        // A huge blast radius turns every seed failure into a correlated
        // group (bounded by the never-empty-a-cluster invariant); zero
        // radius keeps failures independent.
        let mut cfg = churn_cfg();
        cfg.blast_radius_m = 1e9;
        let mut correlated = 0;
        for seed in [1u64, 2, 3] {
            let r = run_dynamic(&cfg, Method::SroleC, seed);
            assert_eq!(r.jct.len(), 6, "jobs must still complete under blasts");
            correlated += r.correlated_failures;
        }
        assert!(correlated > 0, "a 1e9 m blast radius never took a second node down");

        let mut cfg0 = churn_cfg();
        cfg0.blast_radius_m = 0.0;
        for seed in [1u64, 2, 3] {
            let r = run_dynamic(&cfg0, Method::SroleC, seed);
            assert_eq!(r.correlated_failures, 0, "independent failures must not correlate");
        }

        // Determinism under correlated churn.
        let a = run_dynamic(&cfg, Method::SroleD, 4);
        let b = run_dynamic(&cfg, Method::SroleD, 4);
        assert_eq!(a.jct, b.jct);
        assert_eq!(a.correlated_failures, b.correlated_failures);
    }
}
