//! Thread-based cluster emulation: real distributed data-parallel
//! training with a Rust parameter server.
//!
//! This is the live analog of the paper's TensorFlow parameter-server
//! strategy: each emulated edge node is an OS thread owning its own PJRT
//! engine; per step the parameter server broadcasts parameters, workers
//! compute gradients on their local data shard through the AOT-compiled
//! `lm_grad` artifact (Pallas kernels inside), and the PS averages and
//! applies them with `lm_update`.  All request-path compute is Rust +
//! PJRT — Python is not running.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::runtime::lm::{average_grads, LmSession};
use crate::util::error::{Context, Result};
use crate::runtime::Engine;
use crate::util::Rng;

/// Parameter-server training configuration.
#[derive(Debug, Clone)]
pub struct PsConfig {
    /// Worker threads (emulated edge nodes holding data shards).
    pub workers: usize,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Evaluate + log every this many steps.
    pub log_every: usize,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig { workers: 3, steps: 60, lr: 0.5, seed: 1, log_every: 10 }
    }
}

/// One logged training step.
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    /// Mean worker loss at this step.
    pub loss: f32,
    /// Wall-clock milliseconds for the full PS round.
    pub wall_ms: f64,
}

enum Cmd {
    Step { params: Arc<Vec<Vec<f32>>>, tokens: Vec<i32> },
    Stop,
}

struct WorkerReply {
    #[allow(dead_code)]
    worker: usize,
    grads: Vec<Vec<f32>>,
    loss: f32,
}

/// Deterministic synthetic corpus: a noisy cyclic Markov chain over the
/// vocabulary — trivially learnable, so the loss curve demonstrably
/// falls below the uniform entropy ln(V).
pub struct SyntheticCorpus {
    rng: Rng,
    vocab: usize,
}

impl SyntheticCorpus {
    pub fn new(seed: u64, vocab: usize) -> SyntheticCorpus {
        SyntheticCorpus { rng: Rng::new(seed), vocab }
    }

    /// Sample a `[batch, seq+1]` token block (row-major).
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let mut cur = self.rng.below(self.vocab) as i32;
            out.push(cur);
            for _ in 0..seq {
                cur = if self.rng.chance(0.1) {
                    self.rng.below(self.vocab) as i32
                } else {
                    (cur + 7) % self.vocab as i32
                };
                out.push(cur);
            }
        }
        out
    }
}

/// Run data-parallel PS training; returns the loss curve.
pub fn train_data_parallel(artifacts_dir: &std::path::Path, cfg: &PsConfig) -> Result<Vec<StepLog>> {
    let mut engine = Engine::open(artifacts_dir)?;
    let vocab = engine.manifest.meta_usize("lm", "vocab")?;
    let seq = engine.manifest.meta_usize("lm", "seq")?;
    let batch = engine.manifest.meta_usize("lm", "batch")?;
    let mut ps = LmSession::new(&mut engine, cfg.seed as i32).context("PS session")?;

    // Spawn workers, each with its own engine (its own PJRT client).
    let (reply_tx, reply_rx) = mpsc::channel::<Result<WorkerReply>>();
    let mut cmd_txs = Vec::with_capacity(cfg.workers);
    let mut joins = Vec::with_capacity(cfg.workers);
    let dir = artifacts_dir.to_path_buf();
    for w in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<Cmd>();
        cmd_txs.push(tx);
        let reply = reply_tx.clone();
        let dir = dir.clone();
        joins.push(std::thread::spawn(move || {
            let run = || -> Result<()> {
                let mut eng = Engine::open(&dir)?;
                let mut session = LmSession::new(&mut eng, 0)?;
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Stop => break,
                        Cmd::Step { params, tokens } => {
                            session.set_params_host(&params)?;
                            let (grads, loss) = session.grad_host(&tokens)?;
                            reply.send(Ok(WorkerReply { worker: w, grads, loss })).ok();
                        }
                    }
                }
                Ok(())
            };
            if let Err(e) = run() {
                reply.send(Err(e)).ok();
            }
        }));
    }
    drop(reply_tx);

    // Each worker has its own shard (distinct corpus stream).
    let mut shards: Vec<SyntheticCorpus> =
        (0..cfg.workers).map(|w| SyntheticCorpus::new(cfg.seed * 7919 + w as u64, vocab)).collect();

    let mut logs = Vec::new();
    for step in 0..cfg.steps {
        let t0 = Instant::now();
        let params = Arc::new(ps.params_host()?);
        for (w, tx) in cmd_txs.iter().enumerate() {
            let tokens = shards[w].batch(batch, seq);
            tx.send(Cmd::Step { params: params.clone(), tokens })
                .map_err(|_| crate::format_err!("worker {w} died"))?;
        }
        let mut worker_grads = Vec::with_capacity(cfg.workers);
        let mut losses = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers {
            let r = reply_rx.recv().context("worker reply")??;
            losses.push(r.loss);
            worker_grads.push(r.grads);
        }
        let avg = average_grads(&worker_grads);
        ps.update_host(&avg, cfg.lr)?;
        let loss = losses.iter().sum::<f32>() / losses.len() as f32;
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            logs.push(StepLog { step, loss, wall_ms: t0.elapsed().as_secs_f64() * 1e3 });
        }
    }

    for tx in &cmd_txs {
        tx.send(Cmd::Stop).ok();
    }
    for j in joins {
        j.join().ok();
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_deterministic_and_in_range() {
        let mut a = SyntheticCorpus::new(5, 512);
        let mut b = SyntheticCorpus::new(5, 512);
        let ba = a.batch(4, 16);
        let bb = b.batch(4, 16);
        assert_eq!(ba, bb);
        assert_eq!(ba.len(), 4 * 17);
        assert!(ba.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn corpus_is_mostly_cyclic() {
        let mut c = SyntheticCorpus::new(9, 512);
        let b = c.batch(8, 32);
        let mut cyclic = 0;
        let mut total = 0;
        for row in b.chunks(33) {
            for w in row.windows(2) {
                total += 1;
                if w[1] == (w[0] + 7) % 512 {
                    cyclic += 1;
                }
            }
        }
        let frac = cyclic as f64 / total as f64;
        assert!(frac > 0.8, "cyclic fraction {frac}");
    }

    // The full PS loop is exercised by rust/tests/integration.rs
    // (emu_ps_round_trains, artifact-gated) and by
    // examples/edge_cluster_train.rs (end-to-end with loss logging).
}
