//! Table I resource configurations.
//!
//! | Environment | Resource ranges                               |
//! |-------------|-----------------------------------------------|
//! | Real edge   | Mem ∈ {1024, 2048, 4096} MB                   |
//! |             | CPU ∈ {0.25, 0.5, 1.0} host ratio             |
//! |             | BW ∈ {20, 100} MBps                           |
//! | Container   | Mem ∈ {768, 1024, 1536, 2048, 4096} MB        |
//! |             | CPU ∈ [0.3, 1.0] host ratio                   |
//! |             | BW ∈ {50, 100, 200, 500, 1000} Mbps           |
//!
//! Resources are assigned round-robin across nodes, exactly as §V-A
//! describes.  The real-edge testbed is additionally specialized by
//! [`real_device_memories`] (2×1 GB + 4×2 GB + 4×4 GB Raspberry Pis).

use super::Resources;

/// A named resource profile (one row group of Table I).
#[derive(Debug, Clone)]
pub struct ResourceProfile {
    pub name: &'static str,
    pub mem_choices: &'static [f64],
    pub cpu_choices: &'static [f64],
    /// Per-node bandwidth capacity choices, Mbps.
    pub bw_node_choices: &'static [f64],
    /// Pairwise link bandwidth choices, Mbps (drives `Topology::bw`).
    pub bw_choices: Vec<f64>,
    /// Geographic spread of a cluster (m) and transmission range (m).
    pub cluster_spread_m: f64,
    pub range_m: f64,
    /// Control-message latency (s).
    pub latency_s: f64,
    /// Effective speed of this testbed's core relative to the reference
    /// host core (Raspberry Pi ARM cores deliver less DNN throughput per
    /// "host ratio" than EC2 vCPUs).
    pub cpu_scale: f64,
}

/// Emulation profile ("Container" rows of Table I).
pub static CONTAINER_PROFILE: std::sync::LazyLock<ResourceProfile> =
    std::sync::LazyLock::new(|| ResourceProfile {
        name: "container",
        mem_choices: &[768.0, 1024.0, 1536.0, 2048.0, 4096.0],
        // CPU ∈ [0.3, 1.0]: represent the continuous range by an even grid
        // (round-robin over it reproduces the paper's spread).
        cpu_choices: &[0.3, 0.475, 0.65, 0.825, 1.0],
        bw_node_choices: &[50.0, 100.0, 200.0, 500.0, 1000.0],
        bw_choices: vec![50.0, 100.0, 200.0, 500.0, 1000.0],
        cluster_spread_m: 10.0,
        range_m: 25.0,
        latency_s: 0.002,
        cpu_scale: 1.0,
    });

/// Real-device profile ("Real edge" rows of Table I): 10 Raspberry Pis on
/// 2.4 GHz Wi-Fi.  BW {20,100} *MBps* = {160, 800} Mbps.
pub static REAL_EDGE_PROFILE: std::sync::LazyLock<ResourceProfile> =
    std::sync::LazyLock::new(|| ResourceProfile {
        name: "real_edge",
        mem_choices: &[1024.0, 2048.0, 4096.0],
        cpu_choices: &[0.25, 0.5, 1.0],
        bw_node_choices: &[160.0, 800.0],
        bw_choices: vec![160.0, 800.0],
        cluster_spread_m: 15.0,
        range_m: 40.0,
        latency_s: 0.005,
        cpu_scale: 0.85,
    });

impl ResourceProfile {
    /// Round-robin capacity assignment for node `id` (§V-A).
    pub fn round_robin(&self, id: usize) -> Resources {
        Resources {
            cpu: self.cpu_choices[id % self.cpu_choices.len()] * self.cpu_scale,
            mem: self.mem_choices[id % self.mem_choices.len()],
            bw: self.bw_node_choices[id % self.bw_node_choices.len()],
        }
    }
}

/// The exact real testbed of §V-A: "two Pis have 1 GB memory, four other
/// Pis have 2 GB memory and four other Pis have 4 GB memory".
pub fn real_device_memories() -> [f64; 10] {
    [1024.0, 1024.0, 2048.0, 2048.0, 2048.0, 2048.0, 4096.0, 4096.0, 4096.0, 4096.0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn container_matches_table_i() {
        let p = &*CONTAINER_PROFILE;
        assert_eq!(p.mem_choices, &[768.0, 1024.0, 1536.0, 2048.0, 4096.0]);
        assert!(p.cpu_choices.iter().all(|&c| (0.3..=1.0).contains(&c)));
        assert_eq!(p.bw_node_choices.len(), 5);
    }

    #[test]
    fn real_edge_matches_table_i() {
        let p = &*REAL_EDGE_PROFILE;
        assert_eq!(p.mem_choices, &[1024.0, 2048.0, 4096.0]);
        assert_eq!(p.cpu_choices, &[0.25, 0.5, 1.0]);
    }

    #[test]
    fn round_robin_cycles_through_choices() {
        let p = &*CONTAINER_PROFILE;
        let r0 = p.round_robin(0);
        let r5 = p.round_robin(5);
        assert_eq!(r0.mem, r5.mem);
        assert_eq!(r0.cpu, r5.cpu);
        let r1 = p.round_robin(1);
        assert_ne!(r0.mem, r1.mem);
    }

    #[test]
    fn pi_memory_mix() {
        let mems = real_device_memories();
        assert_eq!(mems.iter().filter(|&&m| m == 1024.0).count(), 2);
        assert_eq!(mems.iter().filter(|&&m| m == 2048.0).count(), 4);
        assert_eq!(mems.iter().filter(|&&m| m == 4096.0).count(), 4);
    }
}
