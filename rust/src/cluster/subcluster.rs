//! Sub-cluster partitioning for decentralized shielding (§IV-D).
//!
//! "A large cluster is divided into multiple sub-clusters according to the
//! geographical proximity" — implemented as k-means on node positions
//! (deterministic farthest-point initialization, fixed iteration count).
//! Boundary nodes are those within transmission range of a node in a
//! different sub-cluster; each pair of *neighboring* sub-clusters elects a
//! delegate shield for its shared boundary.

use super::NodeId;
use crate::net::Topology;

/// The sub-cluster decomposition of one cluster.
#[derive(Debug, Clone)]
pub struct SubClusters {
    /// `assignment[i]` = sub-cluster index of `members[i]`.
    pub members: Vec<NodeId>,
    pub assignment: Vec<usize>,
    pub k: usize,
    /// Boundary node set per sub-cluster pair `(a, b)`, a < b: nodes of
    /// either sub-cluster within the boundary distance of the other.
    pub boundaries: Vec<((usize, usize), Vec<NodeId>)>,
}

/// A node counts as *on the boundary* when it sits within this fraction
/// of the transmission range of a node in another sub-cluster.  Below
/// 1.0 this admits missed collisions from across the (larger) full
/// transmission range — the fidelity gap §IV-D accepts by design.
pub const BOUNDARY_RANGE_FRAC: f64 = 0.6;

impl SubClusters {
    /// Partition `members` into `k` sub-clusters by position.
    pub fn build(members: &[NodeId], topo: &Topology, k: usize) -> SubClusters {
        let k = k.clamp(1, members.len().max(1));
        let assignment = kmeans(members, topo, k);
        let mut sc = SubClusters { members: members.to_vec(), assignment, k, boundaries: Vec::new() };
        sc.boundaries = sc.find_boundaries(topo);
        sc
    }

    pub fn sub_of(&self, node: NodeId) -> usize {
        let idx = self.members.iter().position(|&m| m == node).expect("node not a member");
        self.assignment[idx]
    }

    pub fn members_of(&self, sub: usize) -> Vec<NodeId> {
        self.members
            .iter()
            .zip(&self.assignment)
            .filter(|(_, &a)| a == sub)
            .map(|(&m, _)| m)
            .collect()
    }

    /// Delegate for a sub-cluster pair: the lowest node id among the pair's
    /// boundary nodes' sub-cluster shields — deterministic election.
    pub fn delegate(&self, a: usize, b: usize) -> usize {
        a.min(b)
    }

    fn find_boundaries(&self, topo: &Topology) -> Vec<((usize, usize), Vec<NodeId>)> {
        let mut out: Vec<((usize, usize), Vec<NodeId>)> = Vec::new();
        for (i, &m) in self.members.iter().enumerate() {
            for (j, &n) in self.members.iter().enumerate() {
                if i >= j || self.assignment[i] == self.assignment[j] {
                    continue;
                }
                if topo.positions[m].dist(&topo.positions[n]) <= topo.range * BOUNDARY_RANGE_FRAC {
                    let key = if self.assignment[i] < self.assignment[j] {
                        (self.assignment[i], self.assignment[j])
                    } else {
                        (self.assignment[j], self.assignment[i])
                    };
                    let entry = match out.iter_mut().find(|(k2, _)| *k2 == key) {
                        Some(e) => e,
                        None => {
                            out.push((key, Vec::new()));
                            out.last_mut().unwrap()
                        }
                    };
                    for node in [m, n] {
                        if !entry.1.contains(&node) {
                            entry.1.push(node);
                        }
                    }
                }
            }
        }
        out.sort_by_key(|(k2, _)| *k2);
        out
    }

    /// All boundary nodes (union over pairs).
    pub fn boundary_nodes(&self) -> Vec<NodeId> {
        let mut out: Vec<NodeId> = Vec::new();
        for (_, nodes) in &self.boundaries {
            for &n in nodes {
                if !out.contains(&n) {
                    out.push(n);
                }
            }
        }
        out.sort_unstable();
        out
    }
}

/// Deterministic k-means on member positions: farthest-point init, 16
/// Lloyd iterations (converges long before that at this scale).
fn kmeans(members: &[NodeId], topo: &Topology, k: usize) -> Vec<usize> {
    let pts: Vec<(f64, f64)> =
        members.iter().map(|&m| (topo.positions[m].x, topo.positions[m].y)).collect();
    if k <= 1 || members.len() <= k {
        return (0..members.len()).map(|i| if members.len() <= k { i } else { 0 }).collect();
    }
    // Farthest-point initialization from the centroid-closest point.
    let mut centers: Vec<(f64, f64)> = Vec::with_capacity(k);
    centers.push(pts[0]);
    while centers.len() < k {
        let far = pts
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                let da = centers.iter().map(|c| d2(**a, *c)).fold(f64::MAX, f64::min);
                let db = centers.iter().map(|c| d2(**b, *c)).fold(f64::MAX, f64::min);
                da.partial_cmp(&db).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        centers.push(pts[far]);
    }
    let mut assignment = vec![0usize; pts.len()];
    for _ in 0..16 {
        for (i, p) in pts.iter().enumerate() {
            assignment[i] = centers
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| d2(*p, **a).partial_cmp(&d2(*p, **b)).unwrap())
                .map(|(j, _)| j)
                .unwrap();
        }
        for (j, c) in centers.iter_mut().enumerate() {
            let mine: Vec<&(f64, f64)> =
                pts.iter().zip(&assignment).filter(|(_, &a)| a == j).map(|(p, _)| p).collect();
            if !mine.is_empty() {
                c.0 = mine.iter().map(|p| p.0).sum::<f64>() / mine.len() as f64;
                c.1 = mine.iter().map(|p| p.1).sum::<f64>() / mine.len() as f64;
            }
        }
    }
    assignment
}

fn d2(a: (f64, f64), b: (f64, f64)) -> f64 {
    (a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Topology;
    use crate::util::Rng;

    fn topo(n: usize) -> Topology {
        let mut rng = Rng::new(3);
        Topology::generate(&mut rng, n, 60.0, 30.0, &[100.0], 0.001)
    }

    #[test]
    fn partitions_all_members() {
        let t = topo(20);
        let members: Vec<NodeId> = (0..20).collect();
        let sc = SubClusters::build(&members, &t, 4);
        assert_eq!(sc.assignment.len(), 20);
        for sub in 0..4 {
            assert!(!sc.members_of(sub).is_empty(), "empty sub-cluster {sub}");
        }
        let total: usize = (0..4).map(|s| sc.members_of(s).len()).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn geographic_coherence() {
        // Sub-cluster diameter should be smaller than the full spread.
        let t = topo(30);
        let members: Vec<NodeId> = (0..30).collect();
        let sc = SubClusters::build(&members, &t, 3);
        let full_diam = max_diam(&members, &t);
        for sub in 0..3 {
            let m = sc.members_of(sub);
            if m.len() >= 2 {
                assert!(max_diam(&m, &t) <= full_diam);
            }
        }
    }

    fn max_diam(nodes: &[NodeId], t: &Topology) -> f64 {
        let mut d = 0.0f64;
        for &a in nodes {
            for &b in nodes {
                d = d.max(t.positions[a].dist(&t.positions[b]));
            }
        }
        d
    }

    #[test]
    fn boundaries_are_cross_subcluster_and_in_range() {
        let t = topo(24);
        let members: Vec<NodeId> = (0..24).collect();
        let sc = SubClusters::build(&members, &t, 3);
        for ((a, b), nodes) in &sc.boundaries {
            assert!(a < b);
            for &n in nodes {
                let sn = sc.sub_of(n);
                assert!(sn == *a || sn == *b);
                // Each boundary node must be within range of some node of
                // the *other* sub-cluster of the pair.
                let other = if sn == *a { *b } else { *a };
                let reach = sc
                    .members_of(other)
                    .iter()
                    .any(|&m| t.positions[n].dist(&t.positions[m]) <= t.range * BOUNDARY_RANGE_FRAC);
                assert!(reach, "node {n} not actually on boundary");
            }
        }
    }

    #[test]
    fn k_one_is_single_subcluster() {
        let t = topo(10);
        let members: Vec<NodeId> = (0..10).collect();
        let sc = SubClusters::build(&members, &t, 1);
        assert!(sc.assignment.iter().all(|&a| a == 0));
        assert!(sc.boundaries.is_empty());
    }

    #[test]
    fn delegate_is_deterministic() {
        let t = topo(12);
        let sc = SubClusters::build(&(0..12).collect::<Vec<_>>(), &t, 3);
        assert_eq!(sc.delegate(2, 1), 1);
        assert_eq!(sc.delegate(0, 2), 0);
    }

    #[test]
    fn deterministic_build() {
        let t = topo(18);
        let m: Vec<NodeId> = (0..18).collect();
        let a = SubClusters::build(&m, &t, 3);
        let b = SubClusters::build(&m, &t, 3);
        assert_eq!(a.assignment, b.assignment);
    }
}
