//! Sub-cluster partitioning for decentralized shielding (§IV-D).
//!
//! "A large cluster is divided into multiple sub-clusters according to the
//! geographical proximity" — implemented as k-means on node positions
//! (deterministic farthest-point initialization, fixed iteration count).
//! Boundary nodes are those within transmission range of a node in a
//! different sub-cluster; each pair of *neighboring* sub-clusters elects a
//! delegate shield for its shared boundary.

use super::NodeId;
use crate::net::Topology;
use crate::util::NodeSet;

/// The sub-cluster decomposition of one cluster.
///
/// Besides the raw partition, [`SubClusters::build`] precomputes dense
/// lookup tables over the whole deployment's node-id space — sub-cluster
/// id per node, boundary membership, per-pair boundary sets and per-pair
/// allowed-target sets — so the SROLE-D shield's per-round checks are
/// O(1) per query instead of `Vec::contains` scans.
#[derive(Debug, Clone)]
pub struct SubClusters {
    /// `assignment[i]` = sub-cluster index of `members[i]`.
    pub members: Vec<NodeId>,
    pub assignment: Vec<usize>,
    pub k: usize,
    /// Boundary node set per sub-cluster pair `(a, b)`, a < b: nodes of
    /// either sub-cluster within the boundary distance of the other.
    pub boundaries: Vec<((usize, usize), Vec<NodeId>)>,
    /// `sub_index[node]` = sub-cluster of `node`, `usize::MAX` for
    /// non-members.  Dense over the deployment's node ids.
    sub_index: Vec<usize>,
    /// Union of all boundary nodes.
    boundary_set: NodeSet,
    /// Members per sub-cluster (original `members` order).
    per_sub: Vec<Vec<NodeId>>,
    /// Member set per sub-cluster.
    sub_sets: Vec<NodeSet>,
    /// Boundary-node set per pair (parallel to `boundaries`).
    pair_boundary: Vec<NodeSet>,
    /// Allowed correction targets per pair: union of the pair's two
    /// sub-cluster member sets (parallel to `boundaries`).
    pair_allowed: Vec<NodeSet>,
}

/// A node counts as *on the boundary* when it sits within this fraction
/// of the transmission range of a node in another sub-cluster.  Below
/// 1.0 this admits missed collisions from across the (larger) full
/// transmission range — the fidelity gap §IV-D accepts by design.
pub const BOUNDARY_RANGE_FRAC: f64 = 0.6;

impl SubClusters {
    /// Partition `members` into `k` sub-clusters by position and build
    /// the dense lookup tables.
    pub fn build(members: &[NodeId], topo: &Topology, k: usize) -> SubClusters {
        let k = k.clamp(1, members.len().max(1));
        let assignment = kmeans(members, topo, k);
        let n = topo.n();
        let mut sc = SubClusters {
            members: members.to_vec(),
            assignment,
            k,
            boundaries: Vec::new(),
            sub_index: Vec::new(),
            boundary_set: NodeSet::with_universe(n),
            per_sub: Vec::new(),
            sub_sets: Vec::new(),
            pair_boundary: Vec::new(),
            pair_allowed: Vec::new(),
        };
        sc.boundaries = sc.find_boundaries(topo);
        sc.build_indices(n);
        sc
    }

    /// Precompute the O(1) lookup tables from the raw partition.
    fn build_indices(&mut self, n: usize) {
        self.sub_index = vec![usize::MAX; n];
        self.per_sub = vec![Vec::new(); self.k];
        self.sub_sets = (0..self.k).map(|_| NodeSet::with_universe(n)).collect();
        for (&m, &a) in self.members.iter().zip(&self.assignment) {
            self.sub_index[m] = a;
            self.per_sub[a].push(m);
            self.sub_sets[a].insert(m);
        }
        self.boundary_set = NodeSet::with_universe(n);
        self.pair_boundary = Vec::with_capacity(self.boundaries.len());
        self.pair_allowed = Vec::with_capacity(self.boundaries.len());
        for ((a, b), nodes) in &self.boundaries {
            for &node in nodes {
                self.boundary_set.insert(node);
            }
            self.pair_boundary.push(NodeSet::from_slice(n, nodes));
            let mut allowed = self.sub_sets[*a].clone();
            allowed.union_with(&self.sub_sets[*b]);
            self.pair_allowed.push(allowed);
        }
    }

    /// Sub-cluster of `node` (O(1); panics for non-members, matching the
    /// previous scan-based behavior).
    #[inline]
    pub fn sub_of(&self, node: NodeId) -> usize {
        let s = self.sub_index.get(node).copied().unwrap_or(usize::MAX);
        assert!(s != usize::MAX, "node not a member");
        s
    }

    /// Whether `node` belongs to this decomposition (O(1)).
    #[inline]
    pub fn is_member(&self, node: NodeId) -> bool {
        self.sub_index.get(node).copied().unwrap_or(usize::MAX) != usize::MAX
    }

    /// Whether `node` belongs to sub-cluster `sub` (O(1)).
    #[inline]
    pub fn in_sub(&self, node: NodeId, sub: usize) -> bool {
        self.sub_index.get(node).copied() == Some(sub)
    }

    /// Whether `node` lies on any sub-cluster boundary (O(1)).
    #[inline]
    pub fn is_boundary(&self, node: NodeId) -> bool {
        self.boundary_set.contains(node)
    }

    pub fn members_of(&self, sub: usize) -> Vec<NodeId> {
        self.per_sub[sub].clone()
    }

    /// Borrowed member list of one sub-cluster.
    #[inline]
    pub fn sub_members(&self, sub: usize) -> &[NodeId] {
        &self.per_sub[sub]
    }

    /// Member set of one sub-cluster (for O(1) allowed-target checks).
    #[inline]
    pub fn sub_set(&self, sub: usize) -> &NodeSet {
        &self.sub_sets[sub]
    }

    /// Boundary-node set of pair `pair_idx` (parallel to `boundaries`).
    #[inline]
    pub fn pair_boundary_set(&self, pair_idx: usize) -> &NodeSet {
        &self.pair_boundary[pair_idx]
    }

    /// Allowed correction targets of pair `pair_idx`: the union of the
    /// pair's two sub-cluster member sets.
    #[inline]
    pub fn pair_allowed_set(&self, pair_idx: usize) -> &NodeSet {
        &self.pair_allowed[pair_idx]
    }

    /// Delegate for a sub-cluster pair: the lowest node id among the pair's
    /// boundary nodes' sub-cluster shields — deterministic election.
    pub fn delegate(&self, a: usize, b: usize) -> usize {
        a.min(b)
    }

    fn find_boundaries(&self, topo: &Topology) -> Vec<((usize, usize), Vec<NodeId>)> {
        let mut out: Vec<((usize, usize), Vec<NodeId>)> = Vec::new();
        for (i, &m) in self.members.iter().enumerate() {
            for (j, &n) in self.members.iter().enumerate() {
                if i >= j || self.assignment[i] == self.assignment[j] {
                    continue;
                }
                if topo.positions[m].dist(&topo.positions[n]) <= topo.range * BOUNDARY_RANGE_FRAC {
                    let key = if self.assignment[i] < self.assignment[j] {
                        (self.assignment[i], self.assignment[j])
                    } else {
                        (self.assignment[j], self.assignment[i])
                    };
                    let entry = match out.iter_mut().find(|(k2, _)| *k2 == key) {
                        Some(e) => e,
                        None => {
                            out.push((key, Vec::new()));
                            out.last_mut().unwrap()
                        }
                    };
                    for node in [m, n] {
                        if !entry.1.contains(&node) {
                            entry.1.push(node);
                        }
                    }
                }
            }
        }
        out.sort_by_key(|(k2, _)| *k2);
        out
    }

    /// All boundary nodes (union over pairs), ascending.
    pub fn boundary_nodes(&self) -> Vec<NodeId> {
        self.boundary_set.iter().collect()
    }
}

/// Deterministic k-means on member positions: farthest-point init, 16
/// Lloyd iterations (converges long before that at this scale).
fn kmeans(members: &[NodeId], topo: &Topology, k: usize) -> Vec<usize> {
    let pts: Vec<(f64, f64)> =
        members.iter().map(|&m| (topo.positions[m].x, topo.positions[m].y)).collect();
    if k <= 1 || members.len() <= k {
        return (0..members.len()).map(|i| if members.len() <= k { i } else { 0 }).collect();
    }
    // Farthest-point initialization from the centroid-closest point.
    let mut centers: Vec<(f64, f64)> = Vec::with_capacity(k);
    centers.push(pts[0]);
    while centers.len() < k {
        let far = pts
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                let da = centers.iter().map(|c| d2(**a, *c)).fold(f64::MAX, f64::min);
                let db = centers.iter().map(|c| d2(**b, *c)).fold(f64::MAX, f64::min);
                da.partial_cmp(&db).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        centers.push(pts[far]);
    }
    let mut assignment = vec![0usize; pts.len()];
    for _ in 0..16 {
        for (i, p) in pts.iter().enumerate() {
            assignment[i] = centers
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| d2(*p, **a).partial_cmp(&d2(*p, **b)).unwrap())
                .map(|(j, _)| j)
                .unwrap();
        }
        for (j, c) in centers.iter_mut().enumerate() {
            let mine: Vec<&(f64, f64)> =
                pts.iter().zip(&assignment).filter(|(_, &a)| a == j).map(|(p, _)| p).collect();
            if !mine.is_empty() {
                c.0 = mine.iter().map(|p| p.0).sum::<f64>() / mine.len() as f64;
                c.1 = mine.iter().map(|p| p.1).sum::<f64>() / mine.len() as f64;
            }
        }
    }
    assignment
}

fn d2(a: (f64, f64), b: (f64, f64)) -> f64 {
    (a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Topology;
    use crate::util::Rng;

    fn topo(n: usize) -> Topology {
        let mut rng = Rng::new(3);
        Topology::generate(&mut rng, n, 60.0, 30.0, &[100.0], 0.001)
    }

    #[test]
    fn partitions_all_members() {
        let t = topo(20);
        let members: Vec<NodeId> = (0..20).collect();
        let sc = SubClusters::build(&members, &t, 4);
        assert_eq!(sc.assignment.len(), 20);
        for sub in 0..4 {
            assert!(!sc.members_of(sub).is_empty(), "empty sub-cluster {sub}");
        }
        let total: usize = (0..4).map(|s| sc.members_of(s).len()).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn geographic_coherence() {
        // Sub-cluster diameter should be smaller than the full spread.
        let t = topo(30);
        let members: Vec<NodeId> = (0..30).collect();
        let sc = SubClusters::build(&members, &t, 3);
        let full_diam = max_diam(&members, &t);
        for sub in 0..3 {
            let m = sc.members_of(sub);
            if m.len() >= 2 {
                assert!(max_diam(&m, &t) <= full_diam);
            }
        }
    }

    fn max_diam(nodes: &[NodeId], t: &Topology) -> f64 {
        let mut d = 0.0f64;
        for &a in nodes {
            for &b in nodes {
                d = d.max(t.positions[a].dist(&t.positions[b]));
            }
        }
        d
    }

    #[test]
    fn boundaries_are_cross_subcluster_and_in_range() {
        let t = topo(24);
        let members: Vec<NodeId> = (0..24).collect();
        let sc = SubClusters::build(&members, &t, 3);
        for ((a, b), nodes) in &sc.boundaries {
            assert!(a < b);
            for &n in nodes {
                let sn = sc.sub_of(n);
                assert!(sn == *a || sn == *b);
                // Each boundary node must be within range of some node of
                // the *other* sub-cluster of the pair.
                let other = if sn == *a { *b } else { *a };
                let reach = sc
                    .members_of(other)
                    .iter()
                    .any(|&m| t.positions[n].dist(&t.positions[m]) <= t.range * BOUNDARY_RANGE_FRAC);
                assert!(reach, "node {n} not actually on boundary");
            }
        }
    }

    #[test]
    fn k_one_is_single_subcluster() {
        let t = topo(10);
        let members: Vec<NodeId> = (0..10).collect();
        let sc = SubClusters::build(&members, &t, 1);
        assert!(sc.assignment.iter().all(|&a| a == 0));
        assert!(sc.boundaries.is_empty());
    }

    #[test]
    fn delegate_is_deterministic() {
        let t = topo(12);
        let sc = SubClusters::build(&(0..12).collect::<Vec<_>>(), &t, 3);
        assert_eq!(sc.delegate(2, 1), 1);
        assert_eq!(sc.delegate(0, 2), 0);
    }

    #[test]
    fn deterministic_build() {
        let t = topo(18);
        let m: Vec<NodeId> = (0..18).collect();
        let a = SubClusters::build(&m, &t, 3);
        let b = SubClusters::build(&m, &t, 3);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn indexed_lookups_agree_with_scans() {
        // The O(1) tables must answer exactly like the Vec scans they
        // replaced.
        let t = topo(24);
        let members: Vec<NodeId> = (0..24).collect();
        let sc = SubClusters::build(&members, &t, 3);
        let boundary = {
            // Scan-based union over pairs (the pre-index implementation).
            let mut out: Vec<NodeId> = Vec::new();
            for (_, nodes) in &sc.boundaries {
                for &n in nodes {
                    if !out.contains(&n) {
                        out.push(n);
                    }
                }
            }
            out.sort_unstable();
            out
        };
        assert_eq!(sc.boundary_nodes(), boundary);
        for n in 0..24 {
            let scan_sub =
                sc.members.iter().position(|&m| m == n).map(|i| sc.assignment[i]).unwrap();
            assert_eq!(sc.sub_of(n), scan_sub);
            assert!(sc.is_member(n));
            assert!(sc.in_sub(n, scan_sub));
            assert!(!sc.in_sub(n, scan_sub + 7));
            assert_eq!(sc.is_boundary(n), boundary.contains(&n));
        }
        assert!(!sc.is_member(24), "out-of-universe node is not a member");
        for (pi, ((a, b), nodes)) in sc.boundaries.iter().enumerate() {
            for n in 0..24 {
                assert_eq!(sc.pair_boundary_set(pi).contains(n), nodes.contains(&n));
                let in_union =
                    sc.members_of(*a).contains(&n) || sc.members_of(*b).contains(&n);
                assert_eq!(sc.pair_allowed_set(pi).contains(n), in_union);
            }
        }
        for s in 0..3 {
            assert_eq!(sc.sub_members(s), &sc.members_of(s)[..]);
            for &m in sc.sub_members(s) {
                assert!(sc.sub_set(s).contains(m));
            }
            assert_eq!(sc.sub_set(s).len(), sc.sub_members(s).len());
        }
    }

    #[test]
    fn partial_membership_indexed() {
        // Members are a strict subset of the topology's nodes: the index
        // must distinguish non-members from members at O(1).
        let t = topo(20);
        let members: Vec<NodeId> = (0..10).collect();
        let sc = SubClusters::build(&members, &t, 2);
        for n in 0..10 {
            assert!(sc.is_member(n));
        }
        for n in 10..20 {
            assert!(!sc.is_member(n));
            assert!(!sc.is_boundary(n));
        }
    }
}
