//! Sub-cluster partitioning for decentralized shielding (§IV-D).
//!
//! "A large cluster is divided into multiple sub-clusters according to the
//! geographical proximity" — implemented two ways behind one entry point:
//! small memberships run deterministic k-means on node positions
//! (farthest-point initialization, fixed iteration count) plus an O(m²)
//! boundary scan; memberships of [`GRID_PARTITION_THRESHOLD`] and above
//! run the grid-backed partitioner, which merges [`SpatialGrid`] cells
//! down to ≤ k regions and derives boundary pairs from grid adjacency in
//! O(m·k).  The k-means + scan path stays in-tree as the pinned
//! equivalence reference (ARCHITECTURE.md policy).
//!
//! Boundary nodes are those within transmission range of a node in a
//! different sub-cluster; each pair of *neighboring* sub-clusters elects a
//! delegate shield for its shared boundary.

use super::NodeId;
use crate::net::{Pos, SpatialGrid, Topology};
use crate::util::NodeSet;

/// The sub-cluster decomposition of one cluster.
///
/// Besides the raw partition, [`SubClusters::build`] precomputes dense
/// lookup tables over the whole deployment's node-id space — sub-cluster
/// id per node, boundary membership, per-pair boundary sets and per-pair
/// allowed-target sets — so the SROLE-D shield's per-round checks are
/// O(1) per query instead of `Vec::contains` scans.
///
/// Membership is *mutable*: [`SubClusters::remove_member`] and
/// [`SubClusters::add_member`] maintain every table incrementally when
/// the event core delivers node churn, re-deriving only the boundary
/// pairs of the affected sub-cluster.  The incremental path is pinned to
/// the [`SubClusters::from_assignment`] reference rebuild by randomized
/// equivalence tests.
#[derive(Debug, Clone, PartialEq)]
pub struct SubClusters {
    /// `assignment[i]` = sub-cluster index of `members[i]`.
    pub members: Vec<NodeId>,
    pub assignment: Vec<usize>,
    pub k: usize,
    /// Boundary node set per sub-cluster pair `(a, b)`, a < b: nodes of
    /// either sub-cluster within the boundary distance of the other.
    pub boundaries: Vec<((usize, usize), Vec<NodeId>)>,
    /// `sub_index[node]` = sub-cluster of `node`, `usize::MAX` for
    /// non-members.  Dense over the deployment's node ids.
    sub_index: Vec<usize>,
    /// Union of all boundary nodes.
    boundary_set: NodeSet,
    /// Members per sub-cluster (original `members` order).
    per_sub: Vec<Vec<NodeId>>,
    /// Member set per sub-cluster.
    sub_sets: Vec<NodeSet>,
    /// Boundary-node set per pair (parallel to `boundaries`).
    pair_boundary: Vec<NodeSet>,
    /// Allowed correction targets per pair: union of the pair's two
    /// sub-cluster member sets (parallel to `boundaries`).
    pair_allowed: Vec<NodeSet>,
}

/// A node counts as *on the boundary* when it sits within this fraction
/// of the transmission range of a node in another sub-cluster.  Below
/// 1.0 this admits missed collisions from across the (larger) full
/// transmission range — the fidelity gap §IV-D accepts by design.
pub const BOUNDARY_RANGE_FRAC: f64 = 0.6;

/// Memberships at or above this size build through the grid-backed
/// partitioner (cell-merge regions + grid-adjacency boundary pairs);
/// below it the original k-means + O(m²) scan runs — small memberships
/// keep their historical partitions bit-exactly, and the scan is the
/// faster option there anyway.
pub const GRID_PARTITION_THRESHOLD: usize = 64;

impl SubClusters {
    /// Partition `members` into (at most) `k` sub-clusters by position
    /// and build the dense lookup tables.  Large memberships
    /// (≥ [`GRID_PARTITION_THRESHOLD`]) route through the grid-backed
    /// cell-merge partitioner; small ones keep the k-means reference
    /// path.  Either way the boundary/delegate tables come out of the
    /// same accumulation rules, pinned to the O(m²) scan reference by
    /// equivalence tests.
    pub fn build(members: &[NodeId], topo: &Topology, k: usize) -> SubClusters {
        if members.len() >= GRID_PARTITION_THRESHOLD {
            SubClusters::build_grid(members, topo, k)
        } else {
            SubClusters::build_reference(members, topo, k)
        }
    }

    /// The pinned reference builder: deterministic k-means assignment +
    /// the O(m²) boundary scan (exactly the pre-grid `build`).  Kept
    /// in-tree per the ARCHITECTURE.md pinning policy; the grid builder's
    /// boundary derivation is equivalence-tested against it.
    pub fn build_reference(members: &[NodeId], topo: &Topology, k: usize) -> SubClusters {
        let k = k.clamp(1, members.len().max(1));
        let assignment = kmeans(members, topo, k);
        SubClusters::from_assignment_reference(members.to_vec(), assignment, k, topo)
    }

    /// Grid-backed builder: seed regions from [`SpatialGrid`] cells
    /// (cell-merge down to ≤ `k` regions, so degenerate inputs — fewer
    /// occupied cells than `k`, all-coincident positions — yield fewer
    /// regions instead of panicking), then derive boundary pairs from
    /// grid adjacency in O(m·k) instead of the all-pairs scan.
    pub fn build_grid(members: &[NodeId], topo: &Topology, k: usize) -> SubClusters {
        let k = k.clamp(1, members.len().max(1));
        let (assignment, k_eff) = grid_partition(members, topo, k);
        SubClusters::from_assignment(members.to_vec(), assignment, k_eff, topo)
    }

    /// Build from a fixed `(members, assignment)` pair — the from-scratch
    /// construction the incremental membership ops
    /// ([`SubClusters::remove_member`] / [`SubClusters::add_member`]) are
    /// pinned against by randomized equivalence tests.  Boundary pairs
    /// derive through the grid for large memberships (byte-identical to
    /// the scan — see [`SubClusters::from_assignment_reference`]).
    pub fn from_assignment(
        members: Vec<NodeId>,
        assignment: Vec<usize>,
        k: usize,
        topo: &Topology,
    ) -> SubClusters {
        SubClusters::from_assignment_impl(members, assignment, k, topo, false)
    }

    /// Reference construction forcing the O(m²) boundary scan regardless
    /// of membership size — what the grid-backed builds and incremental
    /// updates are pinned against by randomized equivalence tests.
    pub fn from_assignment_reference(
        members: Vec<NodeId>,
        assignment: Vec<usize>,
        k: usize,
        topo: &Topology,
    ) -> SubClusters {
        SubClusters::from_assignment_impl(members, assignment, k, topo, true)
    }

    fn from_assignment_impl(
        members: Vec<NodeId>,
        assignment: Vec<usize>,
        k: usize,
        topo: &Topology,
        force_scan: bool,
    ) -> SubClusters {
        assert_eq!(members.len(), assignment.len());
        let n = topo.n();
        let mut sc = SubClusters {
            members,
            assignment,
            k,
            boundaries: Vec::new(),
            sub_index: Vec::new(),
            boundary_set: NodeSet::with_universe(n),
            per_sub: Vec::new(),
            sub_sets: Vec::new(),
            pair_boundary: Vec::new(),
            pair_allowed: Vec::new(),
        };
        sc.boundaries =
            if force_scan { sc.find_boundaries_scan(topo) } else { sc.find_boundaries(topo) };
        sc.build_indices(n);
        sc
    }

    /// Precompute the O(1) lookup tables from the raw partition.
    fn build_indices(&mut self, n: usize) {
        self.sub_index = vec![usize::MAX; n];
        self.per_sub = vec![Vec::new(); self.k];
        self.sub_sets = (0..self.k).map(|_| NodeSet::with_universe(n)).collect();
        for (&m, &a) in self.members.iter().zip(&self.assignment) {
            self.sub_index[m] = a;
            self.per_sub[a].push(m);
            self.sub_sets[a].insert(m);
        }
        self.rebuild_pair_tables(n);
    }

    /// Rebuild the boundary-derived tables (`boundary_set`,
    /// `pair_boundary`, `pair_allowed`) from `boundaries` + `sub_sets`.
    /// O(pairs · boundary nodes) — cheap next to a boundary rescan.
    fn rebuild_pair_tables(&mut self, n: usize) {
        self.boundary_set = NodeSet::with_universe(n);
        self.pair_boundary = Vec::with_capacity(self.boundaries.len());
        self.pair_allowed = Vec::with_capacity(self.boundaries.len());
        for ((a, b), nodes) in &self.boundaries {
            for &node in nodes {
                self.boundary_set.insert(node);
            }
            self.pair_boundary.push(NodeSet::from_slice(n, nodes));
            let mut allowed = self.sub_sets[*a].clone();
            allowed.union_with(&self.sub_sets[*b]);
            self.pair_allowed.push(allowed);
        }
    }

    /// Incremental membership removal (node failed / left the cluster):
    /// drop `node` from its sub-cluster and re-derive *only* the boundary
    /// pairs involving that sub-cluster — no k-means re-run, no all-pairs
    /// rescan.  Returns false when `node` is not a member (no-op).
    ///
    /// Equivalent to `from_assignment` over the shrunk member list —
    /// pinned by randomized equivalence tests.
    pub fn remove_member(&mut self, node: NodeId, topo: &Topology) -> bool {
        let Some(idx) = self.members.iter().position(|&m| m == node) else {
            return false;
        };
        let sub = self.assignment[idx];
        self.members.remove(idx);
        self.assignment.remove(idx);
        if let Some(pos) = self.per_sub[sub].iter().position(|&m| m == node) {
            self.per_sub[sub].remove(pos);
        }
        self.sub_sets[sub].remove(node);
        self.sub_index[node] = usize::MAX;
        self.refresh_pairs_of(sub, topo);
        true
    }

    /// Incremental membership addition (node joined the cluster): assign
    /// `node` to the sub-cluster with the nearest member centroid
    /// (deterministic; ties resolve to the lowest sub-cluster index) and
    /// re-derive only the boundary pairs involving that sub-cluster.
    /// Returns false when `node` is already a member (no-op).
    pub fn add_member(&mut self, node: NodeId, topo: &Topology) -> bool {
        if self.is_member(node) {
            return false;
        }
        let sub = self.nearest_sub(node, topo);
        self.members.push(node);
        self.assignment.push(sub);
        self.per_sub[sub].push(node);
        self.sub_sets[sub].insert(node);
        if node >= self.sub_index.len() {
            self.sub_index.resize(node + 1, usize::MAX);
        }
        self.sub_index[node] = sub;
        self.refresh_pairs_of(sub, topo);
        true
    }

    /// Sub-cluster whose member centroid is closest to `node`; empty
    /// sub-clusters are skipped (everything empty falls back to 0).
    fn nearest_sub(&self, node: NodeId, topo: &Topology) -> usize {
        self.nearest_sub_excluding(node, topo, usize::MAX)
    }

    /// Like [`SubClusters::nearest_sub`], but `exclude` is left out of
    /// every centroid — the handoff decision must not let a moving node
    /// drag its own sub-cluster's centroid along.  Deterministic; ties
    /// resolve to the lowest sub-cluster index.
    fn nearest_sub_excluding(&self, node: NodeId, topo: &Topology, exclude: NodeId) -> usize {
        let p = (topo.positions[node].x, topo.positions[node].y);
        let mut best: Option<(f64, usize)> = None;
        for (s, members) in self.per_sub.iter().enumerate() {
            let (mut cx, mut cy) = (0.0, 0.0);
            let mut count = 0usize;
            for &m in members {
                if m == exclude {
                    continue;
                }
                cx += topo.positions[m].x;
                cy += topo.positions[m].y;
                count += 1;
            }
            if count == 0 {
                continue;
            }
            let c = (cx / count as f64, cy / count as f64);
            let dist = d2(p, c);
            if best.map(|(bd, _)| dist < bd).unwrap_or(true) {
                best = Some((dist, s));
            }
        }
        best.map(|(_, s)| s).unwrap_or(0)
    }

    /// Mobility handler: `node`'s position changed.  Re-evaluates which
    /// sub-cluster the node belongs to (nearest member centroid, its own
    /// position excluded) and re-derives the boundary pairs of every
    /// affected sub-cluster — the old region, plus the new one when the
    /// node migrates — leaving all other pairs untouched.  Returns true
    /// when the node was handed off between sub-clusters; false for a
    /// same-region move (boundaries still refresh: the node's distances
    /// to other regions changed) and for non-members (no-op).
    ///
    /// Equivalent to [`SubClusters::from_assignment`] over the updated
    /// `(members, assignment)` pair and the current positions — pinned by
    /// randomized equivalence tests.
    ///
    /// A node that is its sub-cluster's last member migrates like any
    /// other (its own position never votes): the emptied region simply
    /// stops owning nodes until churn or another handoff repopulates it.
    pub fn handoff_member(&mut self, node: NodeId, topo: &Topology) -> bool {
        if !self.is_member(node) {
            return false;
        }
        let old = self.sub_index[node];
        let new = self.nearest_sub_excluding(node, topo, node);
        if new == old {
            // The node moved within its region: pairs involving that
            // region still see new distances.
            self.refresh_pairs_of(old, topo);
            return false;
        }
        self.migrate_member(node, old, new);
        self.refresh_pairs_of(old, topo);
        self.refresh_pairs_of(new, topo);
        true
    }

    /// Batched mobility handler: re-evaluate every node of `nodes` (in
    /// order, as the per-node path would) but defer the boundary-pair
    /// refreshes, issuing `refresh_pairs_of` at most once per
    /// *affected* sub-cluster at the end of the batch.  Handoff
    /// decisions only read membership and positions — never the
    /// boundary tables — so the final region assignment, the boundary
    /// pairs and the returned handoff count are identical to calling
    /// [`SubClusters::handoff_member`] once per node; only the ≤ k
    /// refreshes are shared.  Pinned by randomized equivalence tests.
    ///
    /// Returns the number of nodes handed off between sub-clusters.
    pub fn handoff_members(&mut self, nodes: &[NodeId], topo: &Topology) -> usize {
        let mut affected: Vec<usize> = Vec::new();
        let mut handoffs = 0usize;
        for &node in nodes {
            if !self.is_member(node) {
                continue;
            }
            let old = self.sub_index[node];
            let new = self.nearest_sub_excluding(node, topo, node);
            // Same-region moves still dirty the region's pairs (the
            // node's distances to other regions changed).
            if !affected.contains(&old) {
                affected.push(old);
            }
            if new != old {
                self.migrate_member(node, old, new);
                if !affected.contains(&new) {
                    affected.push(new);
                }
                handoffs += 1;
            }
        }
        affected.sort_unstable();
        for &sub in &affected {
            self.refresh_pairs_of(sub, topo);
        }
        handoffs
    }

    /// Move `node` from sub-cluster `old` to `new` in every membership
    /// table, leaving the boundary-pair tables to the caller's refresh.
    fn migrate_member(&mut self, node: NodeId, old: usize, new: usize) {
        let idx = self.members.iter().position(|&m| m == node).expect("member index");
        self.assignment[idx] = new;
        let pos = self.per_sub[old].iter().position(|&m| m == node).expect("per-sub slot");
        self.per_sub[old].remove(pos);
        self.sub_sets[old].remove(node);
        // Insert preserving `members`-list order (what `from_assignment`
        // produces), not push order.
        let insert_at = self.members[..idx]
            .iter()
            .zip(&self.assignment[..idx])
            .filter(|&(_, &a)| a == new)
            .count();
        self.per_sub[new].insert(insert_at, node);
        self.sub_sets[new].insert(node);
        self.sub_index[node] = new;
    }

    /// Recompute the boundary pairs involving `sub` from the current
    /// partition, keeping every other pair untouched, then re-derive the
    /// (small, O(k²)-sized) pair tables.  The member scan visits only the
    /// (i, j) index pairs that cross `sub` — O(|sub| · members) instead
    /// of the full O(members²) boundary rescan — in the full scan's
    /// lexicographic order, so per-pair node vectors come out identical
    /// to a [`SubClusters::from_assignment`] reference rebuild.
    fn refresh_pairs_of(&mut self, sub: usize, topo: &Topology) {
        if self.members.len() >= GRID_PARTITION_THRESHOLD {
            self.refresh_pairs_of_grid(sub, topo);
        } else {
            self.refresh_pairs_of_scan(sub, topo);
        }
    }

    /// Reference refresh: the O(|sub| · members) index scan.  What the
    /// grid-backed refresh is pinned against (via the reference rebuild
    /// in the randomized equivalence tests).
    fn refresh_pairs_of_scan(&mut self, sub: usize, topo: &Topology) {
        let m_len = self.members.len();
        // Member indices of `sub`, ascending.
        let sub_idx: Vec<usize> = (0..m_len).filter(|&i| self.assignment[i] == sub).collect();
        let mut fresh: Vec<((usize, usize), Vec<NodeId>)> = Vec::new();
        for i in 0..m_len {
            if self.assignment[i] == sub {
                // Every later member can pair with a `sub` node at i.
                for j in (i + 1)..m_len {
                    self.accumulate_boundary_pair(&mut fresh, topo, i, j);
                }
            } else {
                // Only later `sub` members pair with a non-`sub` node.
                let start = sub_idx.partition_point(|&j| j <= i);
                for &j in &sub_idx[start..] {
                    self.accumulate_boundary_pair(&mut fresh, topo, i, j);
                }
            }
        }
        self.finish_refresh(sub, fresh);
    }

    /// Grid-backed refresh: query the boundary radius around each `sub`
    /// member through a [`SpatialGrid`] over the member positions —
    /// O(|sub| · local density) instead of O(|sub| · members).  The
    /// discovered index pairs are sorted and deduplicated (both-in-`sub`
    /// pairs surface from each end) before accumulation, restoring the
    /// scan's ascending lexicographic (i, j) visit order so the per-pair
    /// node vectors come out bit-identical.
    fn refresh_pairs_of_grid(&mut self, sub: usize, topo: &Topology) {
        let pts: Vec<Pos> = self.members.iter().map(|&m| topo.positions[m]).collect();
        let r = topo.range * BOUNDARY_RANGE_FRAC;
        let grid = SpatialGrid::build(&pts, r.max(1e-9));
        let mut near: Vec<usize> = Vec::new();
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        for i in 0..pts.len() {
            if self.assignment[i] != sub {
                continue;
            }
            grid.within_into(&pts, pts[i], r, i, &mut near);
            for &j in &near {
                pairs.push((i.min(j), i.max(j)));
            }
        }
        pairs.sort_unstable();
        pairs.dedup();
        let mut fresh: Vec<((usize, usize), Vec<NodeId>)> = Vec::new();
        for &(i, j) in &pairs {
            self.accumulate_boundary_pair(&mut fresh, topo, i, j);
        }
        self.finish_refresh(sub, fresh);
    }

    /// Splice `sub`'s freshly derived pairs over its stale ones and
    /// re-derive the (small, O(k²)-sized) pair tables.
    fn finish_refresh(&mut self, sub: usize, fresh: Vec<((usize, usize), Vec<NodeId>)>) {
        self.boundaries.retain(|((a, b), _)| *a != sub && *b != sub);
        self.boundaries.extend(fresh);
        self.boundaries.sort_by_key(|(k2, _)| *k2);
        let n = self.sub_index.len();
        self.rebuild_pair_tables(n);
    }

    /// Sub-cluster of `node` (O(1); panics for non-members, matching the
    /// previous scan-based behavior).
    #[inline]
    pub fn sub_of(&self, node: NodeId) -> usize {
        let s = self.sub_index.get(node).copied().unwrap_or(usize::MAX);
        assert!(s != usize::MAX, "node not a member");
        s
    }

    /// Whether `node` belongs to this decomposition (O(1)).
    #[inline]
    pub fn is_member(&self, node: NodeId) -> bool {
        self.sub_index.get(node).copied().unwrap_or(usize::MAX) != usize::MAX
    }

    /// Whether `node` belongs to sub-cluster `sub` (O(1)).
    #[inline]
    pub fn in_sub(&self, node: NodeId, sub: usize) -> bool {
        self.sub_index.get(node).copied() == Some(sub)
    }

    /// Whether `node` lies on any sub-cluster boundary (O(1)).
    #[inline]
    pub fn is_boundary(&self, node: NodeId) -> bool {
        self.boundary_set.contains(node)
    }

    pub fn members_of(&self, sub: usize) -> Vec<NodeId> {
        self.per_sub[sub].clone()
    }

    /// Borrowed member list of one sub-cluster.
    #[inline]
    pub fn sub_members(&self, sub: usize) -> &[NodeId] {
        &self.per_sub[sub]
    }

    /// Member set of one sub-cluster (for O(1) allowed-target checks).
    #[inline]
    pub fn sub_set(&self, sub: usize) -> &NodeSet {
        &self.sub_sets[sub]
    }

    /// Boundary-node set of pair `pair_idx` (parallel to `boundaries`).
    #[inline]
    pub fn pair_boundary_set(&self, pair_idx: usize) -> &NodeSet {
        &self.pair_boundary[pair_idx]
    }

    /// Allowed correction targets of pair `pair_idx`: the union of the
    /// pair's two sub-cluster member sets.
    #[inline]
    pub fn pair_allowed_set(&self, pair_idx: usize) -> &NodeSet {
        &self.pair_allowed[pair_idx]
    }

    /// Delegate for a sub-cluster pair: the lowest node id among the pair's
    /// boundary nodes' sub-cluster shields — deterministic election.
    pub fn delegate(&self, a: usize, b: usize) -> usize {
        a.min(b)
    }

    fn find_boundaries(&self, topo: &Topology) -> Vec<((usize, usize), Vec<NodeId>)> {
        if self.members.len() >= GRID_PARTITION_THRESHOLD {
            self.find_boundaries_grid(topo)
        } else {
            self.find_boundaries_scan(topo)
        }
    }

    /// Reference boundary derivation: the O(m²) all-pairs scan, kept
    /// in-tree as the pin for the grid-adjacency derivation.
    fn find_boundaries_scan(&self, topo: &Topology) -> Vec<((usize, usize), Vec<NodeId>)> {
        let mut out: Vec<((usize, usize), Vec<NodeId>)> = Vec::new();
        for i in 0..self.members.len() {
            for j in (i + 1)..self.members.len() {
                self.accumulate_boundary_pair(&mut out, topo, i, j);
            }
        }
        out.sort_by_key(|(k2, _)| *k2);
        out
    }

    /// Grid-adjacency boundary derivation: each member queries the
    /// boundary radius through a [`SpatialGrid`] over the member
    /// positions, visiting only the (i, j) pairs that can possibly
    /// accumulate — O(m · local density) instead of O(m²).  The query
    /// returns ascending indices and `i` ascends outside, so pairs are
    /// visited in exactly the scan's lexicographic order and the output
    /// is bit-identical (the accumulate predicate re-checks the same
    /// exact distance the grid pre-filtered on).
    fn find_boundaries_grid(&self, topo: &Topology) -> Vec<((usize, usize), Vec<NodeId>)> {
        let pts: Vec<Pos> = self.members.iter().map(|&m| topo.positions[m]).collect();
        let r = topo.range * BOUNDARY_RANGE_FRAC;
        let grid = SpatialGrid::build(&pts, r.max(1e-9));
        let mut out: Vec<((usize, usize), Vec<NodeId>)> = Vec::new();
        let mut near: Vec<usize> = Vec::new();
        for i in 0..pts.len() {
            grid.within_into(&pts, pts[i], r, i, &mut near);
            for &j in &near {
                if j > i {
                    self.accumulate_boundary_pair(&mut out, topo, i, j);
                }
            }
        }
        out.sort_by_key(|(k2, _)| *k2);
        out
    }

    /// Accumulate the member-index pair `(i, j)` (i < j) into the per-pair
    /// boundary lists when it crosses sub-clusters within boundary range.
    /// The single implementation behind both the full scan
    /// ([`SubClusters::from_assignment`]) and the incremental refresh, so
    /// their outputs stay bit-identical: callers must visit pairs in
    /// ascending lexicographic (i, j) order.
    fn accumulate_boundary_pair(
        &self,
        out: &mut Vec<((usize, usize), Vec<NodeId>)>,
        topo: &Topology,
        i: usize,
        j: usize,
    ) {
        if self.assignment[i] == self.assignment[j] {
            return;
        }
        let (m, n) = (self.members[i], self.members[j]);
        if topo.positions[m].dist(&topo.positions[n]) <= topo.range * BOUNDARY_RANGE_FRAC {
            let key = if self.assignment[i] < self.assignment[j] {
                (self.assignment[i], self.assignment[j])
            } else {
                (self.assignment[j], self.assignment[i])
            };
            let entry = match out.iter_mut().find(|(k2, _)| *k2 == key) {
                Some(e) => e,
                None => {
                    out.push((key, Vec::new()));
                    out.last_mut().unwrap()
                }
            };
            for node in [m, n] {
                if !entry.1.contains(&node) {
                    entry.1.push(node);
                }
            }
        }
    }

    /// All boundary nodes (union over pairs), ascending.
    pub fn boundary_nodes(&self) -> Vec<NodeId> {
        self.boundary_set.iter().collect()
    }
}

/// Deterministic k-means on member positions: farthest-point init, 16
/// Lloyd iterations (converges long before that at this scale).
fn kmeans(members: &[NodeId], topo: &Topology, k: usize) -> Vec<usize> {
    let pts: Vec<(f64, f64)> =
        members.iter().map(|&m| (topo.positions[m].x, topo.positions[m].y)).collect();
    if k <= 1 || members.len() <= k {
        return (0..members.len()).map(|i| if members.len() <= k { i } else { 0 }).collect();
    }
    // Farthest-point initialization from the centroid-closest point.
    let mut centers: Vec<(f64, f64)> = Vec::with_capacity(k);
    centers.push(pts[0]);
    while centers.len() < k {
        let far = pts
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                let da = centers.iter().map(|c| d2(**a, *c)).fold(f64::MAX, f64::min);
                let db = centers.iter().map(|c| d2(**b, *c)).fold(f64::MAX, f64::min);
                da.partial_cmp(&db).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        centers.push(pts[far]);
    }
    let mut assignment = vec![0usize; pts.len()];
    for _ in 0..16 {
        for (i, p) in pts.iter().enumerate() {
            assignment[i] = centers
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| d2(*p, **a).partial_cmp(&d2(*p, **b)).unwrap())
                .map(|(j, _)| j)
                .unwrap();
        }
        for (j, c) in centers.iter_mut().enumerate() {
            let mine: Vec<&(f64, f64)> =
                pts.iter().zip(&assignment).filter(|(_, &a)| a == j).map(|(p, _)| p).collect();
            if !mine.is_empty() {
                c.0 = mine.iter().map(|p| p.0).sum::<f64>() / mine.len() as f64;
                c.1 = mine.iter().map(|p| p.1).sum::<f64>() / mine.len() as f64;
            }
        }
    }
    assignment
}

fn d2(a: (f64, f64), b: (f64, f64)) -> f64 {
    (a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)
}

/// Grid-backed region assignment: bin the member positions into
/// boundary-radius-sized [`SpatialGrid`] cells, then merge the occupied
/// cells down to at most `k` regions — farthest-point seeding over the
/// cell centroids (the k-means init rule lifted from members to cells)
/// and nearest-seed assignment (ties to the lowest seed index).  Every
/// member inherits its cell's region, so assignment costs O(m + cells·k)
/// instead of k-means' O(m·k·iters).
///
/// Returns `(assignment, k_eff)` with `k_eff ≤ k`: degenerate inputs —
/// all-coincident positions, fewer occupied cells than `k` — yield
/// fewer regions instead of panicking or fabricating empty ones.
fn grid_partition(members: &[NodeId], topo: &Topology, k: usize) -> (Vec<usize>, usize) {
    if members.is_empty() {
        return (Vec::new(), 1);
    }
    let pts: Vec<Pos> = members.iter().map(|&m| topo.positions[m]).collect();
    let cell = (topo.range * BOUNDARY_RANGE_FRAC).max(1e-9);
    let grid = SpatialGrid::build(&pts, cell);
    // Occupied cells with their member-position centroids, in cell-index
    // order (deterministic).
    let cells: Vec<(Vec<usize>, (f64, f64))> = grid
        .cells()
        .map(|(_, items)| {
            let (sx, sy) =
                items.iter().fold((0.0, 0.0), |(x, y), &i| (x + pts[i].x, y + pts[i].y));
            let c = (sx / items.len() as f64, sy / items.len() as f64);
            (items.to_vec(), c)
        })
        .collect();
    let centroids: Vec<(f64, f64)> = cells.iter().map(|(_, c)| *c).collect();
    let (cell_group, k_eff) = farthest_point_assign(&centroids, k);
    let mut assignment = vec![0usize; members.len()];
    for ((items, _), &g) in cells.iter().zip(&cell_group) {
        for &i in items {
            assignment[i] = g;
        }
    }
    (assignment, k_eff)
}

/// Farthest-point seeding + nearest-seed assignment over `points` — the
/// k-means init rule without the Lloyd iterations, shared by the grid
/// partitioner (over occupied-cell centroids) and the shield tree's
/// cluster grouping (over cluster centroids, `shield::tree`).
/// Deterministic: the first seed is `points[0]`, each further seed
/// maximizes the minimum squared distance to the seeds chosen so far,
/// and each point joins its nearest seed (ties resolve to the lowest
/// seed index).  Returns `(assignment, k_eff)` with
/// `k_eff = k.clamp(1, points.len())`: degenerate inputs — coincident
/// points, `k` beyond the point count — yield fewer groups instead of
/// fabricating empty ones.
pub(crate) fn farthest_point_assign(points: &[(f64, f64)], k: usize) -> (Vec<usize>, usize) {
    if points.is_empty() {
        return (Vec::new(), 1);
    }
    let k_eff = k.min(points.len()).max(1);
    let mut seeds: Vec<(f64, f64)> = Vec::with_capacity(k_eff);
    seeds.push(points[0]);
    while seeds.len() < k_eff {
        let far = points
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                let da = seeds.iter().map(|s| d2(**a, *s)).fold(f64::MAX, f64::min);
                let db = seeds.iter().map(|s| d2(**b, *s)).fold(f64::MAX, f64::min);
                da.partial_cmp(&db).unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        seeds.push(points[far]);
    }
    let assignment = points
        .iter()
        .map(|p| {
            let mut best = (f64::MAX, 0usize);
            for (s, seed) in seeds.iter().enumerate() {
                let dist = d2(*p, *seed);
                if dist < best.0 {
                    best = (dist, s);
                }
            }
            best.1
        })
        .collect();
    (assignment, k_eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Topology;
    use crate::util::Rng;

    fn topo(n: usize) -> Topology {
        let mut rng = Rng::new(3);
        Topology::generate(&mut rng, n, 60.0, 30.0, &[100.0], 0.001)
    }

    #[test]
    fn partitions_all_members() {
        let t = topo(20);
        let members: Vec<NodeId> = (0..20).collect();
        let sc = SubClusters::build(&members, &t, 4);
        assert_eq!(sc.assignment.len(), 20);
        for sub in 0..4 {
            assert!(!sc.members_of(sub).is_empty(), "empty sub-cluster {sub}");
        }
        let total: usize = (0..4).map(|s| sc.members_of(s).len()).sum();
        assert_eq!(total, 20);
    }

    #[test]
    fn geographic_coherence() {
        // Sub-cluster diameter should be smaller than the full spread.
        let t = topo(30);
        let members: Vec<NodeId> = (0..30).collect();
        let sc = SubClusters::build(&members, &t, 3);
        let full_diam = max_diam(&members, &t);
        for sub in 0..3 {
            let m = sc.members_of(sub);
            if m.len() >= 2 {
                assert!(max_diam(&m, &t) <= full_diam);
            }
        }
    }

    fn max_diam(nodes: &[NodeId], t: &Topology) -> f64 {
        let mut d = 0.0f64;
        for &a in nodes {
            for &b in nodes {
                d = d.max(t.positions[a].dist(&t.positions[b]));
            }
        }
        d
    }

    #[test]
    fn boundaries_are_cross_subcluster_and_in_range() {
        let t = topo(24);
        let members: Vec<NodeId> = (0..24).collect();
        let sc = SubClusters::build(&members, &t, 3);
        for ((a, b), nodes) in &sc.boundaries {
            assert!(a < b);
            for &n in nodes {
                let sn = sc.sub_of(n);
                assert!(sn == *a || sn == *b);
                // Each boundary node must be within range of some node of
                // the *other* sub-cluster of the pair.
                let other = if sn == *a { *b } else { *a };
                let reach = sc
                    .members_of(other)
                    .iter()
                    .any(|&m| t.positions[n].dist(&t.positions[m]) <= t.range * BOUNDARY_RANGE_FRAC);
                assert!(reach, "node {n} not actually on boundary");
            }
        }
    }

    #[test]
    fn k_one_is_single_subcluster() {
        let t = topo(10);
        let members: Vec<NodeId> = (0..10).collect();
        let sc = SubClusters::build(&members, &t, 1);
        assert!(sc.assignment.iter().all(|&a| a == 0));
        assert!(sc.boundaries.is_empty());
    }

    #[test]
    fn delegate_is_deterministic() {
        let t = topo(12);
        let sc = SubClusters::build(&(0..12).collect::<Vec<_>>(), &t, 3);
        assert_eq!(sc.delegate(2, 1), 1);
        assert_eq!(sc.delegate(0, 2), 0);
    }

    #[test]
    fn deterministic_build() {
        let t = topo(18);
        let m: Vec<NodeId> = (0..18).collect();
        let a = SubClusters::build(&m, &t, 3);
        let b = SubClusters::build(&m, &t, 3);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn indexed_lookups_agree_with_scans() {
        // The O(1) tables must answer exactly like the Vec scans they
        // replaced.
        let t = topo(24);
        let members: Vec<NodeId> = (0..24).collect();
        let sc = SubClusters::build(&members, &t, 3);
        let boundary = {
            // Scan-based union over pairs (the pre-index implementation).
            let mut out: Vec<NodeId> = Vec::new();
            for (_, nodes) in &sc.boundaries {
                for &n in nodes {
                    if !out.contains(&n) {
                        out.push(n);
                    }
                }
            }
            out.sort_unstable();
            out
        };
        assert_eq!(sc.boundary_nodes(), boundary);
        for n in 0..24 {
            let scan_sub =
                sc.members.iter().position(|&m| m == n).map(|i| sc.assignment[i]).unwrap();
            assert_eq!(sc.sub_of(n), scan_sub);
            assert!(sc.is_member(n));
            assert!(sc.in_sub(n, scan_sub));
            assert!(!sc.in_sub(n, scan_sub + 7));
            assert_eq!(sc.is_boundary(n), boundary.contains(&n));
        }
        assert!(!sc.is_member(24), "out-of-universe node is not a member");
        for (pi, ((a, b), nodes)) in sc.boundaries.iter().enumerate() {
            for n in 0..24 {
                assert_eq!(sc.pair_boundary_set(pi).contains(n), nodes.contains(&n));
                let in_union =
                    sc.members_of(*a).contains(&n) || sc.members_of(*b).contains(&n);
                assert_eq!(sc.pair_allowed_set(pi).contains(n), in_union);
            }
        }
        for s in 0..3 {
            assert_eq!(sc.sub_members(s), &sc.members_of(s)[..]);
            for &m in sc.sub_members(s) {
                assert!(sc.sub_set(s).contains(m));
            }
            assert_eq!(sc.sub_set(s).len(), sc.sub_members(s).len());
        }
    }

    #[test]
    fn prop_incremental_membership_matches_reference_rebuild() {
        // Randomized churn sequences: after every remove/add the
        // incremental tables must equal a from-scratch rebuild over the
        // same (members, assignment) pair.
        let mut rng = Rng::new(0xBEEF);
        for case in 0..20 {
            let n = 8 + rng.below(20);
            let t = {
                let mut trng = Rng::new(100 + case);
                Topology::generate(&mut trng, n, 60.0, 30.0, &[100.0], 0.001)
            };
            let members: Vec<NodeId> = (0..n).collect();
            let k = 2 + rng.below(3);
            let mut sc = SubClusters::build(&members, &t, k);
            for step in 0..40 {
                let node = rng.below(n);
                if rng.chance(0.5) {
                    sc.remove_member(node, &t);
                } else {
                    sc.add_member(node, &t);
                }
                let reference = SubClusters::from_assignment(
                    sc.members.clone(),
                    sc.assignment.clone(),
                    sc.k,
                    &t,
                );
                assert_eq!(sc, reference, "case {case} step {step} node {node}");
            }
        }
    }

    #[test]
    fn prop_handoff_matches_reference_rebuild_over_mobility_steps() {
        // The acceptance criterion for shield-region handoff: across
        // ≥100 random mobility steps (random node teleports within the
        // arena), the incremental handoff must produce *identical*
        // region assignments and boundary pairs to a from-scratch
        // re-partition over the same (members, assignment) pair.
        let mut rng = Rng::new(0xD1CE);
        for case in 0..5u64 {
            let n = 12 + rng.below(16);
            let mut t = {
                let mut trng = Rng::new(500 + case);
                Topology::generate(&mut trng, n, 60.0, 30.0, &[100.0], 0.001)
            };
            let members: Vec<NodeId> = (0..n).collect();
            let k = 2 + rng.below(3);
            let mut sc = SubClusters::build(&members, &t, k);
            let mut handoffs = 0usize;
            for step in 0..120 {
                let node = rng.below(n);
                // Teleport the node somewhere in (or slightly outside)
                // the arena and refresh the position-derived caches.
                t.positions[node] = crate::net::Pos {
                    x: rng.range_f64(-10.0, 70.0),
                    y: rng.range_f64(-10.0, 70.0),
                };
                t.rebuild_adjacency();
                if sc.handoff_member(node, &t) {
                    handoffs += 1;
                }
                let reference = SubClusters::from_assignment(
                    sc.members.clone(),
                    sc.assignment.clone(),
                    sc.k,
                    &t,
                );
                assert_eq!(sc, reference, "case {case} step {step} node {node}");
            }
            assert!(handoffs > 0, "case {case}: 120 teleports never crossed a region");
        }
    }

    #[test]
    fn prop_batched_handoff_matches_per_node_path() {
        // The batched per-tick refresh (ROADMAP follow-up): moving a
        // whole batch through `handoff_members` must produce the same
        // structure, the same handoff count and the same reference-
        // rebuild pin as calling `handoff_member` once per node in the
        // same order.
        let mut rng = Rng::new(0xBA7C);
        let mut total_handoffs = 0usize;
        for case in 0..8u64 {
            let n = 12 + rng.below(16);
            let mut t = {
                let mut trng = Rng::new(900 + case);
                Topology::generate(&mut trng, n, 60.0, 30.0, &[100.0], 0.001)
            };
            let members: Vec<NodeId> = (0..n).collect();
            let k = 2 + rng.below(3);
            let mut batched = SubClusters::build(&members, &t, k);
            let mut sequential = batched.clone();
            for tick in 0..25 {
                // One tick's worth of motion: several nodes teleport
                // (including, sometimes, a non-member id when the
                // partition covers a subset — here all are members).
                let mut moved: Vec<NodeId> = Vec::new();
                for _ in 0..1 + rng.below(5) {
                    let node = rng.below(n);
                    if !moved.contains(&node) {
                        moved.push(node);
                    }
                    t.positions[node] = crate::net::Pos {
                        x: rng.range_f64(-10.0, 70.0),
                        y: rng.range_f64(-10.0, 70.0),
                    };
                }
                moved.sort_unstable();
                t.rebuild_adjacency();
                let batch_count = batched.handoff_members(&moved, &t);
                let mut seq_count = 0usize;
                for &node in &moved {
                    if sequential.handoff_member(node, &t) {
                        seq_count += 1;
                    }
                }
                assert_eq!(batch_count, seq_count, "case {case} tick {tick}");
                assert_eq!(batched, sequential, "case {case} tick {tick}");
                let reference = SubClusters::from_assignment(
                    batched.members.clone(),
                    batched.assignment.clone(),
                    batched.k,
                    &t,
                );
                assert_eq!(batched, reference, "case {case} tick {tick} vs rebuild");
                total_handoffs += batch_count;
            }
        }
        assert!(total_handoffs > 0, "no batch ever crossed a region");
    }

    #[test]
    fn batched_handoff_skips_non_members_and_empty_batches() {
        let t = topo(20);
        let members: Vec<NodeId> = (0..10).collect();
        let mut sc = SubClusters::build(&members, &t, 2);
        let before = sc.clone();
        assert_eq!(sc.handoff_members(&[], &t), 0);
        assert_eq!(sc, before, "empty batch must be a no-op");
        assert_eq!(sc.handoff_members(&[15, 17], &t), 0);
        assert_eq!(sc, before, "non-member batch must be a no-op");
    }

    #[test]
    fn prop_handoff_interleaved_with_churn_matches_reference() {
        // Mobility and membership churn hit the same incremental tables;
        // interleaving them must stay pinned to the reference rebuild.
        let mut rng = Rng::new(0xFADE);
        let n = 20usize;
        let mut t = {
            let mut trng = Rng::new(77);
            Topology::generate(&mut trng, n, 60.0, 30.0, &[100.0], 0.001)
        };
        let members: Vec<NodeId> = (0..n).collect();
        let mut sc = SubClusters::build(&members, &t, 3);
        for step in 0..150 {
            let node = rng.below(n);
            match rng.below(4) {
                0 => {
                    sc.remove_member(node, &t);
                }
                1 => {
                    sc.add_member(node, &t);
                }
                _ => {
                    t.positions[node] = crate::net::Pos {
                        x: rng.range_f64(0.0, 60.0),
                        y: rng.range_f64(0.0, 60.0),
                    };
                    t.rebuild_adjacency();
                    sc.handoff_member(node, &t);
                }
            }
            let reference =
                SubClusters::from_assignment(sc.members.clone(), sc.assignment.clone(), sc.k, &t);
            assert_eq!(sc, reference, "step {step} node {node}");
        }
    }

    #[test]
    fn handoff_moves_node_to_nearest_region() {
        // Drop a node directly onto another sub-cluster's centroid: the
        // handoff must migrate it there, and a non-member is a no-op.
        let t0 = topo(24);
        let members: Vec<NodeId> = (0..24).collect();
        let mut t = t0.clone();
        let mut sc = SubClusters::build(&members, &t, 3);
        let node = 0usize;
        let home = sc.sub_of(node);
        let other = (0..3).find(|&s| s != home && !sc.members_of(s).is_empty()).unwrap();
        // Centroid of the target region (excluding the probe).
        let om = sc.members_of(other);
        let (cx, cy) = om.iter().fold((0.0, 0.0), |(x, y), &m| {
            (x + t.positions[m].x, y + t.positions[m].y)
        });
        t.positions[node] =
            crate::net::Pos { x: cx / om.len() as f64, y: cy / om.len() as f64 };
        t.rebuild_adjacency();
        assert!(sc.handoff_member(node, &t), "probe must be handed off");
        assert_eq!(sc.sub_of(node), other);
        assert!(sc.sub_set(other).contains(node));
        assert!(!sc.sub_set(home).contains(node));
        // A second handoff without further movement is a same-region
        // refresh, not a migration.
        assert!(!sc.handoff_member(node, &t));
        // Non-members are untouched.
        let mut sc2 = SubClusters::build(&members[..10], &t, 2);
        assert!(!sc2.handoff_member(15, &t));
    }

    #[test]
    fn remove_then_add_keeps_queries_consistent() {
        let t = topo(24);
        let members: Vec<NodeId> = (0..24).collect();
        let mut sc = SubClusters::build(&members, &t, 3);
        assert!(sc.remove_member(5, &t));
        assert!(!sc.remove_member(5, &t), "double remove is a no-op");
        assert!(!sc.is_member(5));
        assert!(!sc.is_boundary(5), "removed nodes leave every boundary");
        assert_eq!(sc.members.len(), 23);
        for (_, nodes) in &sc.boundaries {
            assert!(!nodes.contains(&5));
        }
        assert!(sc.add_member(5, &t));
        assert!(!sc.add_member(5, &t), "double add is a no-op");
        assert!(sc.is_member(5));
        let s = sc.sub_of(5);
        assert!(s < 3);
        assert!(sc.sub_set(s).contains(5));
        assert!(sc.sub_members(s).contains(&5));
    }

    #[test]
    fn add_member_picks_nearest_subcluster() {
        // A node re-added right on top of an existing member must land in
        // that member's sub-cluster.
        let t = topo(20);
        let members: Vec<NodeId> = (0..20).collect();
        let mut sc = SubClusters::build(&members, &t, 3);
        let probe = 7;
        let home = sc.sub_of(probe);
        sc.remove_member(probe, &t);
        // Unless the removal emptied the home sub-cluster, the centroid
        // nearest to the probe's position is its old sub's.
        if !sc.members_of(home).is_empty() {
            sc.add_member(probe, &t);
            // The probe must land in SOME valid sub-cluster and the
            // structure must match the reference rebuild.
            let s = sc.sub_of(probe);
            assert!(s < 3);
            let reference =
                SubClusters::from_assignment(sc.members.clone(), sc.assignment.clone(), 3, &t);
            assert_eq!(sc, reference);
        }
    }

    #[test]
    fn partial_membership_indexed() {
        // Members are a strict subset of the topology's nodes: the index
        // must distinguish non-members from members at O(1).
        let t = topo(20);
        let members: Vec<NodeId> = (0..10).collect();
        let sc = SubClusters::build(&members, &t, 2);
        for n in 0..10 {
            assert!(sc.is_member(n));
        }
        for n in 10..20 {
            assert!(!sc.is_member(n));
            assert!(!sc.is_boundary(n));
        }
    }

    #[test]
    fn grid_build_is_pinned_to_the_scan_reference() {
        // At grid scale, `build` routes through the cell-merge
        // partitioner and grid-adjacency boundary derivation; the whole
        // structure must equal the forced O(m²) scan over the same
        // (members, assignment) pair, byte for byte.
        for (case, (n, k)) in [(64usize, 4usize), (96, 6), (150, 10)].into_iter().enumerate() {
            let t = {
                let mut trng = Rng::new(0x9137 + case as u64);
                Topology::generate(&mut trng, n, 250.0, 30.0, &[100.0], 0.001)
            };
            let members: Vec<NodeId> = (0..n).collect();
            let sc = SubClusters::build(&members, &t, k);
            assert!(sc.k >= 2 && sc.k <= k, "n={n} produced k={}", sc.k);
            let reference = SubClusters::from_assignment_reference(
                sc.members.clone(),
                sc.assignment.clone(),
                sc.k,
                &t,
            );
            assert_eq!(sc, reference, "case {case} n={n} k={k}");
            let covered: usize = (0..sc.k).map(|s| sc.members_of(s).len()).sum();
            assert_eq!(covered, n, "every member owned by exactly one region");
        }
    }

    #[test]
    fn grid_boundary_derivation_matches_scan_on_partial_membership() {
        // A ≥ threshold membership that is a strict subset of the node-id
        // space (the common case inside a cluster) must still derive
        // scan-identical boundaries through the grid.
        let t = {
            let mut trng = Rng::new(0x5b5e7);
            Topology::generate(&mut trng, 120, 240.0, 30.0, &[100.0], 0.001)
        };
        let members: Vec<NodeId> = (20..100).collect();
        assert!(members.len() >= GRID_PARTITION_THRESHOLD);
        let sc = SubClusters::build(&members, &t, 5);
        let reference = SubClusters::from_assignment_reference(
            sc.members.clone(),
            sc.assignment.clone(),
            sc.k,
            &t,
        );
        assert_eq!(sc, reference);
    }

    #[test]
    fn degenerate_partitions_yield_fewer_regions_without_panicking() {
        // k far beyond the member count clamps down instead of panicking.
        let t = topo(20);
        let members: Vec<NodeId> = (0..20).collect();
        let sc = SubClusters::build(&members, &t, 200);
        assert!(sc.k <= 20);
        assert_eq!(sc.assignment.len(), 20);

        // Empty membership: one (empty) region, no boundaries.
        let sc = SubClusters::build(&[], &t, 4);
        assert_eq!(sc.k, 1);
        assert!(sc.members.is_empty());
        assert!(sc.boundaries.is_empty());
        assert!(!sc.is_member(0));

        // All-coincident positions at grid scale: a single occupied cell
        // collapses to one region — no empty fabricated regions, no
        // panic, no boundary pairs (a pair needs two regions).
        let n = 80usize;
        let mut t = {
            let mut trng = Rng::new(0xC01D);
            Topology::generate(&mut trng, n, 200.0, 30.0, &[100.0], 0.001)
        };
        for p in &mut t.positions {
            *p = crate::net::Pos { x: 12.0, y: 34.0 };
        }
        t.rebuild_adjacency();
        let members: Vec<NodeId> = (0..n).collect();
        let sc = SubClusters::build(&members, &t, 8);
        assert_eq!(sc.k, 1, "coincident members collapse to one region");
        assert!(sc.assignment.iter().all(|&a| a == 0));
        assert!(sc.boundaries.is_empty());
        assert_eq!(sc.members_of(0).len(), n);
    }

    #[test]
    fn prop_grid_partition_matches_scan_reference_under_churn_and_mobility() {
        // Acceptance pin: a ≥ 100-step randomized churn + mobility +
        // handoff run on a grid-scale membership stays byte-identical to
        // the O(m²) scan reference rebuild after every step — the
        // incremental grid refresh and the forced-scan construction
        // never diverge.
        let mut rng = Rng::new(0x61D5);
        let n = 96usize;
        let mut t = {
            let mut trng = Rng::new(4242);
            Topology::generate(&mut trng, n, 220.0, 30.0, &[100.0], 0.001)
        };
        let members: Vec<NodeId> = (0..n).collect();
        let mut sc = SubClusters::build(&members, &t, 6);
        assert!(sc.members.len() >= GRID_PARTITION_THRESHOLD);
        let mut handoffs = 0usize;
        for step in 0..120 {
            let node = rng.below(n);
            match rng.below(4) {
                0 => {
                    sc.remove_member(node, &t);
                }
                1 => {
                    sc.add_member(node, &t);
                }
                _ => {
                    t.positions[node] = crate::net::Pos {
                        x: rng.range_f64(-20.0, 240.0),
                        y: rng.range_f64(-20.0, 240.0),
                    };
                    t.rebuild_adjacency();
                    if sc.handoff_member(node, &t) {
                        handoffs += 1;
                    }
                }
            }
            let reference = SubClusters::from_assignment_reference(
                sc.members.clone(),
                sc.assignment.clone(),
                sc.k,
                &t,
            );
            assert_eq!(sc, reference, "step {step} node {node}");
        }
        assert!(handoffs > 0, "120 steps never crossed a region");
    }
}
