//! Mutable cluster membership with incremental index maintenance.
//!
//! The deployment's topology and cluster assignment are fixed for the
//! lifetime of an experiment, but *which nodes are alive* is not: the
//! event core delivers node failure/leave and (re)join events.  A
//! [`Membership`] overlays the static [`super::Deployment`] with the
//! alive set and keeps the derived per-round lookup structures — alive
//! members per cluster, alive cluster-neighbors per node — maintained
//! *incrementally*: a churn event costs O(cluster size + node degree),
//! not a full O(n · degree) rebuild.
//!
//! The incremental path is pinned to [`Membership::rebuild`] — a
//! from-scratch reference construction — by randomized equivalence tests
//! (the same pattern that pins the indexed shields to
//! `shield::reference`).

use super::{Deployment, NodeId};
use crate::util::NodeSet;

/// The alive-node overlay of one deployment.
///
/// All derived views preserve the deployment's member ordering: alive
/// member lists keep `ClusterSpec::members` order, alive neighbor lists
/// keep the ascending order of `Deployment::cluster_neighbors_ref`.
#[derive(Debug, Clone, PartialEq)]
pub struct Membership {
    alive: NodeSet,
    /// Alive members per cluster, in `ClusterSpec::members` order.
    cluster_alive: Vec<Vec<NodeId>>,
    /// Alive-member set per cluster.
    cluster_alive_set: Vec<NodeSet>,
    /// Alive cluster-neighbors per node (ascending); empty for dead nodes.
    alive_neighbors: Vec<Vec<NodeId>>,
}

impl Membership {
    /// Everyone alive (the static-deployment special case).
    pub fn full(dep: &Deployment) -> Membership {
        let mut alive = NodeSet::with_universe(dep.n());
        for id in 0..dep.n() {
            alive.insert(id);
        }
        Membership::rebuild(dep, &alive)
    }

    /// Reference from-scratch construction for a given alive set.  The
    /// incremental [`Membership::fail`] / [`Membership::join`] path must
    /// produce exactly this structure — pinned by equivalence tests.
    pub fn rebuild(dep: &Deployment, alive: &NodeSet) -> Membership {
        let n = dep.n();
        let cluster_alive: Vec<Vec<NodeId>> = dep
            .clusters
            .iter()
            .map(|c| c.members.iter().copied().filter(|&m| alive.contains(m)).collect())
            .collect();
        let cluster_alive_set =
            cluster_alive.iter().map(|m| NodeSet::from_slice(n, m)).collect();
        let alive_neighbors = (0..n)
            .map(|node| {
                if !alive.contains(node) {
                    return Vec::new();
                }
                dep.cluster_neighbors_ref(node)
                    .iter()
                    .copied()
                    .filter(|&m| alive.contains(m))
                    .collect()
            })
            .collect();
        Membership { alive: alive.clone(), cluster_alive, cluster_alive_set, alive_neighbors }
    }

    #[inline]
    pub fn is_alive(&self, node: NodeId) -> bool {
        self.alive.contains(node)
    }

    pub fn n_alive(&self) -> usize {
        self.alive.len()
    }

    /// The alive set itself (for reference rebuilds and reporting).
    pub fn alive_set(&self) -> &NodeSet {
        &self.alive
    }

    /// Alive members of `cluster`, in deployment member order.
    #[inline]
    pub fn alive_members(&self, cluster: usize) -> &[NodeId] {
        &self.cluster_alive[cluster]
    }

    /// Alive-member set of `cluster` (O(1) membership checks).
    #[inline]
    pub fn alive_cluster_set(&self, cluster: usize) -> &NodeSet {
        &self.cluster_alive_set[cluster]
    }

    /// Alive cluster-neighbors of `node`, ascending.  Empty for dead
    /// nodes.
    #[inline]
    pub fn alive_neighbors(&self, node: NodeId) -> &[NodeId] {
        &self.alive_neighbors[node]
    }

    /// Node failure / departure: drop `node` from every index.  Returns
    /// false when the node is already dead (no-op).  O(cluster size +
    /// degree).
    pub fn fail(&mut self, dep: &Deployment, node: NodeId) -> bool {
        if !self.alive.remove(node) {
            return false;
        }
        let c = dep.cluster_of(node);
        if let Some(pos) = self.cluster_alive[c].iter().position(|&m| m == node) {
            self.cluster_alive[c].remove(pos);
        }
        self.cluster_alive_set[c].remove(node);
        for &m in dep.cluster_neighbors_ref(node) {
            if let Ok(pos) = self.alive_neighbors[m].binary_search(&node) {
                self.alive_neighbors[m].remove(pos);
            }
        }
        self.alive_neighbors[node].clear();
        true
    }

    /// Node (re)join: restore `node` into every index.  Returns false
    /// when the node is already alive (no-op).  O(cluster size + degree).
    pub fn join(&mut self, dep: &Deployment, node: NodeId) -> bool {
        if !self.alive.insert(node) {
            return false;
        }
        let c = dep.cluster_of(node);
        // Re-insert at the node's position in deployment member order.
        let mut pos = 0usize;
        for &m in &dep.clusters[c].members {
            if m == node {
                break;
            }
            if self.alive.contains(m) {
                pos += 1;
            }
        }
        self.cluster_alive[c].insert(pos, node);
        self.cluster_alive_set[c].insert(node);
        self.alive_neighbors[node] = dep
            .cluster_neighbors_ref(node)
            .iter()
            .copied()
            .filter(|&m| self.alive.contains(m))
            .collect();
        for &m in dep.cluster_neighbors_ref(node) {
            if self.alive.contains(m) {
                if let Err(ins) = self.alive_neighbors[m].binary_search(&node) {
                    self.alive_neighbors[m].insert(ins, node);
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CONTAINER_PROFILE;
    use crate::util::Rng;

    fn dep(n: usize, cluster_size: usize, seed: u64) -> Deployment {
        let mut rng = Rng::new(seed);
        Deployment::generate(&mut rng, n, cluster_size, &CONTAINER_PROFILE)
    }

    #[test]
    fn full_membership_mirrors_deployment() {
        let d = dep(25, 5, 3);
        let m = Membership::full(&d);
        assert_eq!(m.n_alive(), 25);
        for (ci, c) in d.clusters.iter().enumerate() {
            assert_eq!(m.alive_members(ci), &c.members[..]);
        }
        for node in 0..25 {
            assert!(m.is_alive(node));
            assert_eq!(m.alive_neighbors(node), d.cluster_neighbors_ref(node));
        }
    }

    #[test]
    fn fail_and_join_roundtrip() {
        let d = dep(25, 5, 3);
        let full = Membership::full(&d);
        let mut m = full.clone();
        assert!(m.fail(&d, 7));
        assert!(!m.fail(&d, 7), "double fail is a no-op");
        assert!(!m.is_alive(7));
        assert_eq!(m.n_alive(), 24);
        let c = d.cluster_of(7);
        assert!(!m.alive_members(c).contains(&7));
        assert!(!m.alive_cluster_set(c).contains(7));
        assert!(m.alive_neighbors(7).is_empty());
        for node in 0..25 {
            assert!(!m.alive_neighbors(node).contains(&7));
        }
        assert!(m.join(&d, 7));
        assert!(!m.join(&d, 7), "double join is a no-op");
        assert_eq!(m, full, "fail + join restores the full membership");
    }

    #[test]
    fn prop_incremental_matches_rebuild() {
        // Randomized churn sequences: after every event the incremental
        // structure must equal the from-scratch reference for the same
        // alive set.
        let mut rng = Rng::new(0xC0FFEE);
        for case in 0..30 {
            let n = 6 + rng.below(30);
            let cs = 1 + rng.below(8);
            let d = dep(n, cs.min(n), 1000 + case);
            let mut m = Membership::full(&d);
            for step in 0..60 {
                let node = rng.below(n);
                if rng.chance(0.5) {
                    m.fail(&d, node);
                } else {
                    m.join(&d, node);
                }
                let reference = Membership::rebuild(&d, m.alive_set());
                assert_eq!(m, reference, "case {case} step {step} node {node}");
            }
        }
    }

    #[test]
    fn alive_neighbor_lists_stay_sorted_under_churn() {
        let d = dep(20, 10, 11);
        let mut rng = Rng::new(5);
        let mut m = Membership::full(&d);
        for _ in 0..100 {
            let node = rng.below(20);
            if rng.chance(0.5) {
                m.fail(&d, node);
            } else {
                m.join(&d, node);
            }
            for v in 0..20 {
                let nb = m.alive_neighbors(v);
                assert!(nb.windows(2).all(|w| w[0] < w[1]), "unsorted neighbors of {v}");
            }
        }
    }
}
