//! Edge nodes, resource capacities, cluster formation and sub-clusters.
//!
//! Mirrors §III of the paper: clusters are proximity-close groups of edge
//! nodes; each cluster elects the highest-capacity node as *cluster head*
//! (which runs the centralized RL scheduler and/or the SROLE-C shield);
//! SROLE-D splits a cluster into geographic *sub-clusters*, one shield
//! each, with boundary nodes handled by neighboring-shield delegates.

pub mod membership;
pub mod profiles;
pub mod subcluster;

pub use membership::Membership;
pub use profiles::{ResourceProfile, CONTAINER_PROFILE, REAL_EDGE_PROFILE};
pub use subcluster::SubClusters;

use crate::net::Topology;
use crate::util::Rng;

/// Index of an edge node within an experiment.
pub type NodeId = usize;

/// Resource types tracked per node (paper Eq. 1: CPU, memory, bandwidth).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    Cpu,
    Mem,
    Bw,
}

impl ResourceKind {
    pub const ALL: [ResourceKind; 3] = [ResourceKind::Cpu, ResourceKind::Mem, ResourceKind::Bw];

    pub fn name(&self) -> &'static str {
        match self {
            ResourceKind::Cpu => "cpu",
            ResourceKind::Mem => "mem",
            ResourceKind::Bw => "bw",
        }
    }
}

/// A bundle of the three resources.  Units: CPU in host-ratio (1.0 = one
/// full core of the reference host), memory in MB, bandwidth in Mbps.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Resources {
    pub cpu: f64,
    pub mem: f64,
    pub bw: f64,
}

impl Resources {
    pub fn new(cpu: f64, mem: f64, bw: f64) -> Resources {
        Resources { cpu, mem, bw }
    }

    pub fn get(&self, k: ResourceKind) -> f64 {
        match k {
            ResourceKind::Cpu => self.cpu,
            ResourceKind::Mem => self.mem,
            ResourceKind::Bw => self.bw,
        }
    }

    pub fn get_mut(&mut self, k: ResourceKind) -> &mut f64 {
        match k {
            ResourceKind::Cpu => &mut self.cpu,
            ResourceKind::Mem => &mut self.mem,
            ResourceKind::Bw => &mut self.bw,
        }
    }

    pub fn add(&self, other: &Resources) -> Resources {
        Resources { cpu: self.cpu + other.cpu, mem: self.mem + other.mem, bw: self.bw + other.bw }
    }

    pub fn sub(&self, other: &Resources) -> Resources {
        Resources { cpu: self.cpu - other.cpu, mem: self.mem - other.mem, bw: self.bw - other.bw }
    }

    pub fn scale(&self, f: f64) -> Resources {
        Resources { cpu: self.cpu * f, mem: self.mem * f, bw: self.bw * f }
    }

    /// Per-resource utilization of `demand` against `self` as capacity
    /// (paper Eq. 1: u_k = D_k / C_k).
    pub fn utilization(&self, demand: &Resources, k: ResourceKind) -> f64 {
        let cap = self.get(k);
        if cap <= 0.0 {
            return f64::INFINITY;
        }
        demand.get(k) / cap
    }

    /// Combined utilization across resource types (paper Eq. 2:
    /// u = Π_k u_k).
    pub fn combined_utilization(&self, demand: &Resources) -> f64 {
        ResourceKind::ALL.iter().map(|&k| self.utilization(demand, k)).product()
    }
}

/// One edge device.
#[derive(Debug, Clone)]
pub struct EdgeNode {
    pub id: NodeId,
    pub caps: Resources,
}

/// A cluster: members, head, and (for SROLE-D) sub-clusters.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub members: Vec<NodeId>,
    pub head: NodeId,
}

/// The full emulated edge deployment for one experiment.
///
/// Cluster membership and the per-node cluster-neighbor lists are
/// precomputed at construction ([`Deployment::new`]), so the per-round
/// hot paths (shield checks, MARL candidate sets) answer membership and
/// adjacency in O(1)/O(degree) instead of rescanning member vectors.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub nodes: Vec<EdgeNode>,
    pub topo: Topology,
    pub clusters: Vec<ClusterSpec>,
    /// `cluster_index[node]` = index into `clusters`.
    cluster_index: Vec<usize>,
    /// Per-node transmission-range neighbors restricted to the node's own
    /// cluster, in ascending id order.
    cluster_neighbors: Vec<Vec<NodeId>>,
}

impl Deployment {
    /// Assemble a deployment from parts, building the membership and
    /// adjacency indices.  Every member node must appear in exactly one
    /// cluster.
    pub fn new(nodes: Vec<EdgeNode>, topo: Topology, clusters: Vec<ClusterSpec>) -> Deployment {
        let n = nodes.len();
        let mut cluster_index = vec![usize::MAX; n];
        for (ci, c) in clusters.iter().enumerate() {
            for &m in &c.members {
                assert_eq!(cluster_index[m], usize::MAX, "node {m} in two clusters");
                cluster_index[m] = ci;
            }
        }
        assert!(
            cluster_index.iter().all(|&c| c != usize::MAX),
            "every node must belong to a cluster"
        );
        let cluster_neighbors = (0..n)
            .map(|node| {
                topo.neighbors(node)
                    .into_iter()
                    .filter(|&m| cluster_index[m] == cluster_index[node])
                    .collect()
            })
            .collect();
        Deployment { nodes, topo, clusters, cluster_index, cluster_neighbors }
    }

    /// Build a deployment per the paper's setup: `n` nodes in clusters of
    /// `cluster_size`, resources assigned round-robin from `profile`
    /// ("the resources of the devices were assigned in a round-robin
    /// way", §V-A), positions geographically grouped.
    pub fn generate(rng: &mut Rng, n: usize, cluster_size: usize, profile: &ResourceProfile) -> Deployment {
        Deployment::generate_spread(rng, n, cluster_size, profile, 0.0)
    }

    /// [`Deployment::generate`] with an explicit geographic cluster
    /// spread in meters (`<= 0` falls back to the profile's default).
    /// The scale sweeps use this to hold node *density* constant as a
    /// single cluster grows to 10k nodes, keeping the grid adjacency —
    /// and every O(n·k) structure built on it — genuinely sparse.
    pub fn generate_spread(
        rng: &mut Rng,
        n: usize,
        cluster_size: usize,
        profile: &ResourceProfile,
        spread_m: f64,
    ) -> Deployment {
        let spread = if spread_m > 0.0 { spread_m } else { profile.cluster_spread_m };
        let topo = Topology::generate_clustered(
            rng,
            n,
            cluster_size,
            spread,
            profile.range_m,
            &profile.bw_choices,
            profile.latency_s,
        );
        let nodes: Vec<EdgeNode> =
            (0..n).map(|id| EdgeNode { id, caps: profile.round_robin(id) }).collect();
        let n_clusters = n.div_ceil(cluster_size);
        let clusters = (0..n_clusters)
            .map(|c| {
                let members: Vec<NodeId> = ((c * cluster_size)..n.min((c + 1) * cluster_size)).collect();
                // Head = the highest-capacity member ("the cluster head that
                // has relatively high capacity").
                let head = *members
                    .iter()
                    .max_by(|&&a, &&b| {
                        let ka = nodes[a].caps.cpu * nodes[a].caps.mem;
                        let kb = nodes[b].caps.cpu * nodes[b].caps.mem;
                        ka.partial_cmp(&kb).unwrap()
                    })
                    .unwrap();
                ClusterSpec { members, head }
            })
            .collect();
        Deployment::new(nodes, topo, clusters)
    }

    pub fn n(&self) -> usize {
        self.nodes.len()
    }

    /// The cluster index containing `node` (O(1) table lookup).
    #[inline]
    pub fn cluster_of(&self, node: NodeId) -> usize {
        self.cluster_index[node]
    }

    /// Neighbors of `node` restricted to its own cluster (the MARL agent's
    /// candidate set).  Precomputed; this clones — the hot paths use
    /// [`Deployment::cluster_neighbors_ref`].
    pub fn cluster_neighbors(&self, node: NodeId) -> Vec<NodeId> {
        self.cluster_neighbors[node].clone()
    }

    /// Borrowed view of the precomputed cluster-neighbor list.
    #[inline]
    pub fn cluster_neighbors_ref(&self, node: NodeId) -> &[NodeId] {
        &self.cluster_neighbors[node]
    }

    /// Re-derive the cluster-restricted adjacency from the topology's
    /// *current* positions — the mobility hook.  The caller must have
    /// refreshed the topology's own cache first
    /// ([`crate::net::Topology::rebuild_adjacency`], which
    /// [`crate::net::DynamicTopology::advance`] does); derived overlays
    /// ([`Membership`]) must be rebuilt afterwards.
    pub fn refresh_adjacency(&mut self) {
        let idx = &self.cluster_index;
        let topo = &self.topo;
        let fresh: Vec<Vec<NodeId>> = (0..self.nodes.len())
            .map(|node| {
                topo.neighbors_ref(node)
                    .iter()
                    .copied()
                    .filter(|&m| idx[m] == idx[node])
                    .collect()
            })
            .collect();
        self.cluster_neighbors = fresh;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deployment(n: usize) -> Deployment {
        let mut rng = Rng::new(7);
        Deployment::generate(&mut rng, n, 5, &CONTAINER_PROFILE)
    }

    #[test]
    fn utilization_math() {
        let caps = Resources::new(1.0, 2048.0, 100.0);
        let demand = Resources::new(0.5, 1024.0, 25.0);
        assert_eq!(caps.utilization(&demand, ResourceKind::Cpu), 0.5);
        assert_eq!(caps.utilization(&demand, ResourceKind::Mem), 0.5);
        assert_eq!(caps.utilization(&demand, ResourceKind::Bw), 0.25);
        assert!((caps.combined_utilization(&demand) - 0.0625).abs() < 1e-12);
    }

    #[test]
    fn zero_capacity_is_infinite_utilization() {
        let caps = Resources::new(0.0, 100.0, 100.0);
        let demand = Resources::new(0.1, 0.0, 0.0);
        assert!(caps.utilization(&demand, ResourceKind::Cpu).is_infinite());
    }

    #[test]
    fn clusters_partition_nodes() {
        let d = deployment(25);
        assert_eq!(d.clusters.len(), 5);
        let mut all: Vec<NodeId> = d.clusters.iter().flat_map(|c| c.members.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn head_is_member_with_max_capacity() {
        let d = deployment(25);
        for c in &d.clusters {
            assert!(c.members.contains(&c.head));
            let kh = d.nodes[c.head].caps.cpu * d.nodes[c.head].caps.mem;
            for &m in &c.members {
                let km = d.nodes[m].caps.cpu * d.nodes[m].caps.mem;
                assert!(kh >= km);
            }
        }
    }

    #[test]
    fn uneven_cluster_sizes() {
        let d = deployment(12); // 5 + 5 + 2
        assert_eq!(d.clusters.len(), 3);
        assert_eq!(d.clusters[2].members.len(), 2);
    }

    #[test]
    fn cluster_neighbors_stay_in_cluster() {
        let d = deployment(25);
        for id in 0..25 {
            let c = d.cluster_of(id);
            for nb in d.cluster_neighbors(id) {
                assert_eq!(d.cluster_of(nb), c);
                assert_ne!(nb, id);
            }
        }
    }

    #[test]
    fn cluster_members_are_mutually_reachable() {
        // The clustered generator must place cluster members within range
        // so MARL agents actually have candidates.
        let d = deployment(25);
        for id in 0..25 {
            assert!(!d.cluster_neighbors(id).is_empty(), "node {id} isolated");
        }
    }

    #[test]
    fn refresh_adjacency_tracks_moved_positions() {
        let mut d = deployment(25);
        // Teleport node 3 far outside everyone's range.
        d.topo.positions[3] = crate::net::Pos { x: 1e6, y: 1e6 };
        d.topo.rebuild_adjacency();
        d.refresh_adjacency();
        assert!(d.cluster_neighbors_ref(3).is_empty());
        for id in 0..25 {
            assert!(!d.cluster_neighbors_ref(id).contains(&3));
            // Still cluster-restricted and in range.
            let c = d.cluster_of(id);
            for &nb in d.cluster_neighbors_ref(id) {
                assert_eq!(d.cluster_of(nb), c);
                assert!(d.topo.positions[id].dist(&d.topo.positions[nb]) <= d.topo.range);
            }
        }
        // Teleport it back onto a cluster-mate: adjacency returns.
        let mate = d.clusters[d.cluster_of(3)].members.iter().copied().find(|&m| m != 3).unwrap();
        d.topo.positions[3] = d.topo.positions[mate];
        d.topo.rebuild_adjacency();
        d.refresh_adjacency();
        assert!(d.cluster_neighbors_ref(3).contains(&mate));
        assert!(d.cluster_neighbors_ref(mate).contains(&3));
    }

    #[test]
    fn round_robin_resources_cycle() {
        let d = deployment(25);
        let p = &CONTAINER_PROFILE;
        assert_eq!(d.nodes[0].caps.mem, p.mem_choices[0]);
        assert_eq!(d.nodes[1].caps.mem, p.mem_choices[1 % p.mem_choices.len()]);
    }
}
